"""Real-data scenarios end to end: ingest ENTSO-E prices + PVGIS solar,
inspect the canonical tables, lower the REAL_PACK next to the synthetic
catalog under ONE compiled step, and roll a real-data day.

    PYTHONPATH=src python examples/real_data.py

Everything runs offline from the vendored sample extracts (~75 KB under
``src/repro/data/ingest/fixtures/``); ``docs/data_provenance.md`` documents
their schemas and how to fetch full datasets yourself.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import scenarios
from repro.core import ChargaxEnv, EnvConfig
from repro.data import ingest


def main():
    env = ChargaxEnv(EnvConfig())
    dtm = env.config.dt_minutes

    # --- 1. the ingested tables themselves ----------------------------------
    print("registered real-data sources:")
    for name, src in ingest.SOURCES.items():
        print(f"  {name:18s} [{src.kind:6s}] {src.description}")

    prices = ingest.load_price_table("nl_2024", dtm)  # (365, spd) EUR/kWh
    neg_hours = float((prices < 0).mean()) * 365 * 24
    print(
        f"\nNL 2024 day-ahead: mean {prices.mean():.3f} EUR/kWh, "
        f"min {prices.min():.3f}, max {prices.max():.3f}, "
        f"~{neg_hours:.0f} negative hours/year"
    )
    for site in ("pvgis_nl_delft", "pvgis_es_seville"):
        shape = ingest.load_pv_table(site, dtm)  # peak-normalised
        cap_factor = float(shape.mean())
        print(f"{site}: capacity factor {cap_factor:.2%} of peak")

    # --- 2. REAL_PACK + the full synthetic catalog: one jit entry -----------
    all_names = scenarios.names()
    params = [scenarios.make(n).make_params(env) for n in all_names]
    step = jax.jit(env.step)
    _, state = env.reset(jax.random.key(0), params[0])
    action = env.sample_action(jax.random.key(1))
    step(jax.random.key(2), state, action, params[0])
    n_entries = step._cache_size()
    for p in params[1:]:
        step(jax.random.key(2), state, action, p)
    assert step._cache_size() == n_entries, "a scenario recompiled the step!"
    print(
        f"\n{len(all_names)} scenarios ({len(scenarios.REAL_PACK)} real-data) "
        f"stepped through {n_entries} compiled program(s)"
    )

    # --- 3. a 24h rollout on a real-data world ------------------------------
    sc = scenarios.make("real_es_solar_heavy")
    p = sc.make_params(env)

    @jax.jit
    def rollout(key, p):
        _, state = env.reset(key, p)

        def body(carry, _):
            key, state = carry
            key, ka, ks = jax.random.split(key, 3)
            _, state, r, _, info = env.step(ks, state, env.sample_action(ka), p)
            return (key, state), (r, info["e_pv"])

        (_, state), (rs, e_pv) = jax.lax.scan(
            body, (key, state), None, env.config.episode_steps
        )
        return state, rs, e_pv

    state, rs, e_pv = rollout(jax.random.key(3), p)
    print(
        f"{sc.name}: {int(state.cars_served)} cars served, "
        f"profit EUR {float(state.profit_cum):.2f}, "
        f"PV {float(e_pv.sum()):.1f} kWh (real Seville shape @ "
        f"{sc.pv_peak_kw:.0f} kW)"
    )

    # --- 4. PPO across the real-data distribution ---------------------------
    from repro.rl import PPOConfig, make_train

    stacked = scenarios.stack_params(
        [scenarios.make(n).make_params(env) for n in scenarios.REAL_PACK]
    )
    cfg = PPOConfig(
        total_timesteps=40_000, num_envs=len(scenarios.REAL_PACK) * 2,
        rollout_steps=100, hidden=(64, 64),
    )
    print(f"\ntraining PPO over REAL_PACK ({', '.join(scenarios.REAL_PACK)}) ...")
    out = jax.jit(make_train(cfg, env, scenario_params=stacked))(jax.random.key(4))
    rr = out["metrics"]["rollout_reward"]
    print(f"rollout reward: {float(rr[0]):.0f} -> {float(rr[-1]):.0f}")


if __name__ == "__main__":
    main()
