"""Build a CUSTOM charging-station architecture (paper Fig. 3c) with a
battery, a custom reward, and a price-threshold policy — the "bring your own
infrastructure" workflow the paper's modularity claim is about.

    PYTHONPATH=src python examples/custom_station.py
"""
import dataclasses

import jax

from repro.core import ChargaxEnv, EnvConfig, RewardWeights
from repro.core import station
from repro.rl import evaluate
from repro.rl.baselines import price_threshold_policy


def main():
    # --- a deep custom tree: grid -> 2 transformers -> 4 groups of ports ----
    grp = lambda n, dc: station.Node(
        max_current=0.8 * n * (station.DC_MAX_CURRENT if dc else station.AC_MAX_CURRENT),
        efficiency=0.99,
        children=[(station.dc_evse() if dc else station.ac_evse()) for _ in range(n)],
    )
    left = station.Node(max_current=900.0, efficiency=0.985, children=[grp(4, True), grp(4, True)])
    right = station.Node(max_current=120.0, efficiency=0.985, children=[grp(6, False), grp(2, False)])
    root = station.Node(max_current=950.0, efficiency=0.98, children=[left, right])
    layout = station.flatten_tree(root, station.BatteryConfig(enabled=True, capacity_kwh=600.0))
    print(f"custom station: {layout.n_evse} EVSEs, {layout.n_nodes} constraint nodes")

    # register it and build the env around it
    station.ARCHITECTURES["custom_demo"] = lambda **kw: layout
    env = ChargaxEnv(EnvConfig(architecture="custom_demo", scenario="highway",
                               traffic="high", price_region="DE"))

    # --- custom reward: profit + rejection and satisfaction penalties -------
    params = env.make_params(
        weights=RewardWeights(satisfaction_time=2.0, rejected=5.0, degradation=0.05)
    )

    # --- evaluate the price-threshold heuristic ------------------------------
    res = evaluate(env, price_threshold_policy(env), None, jax.random.key(0),
                   num_episodes=16, env_params=params)
    for k, v in sorted(res.items()):
        print(f"  {k:>24}: {v:,.2f}")


if __name__ == "__main__":
    main()
