"""Fleet + scenario quickstart: declarative worlds, heterogeneous stations,
one vmapped 24h rollout, and PPO trained across a scenario distribution.

    PYTHONPATH=src python examples/fleet_rollout.py
"""
import jax
import jax.numpy as jnp

from repro import scenarios
from repro.core import ChargaxEnv, EnvConfig, FleetEnv
from repro.envs import FleetAdapter


def main():
    # --- 1. the scenario catalog --------------------------------------------
    print("bundled scenarios:")
    for name in scenarios.names():
        print(f"  {name:28s} {scenarios.make(name).description}")

    # --- 2. a heterogeneous fleet: 3 architectures, 3 worlds ----------------
    fleet = FleetEnv(
        ["paper_16", "deep_4x4", "single_dc_8"],  # 16/16/8 lanes
        EnvConfig(),
        scenarios=["shopping_pv_tou", "work_solar_summer", "highway_demand_charge"],
    )
    # FleetAdapter presents the fleet through the Environment protocol:
    # typed (S, ...) spaces + TimeStep returns
    env = FleetAdapter(fleet)
    params = env.default_params
    print(
        f"\nfleet: {fleet.n_stations} stations padded to "
        f"{fleet.max_evse} lanes / {fleet.max_nodes} nodes each; "
        f"action_space: {env.action_space}"
    )

    # --- 3. a jitted 24h rollout in a single vmapped scan -------------------
    steps = fleet.config.episode_steps

    @jax.jit
    def rollout(key):
        _, state = env.reset(key, params)

        def body(carry, _):
            key, state = carry
            key, ka, ks = jax.random.split(key, 3)
            ts = env.step(ks, state, env.sample_action(ka), params)
            return (key, ts.state), (ts.reward, ts.info["e_pv"])

        (_, state), (rewards, e_pv) = jax.lax.scan(body, (key, state), None, steps)
        return state, rewards, e_pv

    state, rewards, e_pv = rollout(jax.random.key(0))
    for i in range(fleet.n_stations):
        print(
            f"  station {i} ({fleet.architectures[i]:12s} "
            f"/ {fleet.scenarios[i]:22s}): "
            f"{int(state.cars_served[i]):3d} cars, "
            f"profit EUR {float(state.profit_cum[i]):8.2f}, "
            f"PV {float(e_pv[:, i].sum()):6.1f} kWh"
        )
    print(f"  fleet daily reward: {float(rewards.sum()):.1f}")

    # --- 4. PPO across a scenario distribution (distribution-shift robust) --
    from repro.rl import PPOConfig, make_train

    env = ChargaxEnv(EnvConfig())
    names = scenarios.names()
    stacked = scenarios.stack_params(
        [scenarios.make(n).make_params(env) for n in names]
    )
    # one env per scenario: num_envs must be a multiple of the catalog size
    cfg = PPOConfig(total_timesteps=40_000, num_envs=len(names),
                    rollout_steps=100, hidden=(64, 64))
    print(f"\ntraining PPO over {len(scenarios.names())} scenarios ...")
    out = jax.jit(make_train(cfg, env, scenario_params=stacked))(jax.random.key(1))
    rr = out["metrics"]["rollout_reward"]
    print(f"rollout reward: {float(rr[0]):.0f} -> {float(rr[-1]):.0f}")


if __name__ == "__main__":
    main()
