"""Quickstart: build Chargax, step it, train a small PPO agent, compare to
the paper's baseline.  Runs in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import ChargaxEnv, EnvConfig
from repro.rl.baselines import make_baseline_max_action
from repro.rl import PPOConfig, evaluate, make_ppo_policy, make_train
from repro.rl.baselines import max_charge_policy


def main():
    # --- 1. the environment (paper Table 1 bundled scenario) ---------------
    env = ChargaxEnv(
        EnvConfig(scenario="shopping", traffic="medium", price_region="NL",
                  price_year=2021, car_region="EU", architecture="paper_16")
    )
    key = jax.random.key(0)
    obs, state = env.reset(key)
    # typed spaces are the env's shape contract (repro.envs.spaces)
    print(f"observation_space: {env.observation_space}, "
          f"action_space: {env.action_space}")

    # --- 2. step it with the paper's max-charge baseline --------------------
    step = jax.jit(env.step)
    baseline = make_baseline_max_action(env)  # policy(params, key, obs)
    for t in range(12):  # one hour
        key, k = jax.random.split(key)
        obs, state, reward, done, info = step(k, state, baseline(None, k, obs))
    print(f"after 1h: {int(state.cars_served)} cars, "
          f"profit so far EUR {float(state.profit_cum):.2f}")

    # --- 3. train PPO briefly ------------------------------------------------
    cfg = PPOConfig(total_timesteps=150_000, num_envs=8, rollout_steps=150,
                    hidden=(64, 64))
    print(f"training PPO for {cfg.total_timesteps:,} env steps ...")
    train = jax.jit(make_train(cfg, env))
    out = train(jax.random.key(1))
    rr = out["metrics"]["rollout_reward"]
    print(f"rollout reward: {float(rr[0]):.0f} -> {float(rr[-1]):.0f}")

    # --- 4. evaluate against the baseline ------------------------------------
    ppo = evaluate(env, make_ppo_policy(env), out["runner_state"].params,
                   jax.random.key(2), 16)
    base = evaluate(env, max_charge_policy(env), None, jax.random.key(2), 16)
    print(f"PPO      daily profit EUR {ppo['daily_profit']:.0f}, "
          f"missing {ppo['missing_kwh']:.0f} kWh")
    print(f"baseline daily profit EUR {base['daily_profit']:.0f}, "
          f"missing {base['missing_kwh']:.0f} kWh")


if __name__ == "__main__":
    main()
