"""End-to-end driver: pretrain a ~100M-param LM for a few hundred steps with
the full production path — sharded train step, grad accumulation, AdamW,
checkpoint/resume, deterministic data (assignment deliverable (b)).

    PYTHONPATH=src python examples/lm_pretrain.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax

from repro.configs.registry import build_model, get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.train_step import TrainStepConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()

    # a ~100M tinyllama-family config (12L x 768)
    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b"),
        name="tinyllama-100m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_ff=2048,
        vocab=32000,
        param_dtype="float32",
        compute_dtype="float32",
    )
    model = build_model(cfg)
    n_params = sum(
        x.size for x in jax.tree_util.tree_leaves(jax.eval_shape(model.init, jax.random.key(0)))
    )
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params")

    ts_cfg = TrainStepConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps,
                             num_microbatches=2)
    state = init_train_state(model, jax.random.key(0), ts_cfg)
    step_fn = jax.jit(make_train_step(model, ts_cfg), donate_argnums=(0,))
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq_len))
    mgr = CheckpointManager("checkpoints/lm_pretrain", keep=2)

    losses = []
    t0 = time.perf_counter()
    for step in range(args.steps):
        state, metrics = step_fn(state, data.batch(step))
        if step % 20 == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            tok_s = args.batch * args.seq_len * (step + 1) / (time.perf_counter() - t0)
            print(f"step {step:4d}  loss {loss:.4f}  ({tok_s:,.0f} tok/s)")
    mgr.save(args.steps, state, extras={"step": args.steps})
    assert losses[-1] < losses[0], "loss should decrease"
    print(f"done: {losses[0]:.3f} -> {losses[-1]:.3f}; checkpoint saved")


if __name__ == "__main__":
    main()
