"""Paper Figure 5 in miniature: train on one price year, evaluate on another.

Demonstrates the exogenous-state plug-in point: the SAME jitted agent and env
run against any price series without recompilation (params, not config).

    PYTHONPATH=src python examples/distribution_shift.py
"""
import jax

from repro.core import ChargaxEnv, EnvConfig
from repro.rl import PPOConfig, evaluate, make_ppo_policy, make_train


def main():
    env = ChargaxEnv(EnvConfig(scenario="shopping", traffic="medium"))
    params_by_year = {y: env.make_params(price_year=y) for y in (2021, 2022, 2023)}

    print("training on 2021 prices ...")
    cfg = PPOConfig(total_timesteps=150_000, num_envs=8, rollout_steps=150, hidden=(64, 64))
    train = jax.jit(make_train(cfg, env, env_params=params_by_year[2021]))
    out = train(jax.random.key(0))
    pol = make_ppo_policy(env)

    print(f"{'eval year':>10} {'reward':>10} {'profit':>10}")
    for year, p in params_by_year.items():
        res = evaluate(env, pol, out["runner_state"].params, jax.random.key(1),
                       16, env_params=p)
        print(f"{year:>10} {res['episode_reward']:>10.0f} {res['daily_profit']:>10.0f}")
    print("(2022 = synthetic energy-crisis regime; expect a shifted payoff)")


if __name__ == "__main__":
    main()
