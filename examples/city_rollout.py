"""City-scale demand: one population arrival stream split across a fleet,
plus the station-placement sweep and the serving-shaped inference path.

Three rungs of the "millions of users" ladder in one script:

1. couple a heterogeneous ``FleetEnv`` to a ``CityParams`` city — drivers
   choose stations via the gravity/queue model, rejected demand shows up as
   ``city/overflow``;
2. score candidate station layouts with one vmapped sweep
   (``city.sweep_layouts``);
3. serve a large concurrent observation batch through the jitted
   batched-policy step (``rl.serve``), the control-plane access pattern.

    PYTHONPATH=src python examples/city_rollout.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.city import make_city, sweep_layouts
from repro.core import ChargaxEnv, EnvConfig, FleetEnv
from repro.rl import make_ppo_policy, networks, serve
from repro.rl.baselines import max_charge_policy

ARCHS = ["paper_16", "deep_4x4", "single_dc_8", "paper_16"]


def main():
    # --- 1. a city-coupled fleet --------------------------------------------
    # the scenario's city_* axis sets population/layout/choice weights; the
    # fleet splits the population stream across its stations every step
    fleet = FleetEnv(ARCHS, EnvConfig(), city="city_ring_evening")
    params = fleet.default_params
    step = jax.jit(fleet.step)
    _, state = fleet.reset(jax.random.key(0), params)
    served0 = float(np.sum(np.asarray(state.cars_served)))
    overflow = 0.0
    for i in range(fleet.config.episode_steps):
        a = fleet.sample_action(jax.random.key(1000 + i))
        _, state, r, _, info = step(jax.random.key(i), state, a, params)
        overflow += float(np.asarray(info["city/overflow"])[0])
    print(f"city-coupled fleet ({fleet.n_stations} stations, "
          f"pop {float(fleet.city.population):.0f}/day):")
    print(f"  cars served over 24h : {np.sum(np.asarray(state.cars_served)) - served0:.0f}")
    print(f"  balked (overflow)    : {overflow:.1f} expected drivers")
    print(f"  fleet profit         : {np.sum(np.asarray(state.profit_cum)):.2f} EUR")

    # --- 2. placement sweep: score layouts as one compiled vmap -------------
    cities = [
        make_city("city_ring_evening", n_stations=len(ARCHS), layout=kind)
        for kind in ("ring", "grid", "clustered")
    ]
    out = sweep_layouts(fleet, cities, max_charge_policy(fleet.template))
    for kind, p, o in zip(("ring", "grid", "clustered"),
                          np.asarray(out["profit"]), np.asarray(out["overflow"])):
        print(f"  layout {kind:>9}: profit {p:8.2f} EUR  overflow {o:7.1f}")
    print(f"  best layout: {('ring', 'grid', 'clustered')[int(out['best'])]}")

    # --- 3. serving-shaped inference ----------------------------------------
    env = ChargaxEnv(EnvConfig())
    policy = make_ppo_policy(env, greedy=True)
    pparams = networks.init_actor_critic(
        jax.random.key(7), env.obs_dim,
        env.action_space.shape[-1], env.action_space.num_categories,
    )
    batch = 131_072  # O(1e5) concurrent station observations, one device step
    obs = jax.random.normal(jax.random.key(1), (batch, env.obs_dim), jnp.float32)
    jax.block_until_ready(serve(policy, pparams, obs))  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(serve(policy, pparams, obs))
    dt = time.perf_counter() - t0
    print(f"serve: {batch:,} obs in {dt*1e3:.0f} ms "
          f"({batch/dt:,.0f} obs/s; see BENCH_serve.json for the full table)")


if __name__ == "__main__":
    main()
