"""Regenerate the vendored real-data sample extracts under
``src/repro/data/ingest/fixtures/``.

The container this repo grows in has no network access, so the fixtures are
*format-faithful synthetic extracts*: byte-layout, timestamps, DST artefacts,
units and gaps all match what the real ENTSO-E transparency-platform CSV
export and the PVGIS ``seriescalc`` API return, but the numbers are generated
from seeded models (documented in ``docs/data_provenance.md``, which also
tells you how to fetch the real thing).  Everything here is deterministic:
re-running this script reproduces the vendored files bit-for-bit.

    python tools/make_real_fixtures.py        # writes + prints sizes

Deliberate warts baked into the extracts (the ingest layer must survive them):

* ``entsoe_nl_2024.csv.xz`` — local-clock CET/CEST MTUs for the whole of
  2024 (a leap year): the spring-forward day 31.03.2024 is missing its
  02:00-03:00 row (23 rows), the fall-back day 27.10.2024 has 02:00-03:00
  twice (25 rows), a handful of prices are ``N/A`` (platform outages) and a
  few summer midday prices are negative (real feature of NL 2024).
* ``pvgis_nl_delft.csv.xz`` / ``pvgis_es_seville.json.xz`` — hourly 2023 in
  the two PVGIS output formats (CSV with header/footer prose, JSON), UTC
  timestamps with PVGIS's ``:11`` minute marker, power in W for a 10 kWp
  system.
"""
from __future__ import annotations

import datetime as dt
import json
import lzma
import os
import sys

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(HERE, "..", "src"))

from repro.data.ingest import FIXTURE_DIR as FIXDIR  # noqa: E402 (one budget source)
from repro.data.ingest import check_fixture_budget  # noqa: E402

# Europe/Amsterdam + Europe/Madrid 2024/2023 DST transitions (last Sundays)
DST_START_2024 = dt.date(2024, 3, 31)  # 02:00 -> 03:00 (23-hour day)
DST_END_2024 = dt.date(2024, 10, 27)  # 03:00 -> 02:00 (25-hour day)


def _xz_write(path: str, data: bytes) -> int:
    # lzma is deterministic for fixed input + preset, so regeneration is
    # bit-for-bit; it also beats gzip ~2x on these column-repetitive files,
    # which is what keeps the whole vendored set under the 100 KB budget
    payload = lzma.compress(data, preset=9 | lzma.PRESET_EXTREME)
    with open(path, "wb") as f:
        f.write(payload)
    return len(payload)


# ---------------------------------------------------------------------------
# ENTSO-E day-ahead prices, NL bidding zone, calendar year 2024
# ---------------------------------------------------------------------------
def entsoe_nl_2024() -> bytes:
    rng = np.random.default_rng(202_4)
    days = [dt.date(2024, 1, 1) + dt.timedelta(days=d) for d in range(366)]
    doy = np.arange(366)
    h = np.arange(24)

    # daily shape: morning + evening peaks, midday solar depression
    shape = (
        1.0
        + 0.35 * np.exp(-0.5 * ((h - 8.0) / 1.7) ** 2)
        + 0.55 * np.exp(-0.5 * ((h - 19.0) / 2.1) ** 2)
        - 0.50 * np.exp(-0.5 * ((h - 13.5) / 2.3) ** 2)
    )
    season = 1.0 + 0.25 * np.cos(2 * np.pi * (doy - 20) / 366)  # winter high
    # midday solar depression deepens in summer (can push prices negative)
    solar_season = 0.5 + 0.5 * np.cos(2 * np.pi * (doy - 200) / 366)
    walk = np.cumsum(rng.normal(0.0, 4.0, 366))
    walk -= np.linspace(walk[0], walk[-1], 366)
    spikes = 60.0 * rng.gamma(1.4, 1.0, 366) * (rng.random(366) < 0.04)

    lines = [
        '"MTU (CET/CEST)","Day-ahead Price [EUR/MWh]","Currency","BZN|NL"'
    ]
    n_gaps = 0
    for d, date in enumerate(days):
        base = 72.0 * season[d] + walk[d] + spikes[d]
        weekend = date.weekday() >= 5
        hours = list(range(24))
        if date == DST_START_2024:
            hours.remove(2)  # 02:00-03:00 never happens on the clock
        elif date == DST_END_2024:
            hours = hours[:3] + [2] + hours[3:]  # 02:00-03:00 runs twice
        for hh in hours:
            midday_pull = 55.0 * (1.0 - solar_season[d]) * np.exp(
                -0.5 * ((hh - 13.5) / 2.3) ** 2
            )
            price = base * shape[hh] - midday_pull + rng.normal(0.0, 3.0)
            if weekend:
                price *= 0.88
            start = f"{date:%d.%m.%Y} {hh:02d}:00"
            end_date = date if hh < 23 else date + dt.timedelta(days=1)
            end = f"{end_date:%d.%m.%Y} {(hh + 1) % 24:02d}:00"
            # sprinkle platform outages (never on the DST days: those rows
            # exercise the clock logic and should carry real numbers)
            if rng.random() < 0.0008 and date not in (DST_START_2024, DST_END_2024):
                cell = "N/A"
                n_gaps += 1
            else:
                cell = f"{price:.2f}"
            lines.append(f'"{start} - {end}","{cell}","EUR","NL"')
    assert n_gaps >= 3, "want a few N/A gaps in the vendored extract"
    return ("\n".join(lines) + "\n").encode()


# ---------------------------------------------------------------------------
# PVGIS hourly PV output (seriescalc), 10 kWp, year 2023, UTC timestamps
# ---------------------------------------------------------------------------
def _pv_series(lat: float, seed: int) -> np.ndarray:
    """(365, 24) hourly mean power in W for a 10 kWp system, UTC clock."""
    rng = np.random.default_rng(seed)
    doy = np.arange(365)
    decl = -23.44 * np.cos(2 * np.pi * (doy + 10) / 365.0)
    lat_r, decl_r = np.radians(lat), np.radians(decl)
    h = (np.arange(24) + 0.5) * 15.0 - 180.0  # solar hour angle at UTC hours
    cos_z = (
        np.sin(lat_r) * np.sin(decl_r)[:, None]
        + np.cos(lat_r) * np.cos(decl_r)[:, None] * np.cos(np.radians(h))[None, :]
    )
    elev = np.maximum(cos_z, 0.0)
    # AR(1) daily cloud cover
    x = rng.beta(1.6, 1.2, 365)
    cloud = np.empty(365)
    c = 0.7
    for d in range(365):
        c = 0.65 * c + 0.35 * x[d]
        cloud[d] = c
    p = 10_000.0 * 0.93 * elev ** 1.15 * cloud[:, None]
    p *= 1.0 + rng.normal(0.0, 0.03, p.shape) * (p > 0)
    return np.maximum(p, 0.0)


def pvgis_csv_delft() -> bytes:
    p = _pv_series(lat=52.0, seed=31)
    lines = [
        "Latitude (decimal degrees):\t52.000",
        "Longitude (decimal degrees):\t4.374",
        "Elevation (m):\t3",
        "Radiation database:\tPVGIS-SARAH2",
        "Nominal power of the PV system (c-Si) (kWp):\t10.0",
        "System losses (%):\t7.0",
        "",
        "time,P,G(i)",
    ]
    date = dt.date(2023, 1, 1)
    for d in range(365):
        for hh in range(24):
            watts = p[d, hh]
            gi = watts / (10_000.0 * 0.93) * 1000.0  # back out irradiance-ish
            lines.append(
                f"{date:%Y%m%d}:{hh:02d}11,{watts:.0f},{gi:.0f}"
            )
        date += dt.timedelta(days=1)
    lines += [
        "",
        "P: PV system power (W)",
        "G(i): Global irradiance on the inclined plane (plane of the array) (W/m2)",
        "",
        "PVGIS (c) European Union, 2001-2024",
    ]
    return ("\n".join(lines) + "\n").encode()


def pvgis_json_seville() -> bytes:
    p = _pv_series(lat=37.4, seed=37)
    hourly = []
    date = dt.date(2023, 1, 1)
    for d in range(365):
        for hh in range(24):
            hourly.append(
                {"time": f"{date:%Y%m%d}:{hh:02d}11", "P": round(float(p[d, hh]))}
            )
        date += dt.timedelta(days=1)
    doc = {
        "inputs": {
            "location": {"latitude": 37.4, "longitude": -5.98, "elevation": 11.0},
            "pv_module": {"technology": "c-Si", "peak_power": 10.0, "system_loss": 7.0},
        },
        "outputs": {"hourly": hourly},
        "meta": {
            "outputs": {
                "hourly": {
                    "variables": {"P": {"description": "PV system power", "units": "W"}}
                }
            }
        },
    }
    return json.dumps(doc, separators=(",", ":")).encode()


def main() -> None:
    os.makedirs(FIXDIR, exist_ok=True)
    out = {
        "entsoe_nl_2024.csv.xz": entsoe_nl_2024(),
        "pvgis_nl_delft.csv.xz": pvgis_csv_delft(),
        "pvgis_es_seville.json.xz": pvgis_json_seville(),
    }
    for name, data in out.items():
        size = _xz_write(os.path.join(FIXDIR, name), data)
        print(f"{name}: {len(data):,} raw -> {size:,} xz")
    check_fixture_budget(verbose=True)


if __name__ == "__main__":
    main()
