#!/usr/bin/env python
"""Regenerate the fused-step golden fixtures (tests/kernels/goldens/*.npz).

Run after an INTENDED physics change, commit the updated .npz files, and say
why in the commit message — the goldens exist so unintended physics drift
fails loudly in `tests/kernels/test_goldens.py`:

    PYTHONPATH=src python tools/make_kernel_goldens.py

Each golden is a deterministic short rollout (fixed keys, max-charge action)
of the fused hot path on one canonical scenario — see
``tests/kernels/harness.compute_golden`` for the exact recipe.
"""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, os.path.join(REPO, "tests", "kernels"))

import numpy as np  # noqa: E402

import harness  # noqa: E402


def main() -> None:
    out_dir = os.path.join(REPO, "tests", "kernels", "goldens")
    os.makedirs(out_dir, exist_ok=True)
    for name in harness.GOLDEN_SCENARIOS:
        data = harness.compute_golden(name)
        path = os.path.join(out_dir, f"{name}.npz")
        np.savez_compressed(path, **data)
        print(
            f"{path}: {os.path.getsize(path)} bytes | "
            + " ".join(f"{k}={v.shape}" for k, v in data.items())
        )


if __name__ == "__main__":
    main()
