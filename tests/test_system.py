"""End-to-end behaviour tests for the paper's system (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np


def test_chargax_full_day_episode():
    """The paper's headline loop: a 24h episode of the 16-charger station."""
    from repro.core import ChargaxEnv, EnvConfig
    from repro.rl.baselines import make_baseline_max_action

    env = ChargaxEnv(EnvConfig(scenario="shopping", traffic="medium"))
    key = jax.random.key(0)
    obs, state = env.reset(key)
    step = jax.jit(env.step)
    baseline = make_baseline_max_action(env)  # policy(params, key, obs)
    done = False
    for _ in range(env.config.episode_steps):
        key, k = jax.random.split(key)
        obs, state, reward, done, info = step(k, state, baseline(None, k, obs))
    assert bool(done)
    assert float(state.cars_served) > 20  # a busy day actually happened
    assert float(state.energy_delivered) > 100.0
    assert bool(jnp.isfinite(state.profit_cum))


def test_rl_to_eval_pipeline():
    """PPO trains on the env and the trained policy evaluates end-to-end."""
    from repro.core import ChargaxEnv, EnvConfig
    from repro.rl import PPOConfig, evaluate, make_ppo_policy, make_train

    env = ChargaxEnv(EnvConfig(traffic="low"))
    cfg = PPOConfig(total_timesteps=30_000, num_envs=4, rollout_steps=125, hidden=(32,))
    out = jax.jit(make_train(cfg, env))(jax.random.key(0))
    rr = np.asarray(out["metrics"]["rollout_reward"])
    assert np.isfinite(rr).all()
    res = evaluate(env, make_ppo_policy(env), out["runner_state"].params, jax.random.key(1), 4)
    assert np.isfinite(res["episode_reward"])


def test_lm_train_then_serve():
    """Model zoo end-to-end: train a smoke LM a few steps, then decode."""
    from repro.configs.registry import build_model, get_config
    from repro.data.pipeline import DataConfig, SyntheticTokens
    from repro.distributed.train_step import TrainStepConfig, init_train_state, make_train_step
    from repro.launch.serve import generate

    cfg = get_config("tinyllama-1.1b", smoke=True)
    model = build_model(cfg)
    ts = TrainStepConfig(lr=1e-3, total_steps=10)
    state = init_train_state(model, jax.random.key(0), ts)
    step = jax.jit(make_train_step(model, ts))
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, batch=4, seq_len=32))
    l0 = l1 = None
    for i in range(10):
        state, m = step(state, data.batch(i))
        l0 = float(m["loss"]) if l0 is None else l0
        l1 = float(m["loss"])
    assert l1 < l0
    prompts = jax.random.randint(jax.random.key(2), (2, 8), 0, cfg.vocab)
    seqs = generate(model, state.params, prompts, max_new_tokens=4)
    assert seqs.shape == (2, 12)
