"""Recompile sentinel: clean regions pass, injected recompiles are caught
with the offending function name + avals in the error."""
import jax
import jax.numpy as jnp
import pytest

from repro.obs import RecompileError, cache_entries, compile_guard
from repro.obs.guard import assert_one_compiled_step

jax.config.update("jax_platform_name", "cpu")


def test_clean_region_passes():
    @jax.jit
    def f(x):
        return x * 2.0

    # inputs built OUTSIDE the guard: jnp.full/ones are themselves jitted
    # helpers whose first-use compiles the sentinel would (correctly) flag
    xs = [jnp.full(8, float(i)) for i in range(3)]
    f(xs[0])  # warm-up compile outside the guard
    with compile_guard("cached calls") as g:
        for x in xs:
            f(x)
    assert g.count == 0


def test_shape_polymorphic_recompile_is_caught():
    @jax.jit
    def poly(x):
        return x.sum()

    x4, x5 = jnp.ones(4), jnp.ones(5)
    poly(x4)
    with pytest.raises(RecompileError) as ei:
        with compile_guard("shape leak"):
            poly(x5)  # new shape -> new cache entry
    msg = str(ei.value)
    assert "shape leak" in msg
    assert "poly" in msg  # offending function is named
    assert "float32[5]" in msg  # ...with the triggering avals
    assert len(ei.value.events) == 1


def test_allowance_and_collect_only_modes():
    @jax.jit
    def g(x):
        return x + 1

    x = jnp.ones(3)
    with compile_guard("first call may compile", max_compiles=1):
        g(x)

    @jax.jit
    def h(x):
        return x - 1

    with compile_guard("collect", raise_on_violation=False) as guard:
        h(x)
    assert guard.count == 1
    assert guard.events[0].avals  # avals captured for diagnostics


def test_allow_filter_ignores_named_functions():
    @jax.jit
    def ignored_helper(x):
        return x * 3

    x = jnp.ones(2)
    with compile_guard("allow-list", allow=("ignored_helper",)) as g:
        ignored_helper(x)
    assert g.count == 0


def test_cache_entries_counts_jit_cache():
    @jax.jit
    def f(x):
        return x * x

    f(jnp.ones(2))
    f(jnp.ones(2))
    assert cache_entries(f) == 1
    f(jnp.ones(3))
    assert cache_entries(f) == 2
    with pytest.raises(TypeError):
        cache_entries(lambda x: x)  # not a jitted callable


def test_assert_one_compiled_step_over_scenarios():
    from repro import scenarios
    from repro.core import ChargaxEnv, EnvConfig

    env = ChargaxEnv(EnvConfig(episode_hours=1.0))
    params = [
        scenarios.make(n).make_params(env)
        for n in ("shopping_flat", "shopping_pv_tou", "highway_demand_charge")
    ]
    assert assert_one_compiled_step(env, params) == 3


def test_assert_one_compiled_step_rejects_shape_change():
    from repro.core import ChargaxEnv, EnvConfig

    import dataclasses

    env = ChargaxEnv(EnvConfig(episode_hours=1.0))
    good = env.default_params
    # inject a shape-polymorphic params pytree: the price table with twice
    # the days — traces fine (the day axis is indexed dynamically) but is a
    # different static signature, so the swap MUST recompile
    bad = dataclasses.replace(
        good,
        price_buy_table=jnp.concatenate(
            [good.price_buy_table, good.price_buy_table], axis=0
        ),
    )
    with pytest.raises(RecompileError):
        assert_one_compiled_step(env, [good, bad])
