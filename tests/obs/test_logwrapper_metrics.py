"""LogWrapper episode accounting + KPI accumulation across AutoReset
boundaries: the scan path matches a host Python loop bit-for-bit."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ChargaxEnv, EnvConfig
from repro.envs import AutoReset, LogWrapper, VmapWrapper

jax.config.update("jax_platform_name", "cpu")

N_ENVS = 3
METRICS = ("reward", "profit", "energy_delivered", "missing_kwh")


def _stack():
    env = ChargaxEnv(EnvConfig(episode_hours=1.0))  # 12 steps per episode
    wenv = LogWrapper(AutoReset(VmapWrapper(env, N_ENVS)), metrics=METRICS)
    return env, wenv


def test_episode_accounting_across_autoreset_boundaries():
    env, wenv = _stack()
    params = env.default_params
    ep_steps = env.config.episode_steps
    t_total = 2 * ep_steps + 5  # crosses two episode boundaries

    obs, state = wenv.reset(jax.random.key(0), params)
    step = jax.jit(wenv.step)
    action = wenv.sample_action(jax.random.key(1))
    keys = jax.random.split(jax.random.key(2), t_total)

    ep_ret = np.zeros(N_ENVS, np.float32)  # sequential float32 reference
    ep_len = 0
    boundaries = 0
    for t in range(t_total):
        ts = step(keys[t], state, action, params)
        state = ts.state
        ep_ret = (ep_ret + np.asarray(ts.reward)).astype(np.float32)
        ep_len += 1
        if bool(np.all(np.asarray(ts.done))):
            boundaries += 1
            assert ep_len == ep_steps
            # the finishing episode's totals are surfaced, bit-for-bit
            assert np.asarray(ts.info["returned_episode"]).all()
            assert (
                np.asarray(ts.info["episode_return"]).tobytes() == ep_ret.tobytes()
            )
            assert (np.asarray(ts.info["episode_length"]) == ep_steps).all()
            # running totals restart with the fresh episode
            assert (np.asarray(state.episode_return) == 0.0).all()
            assert (np.asarray(state.episode_length) == 0).all()
            ep_ret = np.zeros(N_ENVS, np.float32)
            ep_len = 0
        else:
            assert not np.asarray(ts.done).any()
            # between boundaries the returned totals stay frozen
            assert (
                np.asarray(ts.info["episode_length"])
                == (ep_steps if boundaries else 0)
            ).all()
    assert boundaries == 2


def test_metrics_accumulate_through_resets_and_match_python_loop():
    env, wenv = _stack()
    params = env.default_params
    t_total = env.config.episode_steps + 7  # crosses one boundary

    obs, state0 = wenv.reset(jax.random.key(0), params)
    action = wenv.sample_action(jax.random.key(1))
    keys = jax.random.split(jax.random.key(2), t_total)
    step = jax.jit(wenv.step)

    # host loop reference: sequential float32 accumulation of info scalars
    state = state0
    ref = {n: np.zeros(N_ENVS, np.float32) for n in METRICS}
    for t in range(t_total):
        ts = step(keys[t], state, action, params)
        state = ts.state
        ref["reward"] = (ref["reward"] + np.asarray(ts.reward)).astype(np.float32)
        for n in METRICS[1:]:
            ref[n] = (ref[n] + np.asarray(ts.info[n])).astype(np.float32)
    loop_acc = state.metrics

    # same steps as ONE jitted rollout scan; emit the per-step values the
    # scan itself computed (XLA may fuse the env math differently inside a
    # scan than in a per-step jit, shifting rewards by 1 ulp — the claim
    # under test is that ACCUMULATION is bit-exact, not that fusion is)
    @jax.jit
    def rollout(state):
        def body(carry, key):
            ts = wenv.step(key, carry, action, params)
            return ts.state, {"reward": ts.reward, **{n: ts.info[n] for n in METRICS[1:]}}

        return jax.lax.scan(body, state, keys)

    scan_final, per_step = rollout(state0)
    scan_acc = scan_final.metrics
    scan_ref = {n: np.zeros(N_ENVS, np.float32) for n in METRICS}
    for t in range(t_total):
        for n in METRICS:
            scan_ref[n] = (
                scan_ref[n] + np.asarray(per_step[n])[t]
            ).astype(np.float32)

    assert float(loop_acc.count.min()) == t_total  # reset did NOT clear KPIs
    for n in METRICS:
        got_loop = np.asarray(loop_acc.sums[n])
        got_scan = np.asarray(scan_acc.sums[n])
        assert got_loop.tobytes() == ref[n].tobytes(), n
        # in-scan accumulator == host float32 loop over the scan's own values
        assert got_scan.tobytes() == scan_ref[n].tobytes(), n
        assert np.allclose(got_scan, ref[n], rtol=1e-5), n

    out = scan_acc.flush(means=("reward",))
    assert out["steps"] == t_total
    assert np.isfinite(out["reward_per_step"])
    assert out["energy_delivered"] >= 0.0


def test_metrics_default_off_keeps_state_lean():
    env = ChargaxEnv(EnvConfig(episode_hours=1.0))
    wenv = LogWrapper(AutoReset(VmapWrapper(env, 2)))
    obs, state = wenv.reset(jax.random.key(0), env.default_params)
    assert state.metrics is None
    ts = wenv.step(
        jax.random.key(1), state, wenv.sample_action(jax.random.key(2)),
        env.default_params,
    )
    assert ts.state.metrics is None
