"""Unified sinks: JSONL round-trip, manifest provenance, BENCH JSON schema."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (
    SCHEMA_VERSION,
    MetricsWriter,
    emit_json_line,
    run_manifest,
    write_benchmark_json,
)
from repro.obs.sinks import read_jsonl, to_jsonable


def test_run_manifest_provenance_fields():
    m = run_manifest(run="test", extra_field=7)
    for key in (
        "schema_version",
        "git_sha",
        "jax_version",
        "backend",
        "device_count",
        "process_count",
        "unix_time",
    ):
        assert key in m, key
    assert m["schema_version"] == SCHEMA_VERSION
    assert m["run"] == "test" and m["extra_field"] == 7
    assert len(m["git_sha"]) == 40  # a real sha inside the checkout


def test_metrics_writer_jsonl_round_trip(tmp_path):
    path = str(tmp_path / "nested" / "metrics.jsonl")  # dirs auto-created
    with MetricsWriter(path, run="unit") as w:
        w.write({"profit": jnp.float32(1.5), "arr": np.arange(3)})
        w.write({"tag": "x"}, kind="eval")
    records = read_jsonl(path)
    assert [r["kind"] for r in records] == ["manifest", "metrics", "eval"]
    assert records[0]["run"] == "unit"
    assert records[1]["profit"] == 1.5  # jax scalar -> plain float
    assert records[1]["arr"] == [0, 1, 2]  # numpy array -> list
    assert all(r["schema_version"] == SCHEMA_VERSION for r in records)


def test_metrics_writer_rejects_writes_after_close(tmp_path):
    w = MetricsWriter(str(tmp_path / "m.jsonl"))
    w.close()
    with pytest.raises(ValueError):
        w.write({"x": 1})


def test_write_benchmark_json_schema(tmp_path):
    rows = [("row_a", 1.23456, "10 steps/s"), ("row_b", np.float64(2.0), "")]
    path = write_benchmark_json(
        "unit",
        rows,
        summary={"steps_per_sec": 10.0, "benchmark": "liar"},  # provenance wins
        quick=True,
        root=str(tmp_path),
    )
    assert path.endswith("BENCH_unit.json")
    rec = json.load(open(path))
    assert rec["schema_version"] == SCHEMA_VERSION
    assert rec["benchmark"] == "unit"  # manifest overrode the summary key
    assert rec["steps_per_sec"] == 10.0  # summary fields stay top-level
    assert rec["quick"] is True
    assert rec["rows"][0] == {
        "name": "row_a",
        "us_per_call": 1.235,
        "derived": "10 steps/s",
    }


def test_write_benchmark_json_warns_on_stale_overwrite(tmp_path, monkeypatch):
    from repro.obs import sinks

    rows = [("r", 1.0, "")]
    # first write: no pre-existing file, never warns
    with _no_warn():
        write_benchmark_json("stale", rows, root=str(tmp_path))

    # overwrite a file whose recorded sha trails HEAD by > STALE_BENCH_COMMITS
    monkeypatch.setattr(sinks, "commits_behind", lambda sha, root=None: 12)
    with pytest.warns(UserWarning, match="12 commits stale"):
        write_benchmark_json("stale", rows, root=str(tmp_path))

    # a fresh sha (0 behind) overwrites silently
    monkeypatch.setattr(sinks, "commits_behind", lambda sha, root=None: 0)
    with _no_warn():
        write_benchmark_json("stale", rows, root=str(tmp_path))


def _no_warn():
    import warnings as _warnings
    from contextlib import contextmanager

    @contextmanager
    def ctx():
        with _warnings.catch_warnings():
            _warnings.simplefilter("error", UserWarning)
            yield

    return ctx()


def test_commits_behind_on_head_and_garbage():
    from repro.obs.sinks import commits_behind, git_sha

    assert commits_behind(git_sha()) == 0
    assert commits_behind("unknown") is None
    assert commits_behind(None) is None
    assert commits_behind("not-a-sha") is None


def test_emit_json_line_is_parseable(capsys):
    line = emit_json_line("TEST_JSON", {"v": jnp.float32(3.0), "n": [1, 2]})
    printed = capsys.readouterr().out.strip()
    assert printed == line
    tag, payload = printed.split(" ", 1)
    assert tag == "TEST_JSON"
    assert json.loads(payload) == {"v": 3.0, "n": [1, 2]}


def test_to_jsonable_covers_nested_structures():
    obj = {
        "a": np.int64(3),
        "b": [np.float32(1.5), (jnp.ones(2),)],
        "c": {"d": np.bool_(True)},
    }
    out = to_jsonable(obj)
    assert out == {"a": 3, "b": [1.5, [[1.0, 1.0]]], "c": {"d": True}}
    json.dumps(out)  # fully serialisable
