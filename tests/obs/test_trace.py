"""Trace annotations: no-op by default, named scopes when enabled, and the
profiler session produces a loadable perfetto trace within budget."""
import glob
import gzip
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.obs import (
    annotate,
    check_trace_budget,
    enable_trace_annotations,
    latest_trace,
    trace_annotations_enabled,
    trace_session,
)
from repro.obs.trace import trace_bytes

jax.config.update("jax_platform_name", "cpu")


def test_annotations_disabled_by_default_and_restore():
    assert not trace_annotations_enabled()
    prev = enable_trace_annotations(True)
    assert prev is False and trace_annotations_enabled()
    enable_trace_annotations(prev)
    assert not trace_annotations_enabled()


def test_annotate_is_a_bare_noop_when_disabled():
    def make(annotated):
        def f(x):  # same __name__ both ways: jit module names match
            if annotated:
                with annotate("env/phase"):
                    return x * 2.0
            return x * 2.0

        return f

    # identical lowered program with and without the (disabled) annotation:
    # the benchmark's HLO-identity proof relies on this
    plain = jax.jit(make(False)).lower(jnp.ones(4)).as_text()
    wrapped = jax.jit(make(True)).lower(jnp.ones(4)).as_text()
    assert plain == wrapped


def test_annotate_names_ops_when_enabled():
    def f(x):
        with annotate("repro_test_phase"):
            return jnp.sin(x) + 1.0

    prev = enable_trace_annotations(True)
    try:
        # scope names live in op metadata, surfaced by the compiled HLO text
        text = jax.jit(f).lower(jnp.ones(4)).compile().as_text()
    finally:
        enable_trace_annotations(prev)
    assert "repro_test_phase" in text  # named_scope reached the IR


def test_latest_trace_and_budget_on_synthetic_files(tmp_path):
    d = tmp_path / "prof"
    (d / "sub").mkdir(parents=True)
    old = d / "sub" / "a.trace.json.gz"
    new = d / "b.trace.json.gz"
    old.write_bytes(b"x" * 100)
    new.write_bytes(b"y" * 200)
    os.utime(old, (1, 1))
    assert latest_trace(str(d)) == str(new)
    assert trace_bytes(str(d)) == 300
    assert check_trace_budget(str(d), max_kb=1) == 300
    with pytest.raises(RuntimeError):
        check_trace_budget(str(d), max_kb=0)
    assert latest_trace(str(tmp_path / "missing")) is None


@pytest.mark.slow
def test_trace_session_produces_loadable_perfetto_trace(tmp_path):
    log_dir = str(tmp_path / "prof")

    @jax.jit
    def f(x):
        with annotate("test/phase_a"):
            y = x @ x.T
        with annotate("test/phase_b"):
            return jnp.tanh(y).sum()

    with trace_session(log_dir, keep_xplane=False) as d:
        assert trace_annotations_enabled()  # session enables annotations
        out = f(jnp.ones((64, 64)))
        out.block_until_ready()
    assert not trace_annotations_enabled()  # ...and restores the toggle

    path = latest_trace(d)
    assert path is not None and path.endswith(".trace.json.gz")
    data = json.loads(gzip.open(path).read())  # loadable perfetto JSON
    assert "traceEvents" in data and len(data["traceEvents"]) > 0
    assert glob.glob(os.path.join(d, "**", "*.xplane.pb"), recursive=True) == []
    check_trace_budget(d)  # a tiny session stays within the artifact budget
