"""MetricsAccumulator: bit-for-bit vs a Python loop, under scan and vmap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import MetricsAccumulator
from repro.obs.metrics import kpi_summary, tree_find_accumulators

jax.config.update("jax_platform_name", "cpu")

NAMES = ("profit", "energy")


def _random_steps(key, t, batch=()):
    return {
        n: jax.random.normal(k, (t,) + batch) * 10.0
        for n, k in zip(NAMES, jax.random.split(key, len(NAMES)))
    }


def test_scan_matches_python_loop_bit_for_bit():
    t = 37
    vals = _random_steps(jax.random.key(0), t)

    acc0 = MetricsAccumulator.create(NAMES, max_names=("profit",))

    def body(acc, i):
        return acc.update({n: v[i] for n, v in vals.items()}), None

    scanned, _ = jax.jit(
        lambda a: jax.lax.scan(body, a, jnp.arange(t))
    )(acc0)

    looped = acc0
    for i in range(t):
        looped = looped.update({n: v[i] for n, v in vals.items()})

    for n in NAMES:
        assert np.asarray(scanned.sums[n]).tobytes() == np.asarray(
            looped.sums[n]
        ).tobytes(), n
    assert np.asarray(scanned.maxes["profit"]).tobytes() == np.asarray(
        looped.maxes["profit"]
    ).tobytes()
    assert float(scanned.count) == t


def test_vmap_lanes_match_independent_loops_bit_for_bit():
    t, b = 11, 4
    vals = _random_steps(jax.random.key(1), t, (b,))
    acc0 = MetricsAccumulator.create(NAMES, batch_shape=(b,))

    def body(acc, i):
        return acc.update({n: v[i] for n, v in vals.items()}), None

    batched, _ = jax.lax.scan(body, acc0, jnp.arange(t))

    for lane in range(b):
        solo = MetricsAccumulator.create(NAMES)
        for i in range(t):
            solo = solo.update({n: v[i, lane] for n, v in vals.items()})
        for n in NAMES:
            assert (
                np.asarray(batched.sums[n])[lane].tobytes()
                == np.asarray(solo.sums[n]).tobytes()
            ), (n, lane)


def test_update_missing_metric_is_an_error():
    acc = MetricsAccumulator.create(("profit",))
    with pytest.raises(KeyError):
        acc.update({"not_profit": jnp.float32(1.0)})


def test_merge_and_since():
    a = MetricsAccumulator.create(NAMES).update({n: jnp.float32(1.0) for n in NAMES})
    b = MetricsAccumulator.create(NAMES).update({n: jnp.float32(2.0) for n in NAMES})
    m = a.merge(b)
    assert float(m.sums["profit"]) == 3.0
    assert float(m.count) == 2.0
    with pytest.raises(ValueError):
        a.merge(MetricsAccumulator.create(("other",)))

    later = b.update({n: jnp.float32(5.0) for n in NAMES})
    delta = later.since(b)
    assert float(delta.sums["profit"]) == 5.0
    assert float(delta.count) == 1.0


def test_flush_totals_means_and_maxes():
    acc = MetricsAccumulator.create(("profit",), max_names=("peak",), batch_shape=(2,))
    acc = acc.update({"profit": jnp.array([1.0, 3.0]), "peak": jnp.array([7.0, 2.0])})
    acc = acc.update({"profit": jnp.array([1.0, 3.0]), "peak": jnp.array([4.0, 9.0])})
    out = acc.flush(means=("profit",))
    assert out["profit"] == pytest.approx(4.0)  # mean over lanes of per-lane sums
    assert out["profit_per_step"] == pytest.approx(2.0)
    assert out["peak_max"] == pytest.approx(9.0)
    assert out["steps"] == pytest.approx(2.0)

    per_lane = acc.flush(reduce_batch=False)
    assert np.allclose(per_lane["profit"], [2.0, 6.0])


def test_kpi_summary_stays_on_device():
    acc = MetricsAccumulator.create(("profit",), batch_shape=(3,))
    acc = acc.update({"profit": jnp.arange(3.0)})
    out = jax.jit(kpi_summary)(acc)  # traced — no host sync required
    assert float(out["kpi/profit"]) == pytest.approx(1.0)


def test_tree_find_accumulators():
    acc = MetricsAccumulator.create(("profit",))
    tree = {"a": [1, {"b": acc}], "c": (acc,)}
    found = tree_find_accumulators(tree)
    assert len(found) == 2 and all(isinstance(f, MetricsAccumulator) for f in found)
    assert tree_find_accumulators({"x": jnp.zeros(2)}) == []
