"""Fused Chargax station-step kernel vs oracles.

Three-way agreement is required:
  1. Pallas kernel (interpret mode) == jnp reference (`ref.fused_step_ref`)
  2. jnp reference == the core transition functions (`apply_actions` +
     `charge_cars`) on real env states — proving the fused path is the same
     MDP, not a lookalike.
Plus a hypothesis sweep asserting the Eq. 5 invariant on the kernel output.

All fixtures come from the shared parity harness (``tests/kernels/harness``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import harness
from repro.core.transition import apply_actions, charge_cars
from repro.kernels.chargax_step import ops as fused_ops
from repro.kernels.chargax_step import ref as fused_ref

ENV = harness.make_env()
PARAMS = ENV.default_params
DT = ENV.config.dt_hours
N = ENV.n_evse


def _random_state(key, n_occupied=10):
    return harness.random_state(ENV, PARAMS, key, n_occupied)


def _random_targets(key):
    return harness.random_targets(PARAMS, key)


@pytest.mark.parametrize("seed", range(5))
def test_ref_matches_core_transition(seed):
    """fused ref == apply_actions + charge_cars on the same state."""
    key = jax.random.key(seed)
    state = _random_state(key)
    t_evse, t_batt = _random_targets(jax.random.key(seed + 100))

    applied = apply_actions(PARAMS, state, t_evse, t_batt, DT)
    charged = charge_cars(PARAMS, state, applied, DT)

    out = fused_ops.fused_step(PARAMS, state, t_evse, t_batt, DT, impl="ref")

    np.testing.assert_allclose(out.current[:N], applied.evse_current, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out.current[N], applied.batt_current, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out.excess, applied.constraint_excess, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(out.soc[:N], charged.state.soc, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out.soc[N], charged.state.batt_soc, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out.e_remain[:N], charged.state.e_remain, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out.rhat[:N], charged.state.rhat, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out.e_pole[:N], charged.e_car, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out.e_pole[N], charged.e_batt_net, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("batch", [1, 64, 300])
def test_kernel_matches_ref(seed, batch):
    """Pallas (interpret) == jnp ref on batched random states."""
    keys = jax.random.split(jax.random.key(seed), batch)
    states = jax.vmap(_random_state)(keys)
    t_evse, t_batt = jax.vmap(_random_targets)(keys)

    out_k = fused_ops.fused_step(
        PARAMS, states, t_evse, t_batt, DT, impl="interpret", block_envs=64
    )
    out_r = fused_ops.fused_step(PARAMS, states, t_evse, t_batt, DT, impl="ref")
    for a, b, name in zip(out_k, out_r, fused_ref.FusedOut._fields):
        # fp32 op-ordering differs between the MXU dot and the jnp matmul
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=2e-4, err_msg=name
        )


def test_kernel_respects_grid_cap():
    """With a finite feeder cap the kernel curtails charging draw to it."""
    state = _random_state(jax.random.key(3), n_occupied=16)
    t_evse = jnp.broadcast_to(PARAMS.evse_max_current, (N,))  # max charge
    t_batt = PARAMS.batt_max_current * 1.0
    cap = jnp.float32(15.0)  # far below an unconstrained max-charge draw
    out = fused_ops.fused_step(
        PARAMS, state, t_evse, t_batt, DT, cap_kw=cap, impl="interpret", block_envs=1
    )
    pp = fused_ops.build_pole_params(PARAMS)
    drawn = jnp.sum(jnp.maximum(out.current, 0.0) * pp.power_w) / 1000.0
    assert float(out.p_req) > float(cap)  # the cap binds ...
    assert float(drawn) <= float(cap) * 1.001 + 1e-4  # ... and is respected
    # unlimited cap is a bitwise no-op vs no cap at all
    out_u = fused_ops.fused_step(
        PARAMS, state, t_evse, t_batt, DT, cap_kw=jnp.float32(fused_ref.BIG),
        impl="interpret", block_envs=1,
    )
    out_n = fused_ops.fused_step(
        PARAMS, state, t_evse, t_batt, DT, impl="interpret", block_envs=1
    )
    for a, b, name in zip(out_u, out_n, fused_ref.FusedOut._fields):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


if harness.HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_kernel_constraint_invariant(seed):
        """Eq. 5 holds on kernel outputs for arbitrary states/targets."""
        key = jax.random.key(seed)
        state = _random_state(key, n_occupied=16)
        t_evse, t_batt = _random_targets(jax.random.key(seed ^ 0x5EED))
        out = fused_ops.fused_step(
            PARAMS, state, t_evse, t_batt, DT, impl="interpret", block_envs=1,
        )
        leaf = out.current[: N + 1]
        loads = PARAMS.member @ jnp.abs(leaf)
        assert bool(jnp.all(loads <= PARAMS.node_budget * 1.0001 + 1e-4))
        assert bool(jnp.all((out.soc >= 0) & (out.soc <= 1)))


def test_fused_step_dtypes_float32_only():
    """State slabs are fp32 end-to-end (env semantics are fp32)."""
    state = _random_state(jax.random.key(9))
    t_evse, t_batt = _random_targets(jax.random.key(10))
    out = fused_ops.fused_step(PARAMS, state, t_evse, t_batt, DT, impl="ref")
    for leaf in out:
        assert leaf.dtype == jnp.float32
