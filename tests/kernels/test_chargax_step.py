"""Fused Chargax station-step kernel vs oracles.

Three-way agreement is required:
  1. Pallas kernel (interpret mode) == jnp reference (`ref.fused_step_ref`)
  2. jnp reference == the core transition functions (`apply_actions` +
     `charge_cars`) on real env states — proving the fused path is the same
     MDP, not a lookalike.
Plus a hypothesis sweep asserting the Eq. 5 invariant on the kernel output.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ChargaxEnv, EnvConfig
from repro.core.transition import apply_actions, charge_cars, decode_action
from repro.kernels.chargax_step import ops as fused_ops
from repro.kernels.chargax_step import ref as fused_ref
from repro.utils import replace

ENV = ChargaxEnv(EnvConfig())
PARAMS = ENV.default_params
DT = ENV.config.dt_hours
N = ENV.n_evse


def _random_state(key, n_occupied=10):
    """Random mid-episode env state with plugged cars."""
    ks = jax.random.split(key, 8)
    _, state = ENV.reset(ks[0])
    occ = (jnp.arange(N) < n_occupied).astype(jnp.float32)
    soc = jax.random.uniform(ks[1], (N,), minval=0.05, maxval=0.95) * occ
    cap = (40.0 + 60.0 * jax.random.uniform(ks[2], (N,))) * occ
    return replace(
        state,
        occupied=occ,
        soc=soc,
        e_remain=jax.random.uniform(ks[3], (N,), minval=0.0, maxval=40.0) * occ,
        t_remain=(jax.random.randint(ks[4], (N,), 1, 100) * occ).astype(jnp.int32),
        cap=cap,
        rbar=(50.0 + 250.0 * jax.random.uniform(ks[5], (N,))) * occ,
        tau=(0.6 + 0.3 * jax.random.uniform(ks[6], (N,))) * occ,
        user_type=(jax.random.uniform(ks[7], (N,)) < 0.5).astype(jnp.float32) * occ,
        batt_soc=jnp.float32(0.5),
    )


def _random_targets(key):
    k1, k2 = jax.random.split(key)
    t_evse = jax.random.uniform(k1, (N,), minval=0.0, maxval=1.0) * PARAMS.evse_max_current
    t_batt = jax.random.uniform(k2, (), minval=-1.0, maxval=1.0) * PARAMS.batt_max_current
    return t_evse, t_batt


@pytest.mark.parametrize("seed", range(5))
def test_ref_matches_core_transition(seed):
    """fused ref == apply_actions + charge_cars on the same state."""
    key = jax.random.key(seed)
    state = _random_state(key)
    t_evse, t_batt = _random_targets(jax.random.key(seed + 100))

    applied = apply_actions(PARAMS, state, t_evse, t_batt, DT)
    charged = charge_cars(PARAMS, state, applied, DT)

    out = fused_ops.fused_step(PARAMS, state, t_evse, t_batt, DT, impl="ref")

    np.testing.assert_allclose(out.current[:N], applied.evse_current, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out.current[N], applied.batt_current, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out.excess, applied.constraint_excess, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(out.soc[:N], charged.state.soc, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out.soc[N], charged.state.batt_soc, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out.e_remain[:N], charged.state.e_remain, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out.rhat[:N], charged.state.rhat, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out.e_pole[:N], charged.e_car, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out.e_pole[N], charged.e_batt_net, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("batch", [1, 64, 300])
def test_kernel_matches_ref(seed, batch):
    """Pallas (interpret) == jnp ref on batched random states."""
    keys = jax.random.split(jax.random.key(seed), batch)
    states = jax.vmap(_random_state)(keys)
    t_evse, t_batt = jax.vmap(_random_targets)(keys)

    out_k = fused_ops.fused_step(
        PARAMS, states, t_evse, t_batt, DT, impl="interpret", block_envs=64
    )
    out_r = fused_ops.fused_step(PARAMS, states, t_evse, t_batt, DT, impl="ref")
    for a, b, name in zip(out_k, out_r, fused_ref.FusedOut._fields):
        # fp32 op-ordering differs between the MXU dot and the jnp matmul
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=2e-4, err_msg=name
        )


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_constraint_invariant(seed):
    """Eq. 5 holds on kernel outputs for arbitrary states/targets."""
    key = jax.random.key(seed)
    state = _random_state(key, n_occupied=16)
    t_evse, t_batt = _random_targets(jax.random.key(seed ^ 0x5EED))
    out = fused_ops.fused_step(
        PARAMS, state, t_evse, t_batt, DT, impl="interpret", block_envs=1,
    )
    leaf = out.current[: N + 1]
    loads = PARAMS.member @ jnp.abs(leaf)
    assert bool(jnp.all(loads <= PARAMS.node_budget * 1.0001 + 1e-4))
    assert bool(jnp.all((out.soc >= 0) & (out.soc <= 1)))


def test_fused_step_dtypes_float32_only():
    """State slabs are fp32 end-to-end (env semantics are fp32)."""
    state = _random_state(jax.random.key(9))
    t_evse, t_batt = _random_targets(jax.random.key(10))
    out = fused_ops.fused_step(PARAMS, state, t_evse, t_batt, DT, impl="ref")
    for leaf in out:
        assert leaf.dtype == jnp.float32
