"""Mamba2 SSD kernel: chunked-jnp and Pallas(interpret) vs sequential-scan oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mamba2_ssd import ref
from repro.kernels.mamba2_ssd.ops import ssd, ssd_decode_step


def _inputs(key, b, l, h, p, n, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h), dtype) - 1.0) + 1e-3
    a = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.5)
    bm = jax.random.normal(ks[3], (b, l, n), dtype) / np.sqrt(n)
    cm = jax.random.normal(ks[4], (b, l, n), dtype) / np.sqrt(n)
    return x, dt, a, bm, cm


@pytest.mark.parametrize(
    "b,l,h,p,n,chunk",
    [
        (1, 128, 2, 64, 64, 64),
        (2, 256, 4, 32, 16, 128),
        (1, 64, 1, 128, 64, 32),
    ],
)
def test_chunked_matches_scan(b, l, h, p, n, chunk):
    x, dt, a, bm, cm = _inputs(jax.random.key(0), b, l, h, p, n)
    y_ref, s_ref = ref.ssd_scan_ref(x, dt, a, bm, cm)
    y_chk, s_chk = ref.ssd_chunked_jnp(x, dt, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(y_chk, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s_chk, s_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize(
    "b,l,h,p,n,chunk",
    [
        (1, 256, 2, 64, 64, 128),
        (2, 128, 3, 128, 128, 64),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_scan(b, l, h, p, n, chunk, dtype):
    x, dt, a, bm, cm = _inputs(jax.random.key(1), b, l, h, p, n, dtype)
    y_ref, s_ref = ref.ssd_scan_ref(x, dt, a, bm, cm)
    y_k, s_k = ssd(x, dt, a, bm, cm, chunk=chunk, impl="interpret")
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        y_k.astype(np.float32), y_ref.astype(np.float32), rtol=tol, atol=tol
    )
    np.testing.assert_allclose(s_k, s_ref, rtol=tol, atol=tol)


def test_decode_step_matches_scan_tail():
    """Recurrent decode step == last step of a scan over the same sequence."""
    b, l, h, p, n = 1, 16, 2, 32, 16
    x, dt, a, bm, cm = _inputs(jax.random.key(2), b, l, h, p, n)
    y_all, s_all = ref.ssd_scan_ref(x, dt, a, bm, cm)
    # replay: run scan on first l-1 tokens, then decode-step the last token
    y_head, s_head = ref.ssd_scan_ref(
        x[:, :-1], dt[:, :-1], a, bm[:, :-1], cm[:, :-1]
    )
    y_last, s_last = ssd_decode_step(
        x[:, -1], dt[:, -1], a, bm[:, -1], cm[:, -1], s_head
    )
    np.testing.assert_allclose(y_last, y_all[:, -1], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s_last, s_all, rtol=1e-5, atol=1e-5)


def test_gradients_flow():
    x, dt, a, bm, cm = _inputs(jax.random.key(3), 1, 64, 2, 16, 8)

    def loss(x, bm):
        y, _ = ssd(x, dt, a, bm, cm, chunk=32, impl="ref")
        return jnp.sum(y**2)

    gx, gb = jax.grad(loss, argnums=(0, 1))(x, bm)
    assert jnp.isfinite(gx).all() and jnp.isfinite(gb).all()
    assert float(jnp.abs(gx).max()) > 0


def test_state_carry_across_segments():
    """Chunked with s0 continues exactly from a previous segment."""
    x, dt, a, bm, cm = _inputs(jax.random.key(4), 1, 128, 2, 32, 16)
    y_full, s_full = ref.ssd_chunked_jnp(x, dt, a, bm, cm, chunk=32)
    y1, s1 = ref.ssd_chunked_jnp(
        x[:, :64], dt[:, :64], a, bm[:, :64], cm[:, :64], chunk=32
    )
    y2, s2 = ref.ssd_chunked_jnp(
        x[:, 64:], dt[:, 64:], a, bm[:, 64:], cm[:, 64:], chunk=32, s0=s1
    )
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], axis=1), y_full, rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(s2, s_full, rtol=2e-4, atol=2e-4)
