"""Golden regression tests for the fused hot path (ISSUE 10 satellite).

Tiny checked-in .npz digests of a deterministic fused rollout on three
canonical scenarios.  A refactor that silently changes physics — a reordered
clip, a dropped efficiency factor, a broken curtailment — moves these arrays
and fails here loudly.  Intended changes: regenerate with
``python tools/make_kernel_goldens.py`` and commit the diff.
"""
import os

import numpy as np
import pytest

import harness

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")


@pytest.mark.parametrize("name", sorted(harness.GOLDEN_SCENARIOS))
def test_fused_rollout_matches_golden(name):
    path = os.path.join(GOLDEN_DIR, f"{name}.npz")
    assert os.path.exists(path), (
        f"missing golden {path} — run tools/make_kernel_goldens.py"
    )
    want = np.load(path)
    got = harness.compute_golden(name)
    assert set(want.files) == set(got), "golden field set changed — regenerate"
    for k in want.files:
        np.testing.assert_allclose(
            got[k],
            want[k],
            rtol=1e-5,
            atol=1e-6,
            err_msg=f"{name}/{k} drifted from golden (tools/make_kernel_goldens.py "
            "regenerates after INTENDED physics changes)",
        )


@pytest.mark.parametrize("name", sorted(harness.GOLDEN_SCENARIOS))
def test_golden_rollout_fused_equals_staged(name):
    """The same golden recipe through the staged pipeline is bit-identical."""
    fused = harness.compute_golden(name, fused=True)
    staged = harness.compute_golden(name, fused=False)
    for k, v in fused.items():
        np.testing.assert_array_equal(v, staged[k], err_msg=f"{name}/{k}")
