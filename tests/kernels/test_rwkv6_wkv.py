"""RWKV6 WKV kernel: chunked-jnp and Pallas(interpret) vs sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rwkv6_wkv import ref
from repro.kernels.rwkv6_wkv.ops import wkv, wkv_decode_step


def _inputs(key, b, l, h, kd, vd, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, l, h, kd), dtype) / np.sqrt(kd)
    k = jax.random.normal(ks[1], (b, l, h, kd), dtype) / np.sqrt(kd)
    v = jax.random.normal(ks[2], (b, l, h, vd), dtype)
    # data-dependent decay in (0,1): w = exp(-exp(x)) as in RWKV6
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, l, h, kd), jnp.float32) - 2.0))
    u = jax.random.normal(ks[4], (h, kd), jnp.float32) * 0.3
    return r, k, v, w.astype(dtype), u


@pytest.mark.parametrize(
    "b,l,h,kd,vd,chunk",
    [
        (1, 128, 2, 64, 64, 64),
        (2, 96, 1, 32, 64, 32),
        (1, 256, 2, 64, 128, 128),
    ],
)
def test_chunked_matches_scan(b, l, h, kd, vd, chunk):
    r, k, v, w, u = _inputs(jax.random.key(0), b, l, h, kd, vd)
    y_ref, s_ref = ref.wkv_scan_ref(r, k, v, w, u)
    y_chk, s_chk = ref.wkv_chunked_jnp(r, k, v, w, u, chunk=chunk)
    np.testing.assert_allclose(y_chk, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s_chk, s_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize(
    "b,l,h,kd,vd,chunk",
    [
        (1, 128, 2, 64, 64, 64),
        (2, 128, 2, 64, 128, 32),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_scan(b, l, h, kd, vd, chunk, dtype):
    r, k, v, w, u = _inputs(jax.random.key(1), b, l, h, kd, vd, dtype)
    y_ref, s_ref = ref.wkv_scan_ref(r, k, v, w, u)
    y_k, s_k = wkv(r, k, v, w, u, chunk=chunk, impl="interpret")
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        y_k.astype(np.float32), y_ref.astype(np.float32), rtol=tol, atol=tol
    )
    np.testing.assert_allclose(s_k, s_ref, rtol=tol, atol=tol)


def test_decode_step_matches_scan_tail():
    b, l, h, kd, vd = 1, 24, 2, 32, 32
    r, k, v, w, u = _inputs(jax.random.key(2), b, l, h, kd, vd)
    y_all, s_all = ref.wkv_scan_ref(r, k, v, w, u)
    _, s_head = ref.wkv_scan_ref(r[:, :-1], k[:, :-1], v[:, :-1], w[:, :-1], u)
    y_last, s_last = wkv_decode_step(
        r[:, -1], k[:, -1], v[:, -1], w[:, -1], u, s_head
    )
    np.testing.assert_allclose(y_last, y_all[:, -1], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s_last, s_all, rtol=1e-5, atol=1e-5)


def test_strong_decay_is_stable():
    """Near-zero decays (w -> 0) must not overflow the chunked form."""
    r, k, v, w, u = _inputs(jax.random.key(3), 1, 64, 1, 32, 32)
    w = jnp.full_like(w, 1e-12)  # brutal decay
    y_ref, _ = ref.wkv_scan_ref(r, k, v, w, u)
    y_chk, _ = ref.wkv_chunked_jnp(r, k, v, w, u, chunk=32)
    assert bool(jnp.isfinite(y_chk).all())
    np.testing.assert_allclose(y_chk, y_ref, rtol=1e-4, atol=1e-4)


def test_gradients_flow():
    r, k, v, w, u = _inputs(jax.random.key(4), 1, 64, 1, 16, 16)

    def loss(r, w):
        y, _ = wkv(r, k, v, w, u, chunk=32, impl="ref")
        return jnp.sum(y**2)

    gr, gw = jax.grad(loss, argnums=(0, 1))(r, w)
    assert jnp.isfinite(gr).all() and jnp.isfinite(gw).all()
    assert float(jnp.abs(gr).max()) > 0
