"""Flash attention kernel vs jnp oracle — shape/dtype/feature sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.ops import flash_attention


def _rand_qkv(key, b, hq, hkv, lq, lk, d, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, lq, d), dtype)
    k = jax.random.normal(kk, (b, hkv, lk, d), dtype)
    v = jax.random.normal(kv, (b, hkv, lk, d), dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize(
    "b,hq,hkv,lq,lk,d",
    [
        (1, 2, 2, 128, 128, 64),  # square MHA, sub-128 head dim (padded)
        (2, 4, 2, 256, 256, 128),  # GQA group=2
        (1, 8, 1, 128, 384, 128),  # MQA, rectangular (decode-ish chunk)
        (1, 2, 2, 130, 200, 80),  # unaligned lengths exercise padding
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref_causal(b, hq, hkv, lq, lk, d, dtype):
    q, k, v = _rand_qkv(jax.random.key(0), b, hq, hkv, lq, lk, d, dtype)
    out = flash_attention(q, k, v, causal=True, impl="interpret")
    expected = ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(
        out.astype(np.float32), expected.astype(np.float32), atol=TOL[dtype], rtol=TOL[dtype]
    )


def test_flash_non_causal():
    q, k, v = _rand_qkv(jax.random.key(1), 1, 2, 2, 128, 256, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=False, impl="interpret")
    expected = ref.mha_reference(q, k, v, causal=False)
    np.testing.assert_allclose(out, expected, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [64, 128, 300])
def test_flash_sliding_window(window):
    q, k, v = _rand_qkv(jax.random.key(2), 1, 2, 2, 256, 256, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, impl="interpret")
    expected = ref.mha_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, expected, atol=2e-5, rtol=2e-5)


def test_flash_softcap():
    q, k, v = _rand_qkv(jax.random.key(3), 1, 4, 2, 128, 128, 128, jnp.float32)
    out = flash_attention(q, k, v, causal=True, softcap=50.0, impl="interpret")
    expected = ref.mha_reference(q, k, v, causal=True, softcap=50.0)
    np.testing.assert_allclose(out, expected, atol=2e-5, rtol=2e-5)


def test_flash_decode_alignment():
    """Lq < Lk with queries aligned to the end (KV-cache decode chunk)."""
    q, k, v = _rand_qkv(jax.random.key(4), 2, 2, 2, 128, 512, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, impl="interpret")
    expected = ref.mha_reference(q, k, v, causal=True)  # same default offset
    np.testing.assert_allclose(out, expected, atol=2e-5, rtol=2e-5)
    # row 0 of q attends exactly to cols [0, Lk-Lq]
    mask = ref.attention_mask(128, 512, causal=True)
    assert bool(mask[0, 384]) and not bool(mask[0, 385])


def test_gradients_flow_through_wrapper():
    q, k, v = _rand_qkv(jax.random.key(5), 1, 2, 1, 64, 64, 32, jnp.float32)

    def loss(q, k, v, impl):
        return jnp.sum(flash_attention(q, k, v, causal=True, impl=impl) ** 2)

    g_int = jax.grad(loss, argnums=(0, 1, 2))(q, k, v, "interpret")
    g_ref = jax.grad(loss, argnums=(0, 1, 2))(q, k, v, "ref")
    for a, b_ in zip(g_int, g_ref):
        np.testing.assert_allclose(a, b_, atol=1e-4, rtol=1e-4)


def test_block_size_invariance():
    q, k, v = _rand_qkv(jax.random.key(6), 1, 2, 2, 256, 256, 64, jnp.float32)
    o1 = flash_attention(q, k, v, impl="interpret", block_q=128, block_k=128)
    o2 = flash_attention(q, k, v, impl="interpret", block_q=64, block_k=256)
    np.testing.assert_allclose(o1, o2, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize(
    "b,hq,hkv,lq,lk,window,softcap",
    [
        (1, 2, 2, 128, 128, None, None),
        (2, 4, 2, 200, 333, None, None),  # unaligned + GQA
        (1, 2, 2, 256, 256, 100, None),
        (1, 4, 2, 128, 128, None, 50.0),
        (2, 2, 2, 64, 512, None, None),  # decode alignment
    ],
)
def test_blocked_jnp_matches_naive(b, hq, hkv, lq, lk, window, softcap):
    """The blocked online-softmax execution path == dense oracle."""
    q, k, v = _rand_qkv(jax.random.key(7), b, hq, hkv, lq, lk, 64, jnp.float32)
    out = ref.mha_blocked_jnp(q, k, v, causal=True, window=window, softcap=softcap, block_k=96)
    expected = ref.mha_reference(q, k, v, causal=True, window=window, softcap=softcap)
    np.testing.assert_allclose(out, expected, atol=2e-5, rtol=2e-5)


def test_blocked_jnp_gradients_match_naive():
    q, k, v = _rand_qkv(jax.random.key(8), 1, 2, 1, 96, 96, 32, jnp.float32)

    def loss(f, q, k, v):
        return jnp.sum(f(q, k, v, causal=True) ** 2)

    g_blk = jax.grad(lambda *a: loss(ref.mha_blocked_jnp, *a), argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(lambda *a: loss(ref.mha_reference, *a), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_blk, g_ref):
        np.testing.assert_allclose(a, b_, atol=1e-4, rtol=1e-4)
