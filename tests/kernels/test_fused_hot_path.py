"""The fused hot-path flag is bit-identical to the staged pipeline.

Acceptance criteria of ISSUE 10: ``fused_step=True`` proven bit-identical to
staged across all four action modes and dt ∈ {5, 15, 60}, under jit, vmap and
the nested scenario×env layout — all through the shared parity harness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import harness
from repro.envs.wrappers import AutoReset, VmapWrapper
from repro.kernels.chargax_step import ops as fused_ops


@pytest.mark.parametrize("mode", sorted(harness.ACTION_MODES))
@pytest.mark.parametrize("dt", harness.DT_MINUTES)
def test_fused_transition_bit_identical(mode, dt):
    """request+allocate+deliver: fused(ref) == staged, bitwise, under jit."""
    env = harness.make_env(mode, dt)
    params = env.default_params
    for seed in range(6):
        state = harness.random_state(
            env, params, jax.random.key(seed), n_occupied=seed % (env.n_evse + 1)
        )
        te, tb = harness.random_targets(params, jax.random.key(seed + 1000))
        harness.assert_fused_matches_staged(env, params, state, te, tb)


@pytest.mark.parametrize("mode", sorted(harness.ACTION_MODES))
def test_fused_env_step_bit_identical(mode):
    """Full env.step TimeStep (obs/state/reward/done/info): fused == staged."""
    env = harness.make_env(mode)
    params = env.default_params
    for seed in range(4):
        state = harness.random_state(env, params, jax.random.key(seed))
        action = harness.random_action(env, jax.random.key(seed + 50))
        harness.assert_step_matches(env, params, state, action, jax.random.key(seed))


if harness.HAVE_HYPOTHESIS:
    from hypothesis import HealthCheck, given, settings

    @settings(
        max_examples=25, deadline=None, suppress_health_check=[HealthCheck.too_slow]
    )
    @given(case=harness.parity_cases())
    def test_fused_matches_staged_hypothesis(case):
        """Property sweep: modes × dt × ragged architectures × random states."""
        env, params, state, te, tb = case
        harness.assert_fused_matches_staged(env, params, state, te, tb)


def test_fused_rollout_vmap_bit_identical():
    """40-step jitted vmapped rollout through the wrapper stack: fused ==
    staged bitwise on every TimeStep leaf."""
    env = harness.make_env("v2g")
    n_envs, n_steps = 16, 40

    def rollout(wenv, params):
        obs0, st0 = wenv.reset(jax.random.key(0), params)
        acts = jax.random.randint(
            jax.random.key(1),
            (n_steps, n_envs, env.num_action_heads),
            0,
            env.num_actions_per_head,
        )

        def body(carry, xs):
            k, a = xs
            ts = wenv.step(k, carry, a, params)
            return ts.state, (ts.obs, ts.reward, ts.done, ts.info)

        keys = jax.random.split(jax.random.key(2), n_steps)
        return jax.jit(lambda s: jax.lax.scan(body, s, (keys, acts)))(st0)

    staged = rollout(AutoReset(VmapWrapper(env, n_envs)), env.default_params)
    fenv = env.with_fused_step(True)
    fused = rollout(AutoReset(VmapWrapper(fenv, n_envs)), fenv.default_params)
    harness.assert_trees_equal(fused[1], staged[1], "rollout outputs")


def test_fused_nested_scenario_env_layout_bit_identical():
    """The nested scenario×env VmapWrapper layout: fused == staged bitwise."""
    scen = pytest.importorskip("repro.scenarios")
    env = harness.make_env()
    fenv = env.with_fused_step(True)
    names = ["shopping_pv_tou", "grid_tight_transformer"]
    sp = scen.stack_params([scen.make(n).make_params(env) for n in names])
    fsp = scen.stack_params([scen.make(n).make_params(fenv) for n in names])
    n_envs = 8
    action = jnp.zeros((n_envs, env.num_action_heads), jnp.int32) + 14

    def run(e, params):
        w = VmapWrapper(e, n_envs, num_scenarios=len(names))
        obs, st = w.reset(jax.random.key(5), params)
        return jax.jit(lambda s: w.step(jax.random.key(6), s, action, params))(st)

    ts_s = run(env, sp)
    ts_f = run(fenv, fsp)
    harness.assert_trees_equal(ts_f, ts_s, "nested scenario×env TimeStep")


def test_fused_grid_scenario_curtailment_bit_identical():
    """A finite feeder cap (grid scenario) takes the cap-active branch of the
    fused allocate fold and still matches staged bitwise."""
    scen = pytest.importorskip("repro.scenarios")
    env = harness.make_env()
    params = scen.make("grid_tight_transformer").make_params(env)
    for seed in range(4):
        state = harness.random_state(env, params, jax.random.key(seed), 16)
        te = jnp.broadcast_to(params.evse_max_current, (env.n_evse,)) * 1.0
        tb = params.batt_max_current * 1.0
        harness.assert_fused_matches_staged(env, params, state, te, tb)
        # the cap must actually bind somewhere in this sweep
    alloc, _ = fused_ops.fused_transition(
        harness.fused_params(params), state, te, tb, env.config.dt_hours, impl="ref"
    )
    assert float(alloc.cap_kw) < 1e8  # finite cap table is in force


def test_fused_pallas_interpret_close_on_hot_path():
    """The Pallas kernel (interpret mode — what TPU/GPU dispatch runs) agrees
    with staged within fp32 op-reorder tolerance on the same transition."""
    env = harness.make_env("v2g", 15.0)
    params = env.default_params
    state = harness.random_state(env, params, jax.random.key(11))
    te, tb = harness.random_targets(params, jax.random.key(12))
    harness.assert_fused_close(env, params, state, te, tb, impl="interpret")


def test_resolve_impl_env_var_override(monkeypatch):
    """CHARGAX_FUSED_IMPL forces the backend; auto falls back per-platform."""
    monkeypatch.setenv(fused_ops.IMPL_ENV_VAR, "interpret")
    assert fused_ops.resolve_impl() == "interpret"
    monkeypatch.setenv(fused_ops.IMPL_ENV_VAR, "pallas")
    assert fused_ops.resolve_impl() == "pallas"
    monkeypatch.delenv(fused_ops.IMPL_ENV_VAR)
    expected = "pallas" if jax.default_backend() in ("tpu", "gpu") else "ref"
    assert fused_ops.resolve_impl() == expected
    assert fused_ops.resolve_impl("ref") == "ref"  # explicit beats env/auto


def test_fused_params_pole_pack_hoisted():
    """make_params attaches the pole pack only when the flag is on, and the
    per-step builder reuses the hoisted pack as-is."""
    env = harness.make_env()
    fenv = env.with_fused_step(True)
    assert env.default_params.pole is None
    pp = fenv.default_params.pole
    assert pp is not None
    assert fused_ops.build_pole_params(fenv.default_params) is pp
    # power_w row: evse_voltage/path_eff on real lanes, batt_voltage after
    p = env.default_params
    np.testing.assert_allclose(
        np.asarray(pp.power_w[: env.n_evse]),
        np.asarray(p.evse_voltage / p.evse_path_eff),
        rtol=1e-6,
    )
    np.testing.assert_allclose(
        float(pp.power_w[env.n_evse]), float(p.batt_voltage), rtol=1e-6
    )
    assert np.all(np.asarray(pp.power_w[env.n_evse + 1 :]) == 0.0)
