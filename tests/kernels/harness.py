"""Kernel-parity test harness (ISSUE 10).

The ONE way kernel tests build environments, random mid-episode states and
action targets — and the ONE assertion that the fused hot path
(``EnvConfig.fused_step`` → ``kernels/chargax_step/ops.fused_transition``)
matches the staged lax pipeline:

    env = harness.make_env(action_mode="delta", allow_v2g=True, dt_minutes=15)
    state = harness.random_state(env, params, key, n_occupied=6)
    te, tb = harness.random_targets(params, key2)
    harness.assert_fused_matches_staged(env, params, state, te, tb)

Bitwise discipline: on the ``ref`` impl (the CPU hot-path default) parity is
EXACT — ``assert_array_equal``, no tolerances — because the fused request
stage runs the staged clips at their natural shapes and only the Eq. 5 load
reduction uses the kernel's padded matmul (0/1 membership, exact-zero
padding lanes).  ``pallas``/``interpret`` impls get fp32 op-reorder
tolerance via :func:`assert_fused_close`.

Hypothesis strategies (:func:`parity_cases`) sweep the four action modes,
dt ∈ {5, 15, 60} minutes, battery on/off and ragged EVSE counts across
station architectures.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

try:  # strategies need hypothesis; the deterministic harness does not
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal installs
    st = None
    HAVE_HYPOTHESIS = False

from repro.core import ChargaxEnv, EnvConfig, transition
from repro.core.transition import BIG
from repro.kernels.chargax_step import ops as fused_ops
from repro.utils import replace

# the four canonical action modes of the acceptance criteria
ACTION_MODES: dict[str, dict] = {
    "direct": dict(),
    "delta": dict(action_mode="delta"),
    "v2g": dict(allow_v2g=True),
    "delta_v2g_nobatt": dict(action_mode="delta", allow_v2g=True, battery=False),
}
DT_MINUTES = (5.0, 15.0, 60.0)
# ragged EVSE counts: 16, 16 (two trees), 16 (4x4 nodes), 4
ARCHITECTURES = ("paper_16", "mixed_8_8", "deep_4x4", "kiosk_ac_4")


@functools.lru_cache(maxsize=None)
def make_env(
    mode: str = "direct",
    dt_minutes: float = 5.0,
    architecture: str = "paper_16",
    pad_evse: int = 0,
    pad_nodes: int = 0,
) -> ChargaxEnv:
    """Cached env for a (mode, dt, architecture, padding) cell."""
    return ChargaxEnv(
        EnvConfig(
            dt_minutes=dt_minutes,
            architecture=architecture,
            pad_evse=pad_evse,
            pad_nodes=pad_nodes,
            **ACTION_MODES[mode],
        )
    )


def random_state(env: ChargaxEnv, params, key, n_occupied: int | None = None):
    """Random mid-episode state: ``n_occupied`` plugged cars at random ports
    with random SoC/capacity/deadline/charge-curve and open V2G debt."""
    n = env.n_evse
    if n_occupied is None:
        n_occupied = n // 2
    ks = jax.random.split(key, 9)
    _, state = env.reset(ks[0])
    occ = (jax.random.permutation(ks[8], jnp.arange(n)) < n_occupied).astype(
        jnp.float32
    )
    return replace(
        state,
        occupied=occ,
        soc=jax.random.uniform(ks[1], (n,), minval=0.05, maxval=0.95) * occ,
        cap=(40.0 + 60.0 * jax.random.uniform(ks[2], (n,))) * occ,
        e_remain=jax.random.uniform(ks[3], (n,), minval=0.0, maxval=40.0) * occ,
        t_remain=(jax.random.randint(ks[4], (n,), 1, 100) * occ).astype(jnp.int32),
        rbar=(50.0 + 250.0 * jax.random.uniform(ks[5], (n,))) * occ,
        tau=(0.6 + 0.3 * jax.random.uniform(ks[6], (n,))) * occ,
        user_type=(jax.random.uniform(ks[7], (n,)) < 0.5).astype(jnp.float32) * occ,
        batt_soc=jnp.float32(0.37),
        v2g_debt=jax.random.uniform(ks[0], (n,), maxval=5.0) * occ,
    )


def random_targets(params, key):
    """Signed current targets for every EVSE lane + the battery."""
    n = params.evse_voltage.shape[0]
    k1, k2 = jax.random.split(key)
    te = jax.random.uniform(k1, (n,), minval=-1.0, maxval=1.0) * params.evse_max_current
    tb = jax.random.uniform(k2, (), minval=-1.0, maxval=1.0) * params.batt_max_current
    return te, tb


def random_action(env: ChargaxEnv, key):
    """A uniformly random discrete action for the env's action space."""
    return jax.random.randint(
        key, (env.num_action_heads,), 0, env.num_actions_per_head
    )


def fused_params(params):
    """``params`` with the hoisted kernel pole pack attached (what a
    ``fused_step=True`` env's ``make_params`` produces)."""
    if params.pole is not None:
        return params
    return replace(params, pole=fused_ops.build_pole_params(params))


def staged_transition(env: ChargaxEnv, params, state, te, tb):
    """The staged request → allocate → deliver stages, as env.step runs them."""
    dt = env.config.dt_hours
    applied = transition.request(params, state, te, tb, dt)
    alloc = transition.allocate(params, state, applied)
    return alloc, transition.deliver(params, state, alloc.applied, dt)


def assert_trees_equal(got, want, context: str = ""):
    """Bitwise equality over two pytrees, naming the offending leaf."""
    gl, gt = jax.tree_util.tree_flatten(got)
    wl, wt = jax.tree_util.tree_flatten(want)
    assert gt == wt, f"{context}: tree structures differ\n{gt}\nvs\n{wt}"
    for i, (g, w) in enumerate(zip(gl, wl)):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(w), err_msg=f"{context}: leaf {i} of {gt}"
        )


def assert_trees_close(got, want, context: str = "", rtol=1e-4, atol=2e-4):
    """fp32 op-reorder tolerance over two pytrees (pallas/interpret impls)."""
    gl, gt = jax.tree_util.tree_flatten(got)
    wl, wt = jax.tree_util.tree_flatten(want)
    assert gt == wt, f"{context}: tree structures differ"
    for i, (g, w) in enumerate(zip(gl, wl)):
        np.testing.assert_allclose(
            np.asarray(g),
            np.asarray(w),
            rtol=rtol,
            atol=atol,
            err_msg=f"{context}: leaf {i} of {gt}",
        )


@functools.lru_cache(maxsize=None)
def _parity_fn(env: ChargaxEnv, impl: str):
    """One jitted staged-vs-fused comparator per (env, impl) — params/state
    are traced args, so seed/scenario sweeps reuse one compile."""
    dt = env.config.dt_hours

    def both(params, fp, state, te, tb):
        alloc_s, charged_s = staged_transition(env, params, state, te, tb)
        alloc_f, charged_f = fused_ops.fused_transition(fp, state, te, tb, dt, impl=impl)
        return (alloc_s, charged_s), (alloc_f, charged_f)

    return jax.jit(both)


def assert_fused_matches_staged(env: ChargaxEnv, params, state, te, tb):
    """The harness's central assertion: the fused transition on the CPU
    ``ref`` impl is BIT-IDENTICAL to the staged pipeline on the same
    (params, state, targets) — applied currents, constraint excess, grid
    allocation and the full delivered state."""
    staged, fused = _parity_fn(env, "ref")(params, fused_params(params), state, te, tb)
    assert_trees_equal(fused[0], staged[0], "AllocationResult (fused vs staged)")
    assert_trees_equal(fused[1], staged[1], "ChargeResult (fused vs staged)")


def assert_fused_close(env: ChargaxEnv, params, state, te, tb, *, impl="interpret"):
    """Pallas/interpret impl agrees with the staged pipeline within fp32
    op-reorder tolerance (the MXU dot reassociates the Eq. 5 reduction)."""
    staged, fused = _parity_fn(env, impl)(params, fused_params(params), state, te, tb)
    assert_trees_close(fused[0], staged[0], f"AllocationResult ({impl} vs staged)")
    assert_trees_close(fused[1], staged[1], f"ChargeResult ({impl} vs staged)")


@functools.lru_cache(maxsize=None)
def _step_parity_fn(env: ChargaxEnv):
    fenv = env.with_fused_step(True)

    def both(key, params, fp, state, action):
        return env.step(key, state, action, params), fenv.step(key, state, action, fp)

    return jax.jit(both)


def assert_step_matches(env: ChargaxEnv, params, state, action, key):
    """Full ``env.step`` parity: the ``fused_step=True`` env's TimeStep is
    bit-identical to the staged env's on the same key/state/action."""
    ts_s, ts_f = _step_parity_fn(env)(key, params, fused_params(params), state, action)
    assert_trees_equal(ts_f, ts_s, "TimeStep (fused env.step vs staged)")


# ---------------------------------------------------------------------------
# Golden regression fixtures (tests/kernels/goldens/*.npz; regenerate with
# tools/make_kernel_goldens.py)
# ---------------------------------------------------------------------------
# canonical scenario -> the harness action mode its env needs
GOLDEN_SCENARIOS = {
    "shopping_pv_tou": "direct",
    "v2g_shopping_tou": "v2g",
    "grid_tight_transformer": "direct",
}
GOLDEN_STEPS = 24  # two hours at dt=5min: arrivals, charging, PV, curtailment


def compute_golden(name: str, fused: bool = True) -> dict[str, np.ndarray]:
    """Deterministic short rollout on a canonical scenario → physics digest.

    Fixed keys, max-charge action every step; returns the final state's
    physics-bearing arrays plus the reward sequence and last observation —
    exactly what a refactor that silently changes physics would move.
    """
    from repro import scenarios as scen

    env = make_env(GOLDEN_SCENARIOS[name]).with_fused_step(fused)
    params = scen.make(name).make_params(env)
    _, state = env.reset(jax.random.key(0), params)
    action = jnp.full(
        (env.num_action_heads,), env.num_actions_per_head - 1, jnp.int32
    )

    def body(carry, k):
        ts = env.step(k, carry, action, params)
        return ts.state, (ts.obs, ts.reward)

    keys = jax.random.split(jax.random.key(1), GOLDEN_STEPS)
    state, (obs_seq, reward) = jax.jit(lambda s: jax.lax.scan(body, s, keys))(state)
    return {
        "obs_last": np.asarray(obs_seq[-1]),
        "reward": np.asarray(reward),
        "soc": np.asarray(state.soc),
        "e_remain": np.asarray(state.e_remain),
        "v2g_debt": np.asarray(state.v2g_debt),
        "batt_soc": np.asarray(state.batt_soc),
        "profit_cum": np.asarray(state.profit_cum),
        "energy_delivered": np.asarray(state.energy_delivered),
        "energy_discharged": np.asarray(state.energy_discharged),
    }


# ---------------------------------------------------------------------------
# Hypothesis strategies (only when hypothesis is installed)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:

    @st.composite
    def parity_cases(draw):
        """(env, params, state, te, tb) across modes × dt × architectures."""
        mode = draw(st.sampled_from(sorted(ACTION_MODES)))
        dt = draw(st.sampled_from(DT_MINUTES))
        arch = draw(st.sampled_from(ARCHITECTURES))
        seed = draw(st.integers(0, 2**31 - 1))
        env = make_env(mode, dt, arch)
        params = env.default_params
        n_occ = draw(st.integers(0, env.n_evse))
        state = random_state(env, params, jax.random.key(seed), n_occ)
        te, tb = random_targets(params, jax.random.key(seed ^ 0x5EED))
        return env, params, state, te, tb
