"""Hypothesis property tests for Chargax invariants (DESIGN.md §9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ChargaxEnv, EnvConfig
from repro.core.transition import charge_rate, constraint_scale

jax.config.update("jax_platform_name", "cpu")

_ENV = ChargaxEnv(EnvConfig())
_PARAMS = _ENV.default_params
_STEP = jax.jit(_ENV.step)


# ---------------------------------------------------------------------------
# Eq. 5 invariant: after enforcement, every node budget is satisfied
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(
    data=st.data(),
    n_leaves=st.integers(2, 12),
    n_nodes=st.integers(1, 6),
)
def test_constraint_always_satisfied(data, n_leaves, n_nodes):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    member = np.zeros((n_nodes, n_leaves), np.float32)
    member[0] = 1.0  # root holds all leaves
    for i in range(1, n_nodes):
        member[i] = rng.random(n_leaves) < 0.5
    budget = rng.uniform(0.5, 50.0, n_nodes).astype(np.float32)
    currents = rng.uniform(-100.0, 100.0, n_leaves).astype(np.float32)

    scale, _ = constraint_scale(jnp.asarray(currents), jnp.asarray(member), jnp.asarray(budget))
    scaled = currents * np.asarray(scale)
    loads = member @ np.abs(scaled)
    assert np.all(loads <= budget * (1 + 1e-4) + 1e-5)
    # scaling never amplifies or flips a current
    assert np.all(np.abs(scaled) <= np.abs(currents) + 1e-6)
    assert np.all(np.sign(scaled) * np.sign(currents) >= 0)


# ---------------------------------------------------------------------------
# Charging curve properties
# ---------------------------------------------------------------------------
@settings(max_examples=100, deadline=None)
@given(
    soc=st.floats(0.0, 1.0),
    rbar=st.floats(0.1, 500.0),
    tau=st.floats(0.05, 0.95),
)
def test_charge_rate_bounds(soc, rbar, tau):
    r = float(charge_rate(jnp.float32(soc), jnp.float32(rbar), jnp.float32(tau)))
    assert -1e-4 <= r <= rbar * (1 + 1e-5)
    if soc <= tau:
        np.testing.assert_allclose(r, rbar, rtol=1e-6)  # bulk region


# ---------------------------------------------------------------------------
# Full-step invariants under random actions
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 30))
def test_step_invariants(seed, steps):
    key = jax.random.key(seed)
    _, state = _ENV.reset(key)
    for _ in range(steps):
        key, ka, ks = jax.random.split(key, 3)
        action = _ENV.sample_action(ka)
        obs, state, r, d, info = _STEP(ks, state, action)

    # SoC bounded
    assert bool(jnp.all((state.soc >= 0) & (state.soc <= 1)))
    assert 0.0 <= float(state.batt_soc) <= 1.0
    # remaining request never negative
    assert bool(jnp.all(state.e_remain >= 0))
    # unoccupied ports carry no car state / current
    empty = state.occupied < 0.5
    assert bool(jnp.all(jnp.where(empty, jnp.abs(state.evse_current), 0.0) == 0))
    assert bool(jnp.all(jnp.where(empty, state.cap, 0.0) == 0))
    # finite numerics everywhere
    assert bool(jnp.isfinite(obs).all())
    assert bool(jnp.isfinite(r))
    # post-enforcement loads satisfy every node budget (Eq. 5)
    leaf = jnp.concatenate([state.evse_current, state.batt_current[None]])
    loads = _PARAMS.member @ jnp.abs(leaf)
    assert bool(jnp.all(loads <= _PARAMS.node_budget * 1.0001 + 1e-4))


# ---------------------------------------------------------------------------
# Exogenous/endogenous factorisation (Eq. 4): the exogenous stream does not
# depend on actions — same key, different actions => same arrivals & prices.
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_exogenous_independent_of_actions(seed):
    key = jax.random.key(seed)
    _, s0 = _ENV.reset(key)
    ka = jax.random.key(seed + 1)

    a_max = jnp.full((_ENV.num_action_heads,), 2 * _ENV.config.discretization, jnp.int32)
    a_min = jnp.full((_ENV.num_action_heads,), _ENV.config.discretization, jnp.int32)

    _, s1, _, _, i1 = _STEP(ka, s0, a_max)
    _, s2, _, _, i2 = _STEP(ka, s0, a_min)

    # same arrival count, same prices, same day — regardless of action
    np.testing.assert_allclose(i1["arrived"], i2["arrived"])
    np.testing.assert_allclose(i1["price_buy"], i2["price_buy"])
    assert int(s1.day) == int(s2.day)
    np.testing.assert_allclose(s1.price_buy, s2.price_buy)
