"""Calendar/dt/V2G correctness regressions (ISSUE 3).

Covers: day rollover on multi-day episodes (prices + weekday feature), dt
invariance of the per-hour facility cost, V2G round-trip energy conservation
(up to ``evse_path_eff``), the pack-headroom clamp on discharged requests,
idle-port deadline drift, and per-port bidirectional masks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChargaxEnv, EnvConfig
from repro.core.rewards import step_energies
from repro.core.transition import AppliedActions, charge_cars
from repro.utils import replace


def _idle_action(env):
    """All heads at level D: 0 amps everywhere."""
    return jnp.full((env.num_action_heads,), env.config.discretization, jnp.int32)


def _no_arrivals(params):
    return replace(params, arrival_rate=jnp.zeros_like(params.arrival_rate))


# ---------------------------------------------------------------------------
# Day rollover (bugfix: multi-day episodes replayed day-0 prices forever)
# ---------------------------------------------------------------------------
class TestDayRollover:
    def _run_day(self, env, params, state, key):
        step = jax.jit(env.step)
        a = _idle_action(env)
        obs = None
        for _ in range(env.config.steps_per_day):
            key, k = jax.random.split(key)
            obs, state, _, _, _ = step(k, state, a, params)
        return obs, state

    def test_day_and_prices_advance_at_midnight(self):
        env = ChargaxEnv(EnvConfig(dt_minutes=60.0, episode_hours=48.0))
        params = _no_arrivals(env.default_params)
        _, state = env.reset(jax.random.key(0), params)
        state = replace(
            state, day=jnp.int32(0), price_buy=params.price_buy_table[0]
        )
        _, s1 = self._run_day(env, params, state, jax.random.key(1))
        assert int(s1.day) == 1
        np.testing.assert_allclose(s1.price_buy, params.price_buy_table[1])
        # day-1 prices genuinely differ from day-0 (the old bug replayed row 0)
        assert not np.allclose(s1.price_buy, params.price_buy_table[0])

    def test_day_wraps_mod_table_length(self):
        env = ChargaxEnv(EnvConfig(dt_minutes=60.0, episode_hours=48.0))
        params = _no_arrivals(env.default_params)
        n_days = params.price_buy_table.shape[0]
        _, state = env.reset(jax.random.key(0), params)
        state = replace(
            state,
            day=jnp.int32(n_days - 1),
            price_buy=params.price_buy_table[n_days - 1],
        )
        _, s1 = self._run_day(env, params, state, jax.random.key(1))
        assert int(s1.day) == 0
        np.testing.assert_allclose(s1.price_buy, params.price_buy_table[0])

    def test_weekday_feature_flips_at_rollover(self):
        env = ChargaxEnv(EnvConfig(dt_minutes=60.0, episode_hours=48.0))
        params = _no_arrivals(env.default_params)
        _, state = env.reset(jax.random.key(0), params)
        # day 4 (Friday) -> day 5 (Saturday): weekday obs feature 1 -> 0
        state = replace(
            state, day=jnp.int32(4), price_buy=params.price_buy_table[4]
        )
        weekday_idx = 8 * env.n_evse + 2 + 2
        assert float(env.observe(state, params)[weekday_idx]) == 1.0
        obs, s1 = self._run_day(env, params, state, jax.random.key(1))
        assert int(s1.day) == 5
        assert float(obs[weekday_idx]) == 0.0

    def test_mid_day_step_keeps_day_and_prices(self):
        env = ChargaxEnv(EnvConfig(dt_minutes=60.0, episode_hours=48.0))
        params = _no_arrivals(env.default_params)
        _, state = env.reset(jax.random.key(0), params)
        state = replace(
            state, day=jnp.int32(7), price_buy=params.price_buy_table[7]
        )
        _, s1, _, _, _ = env.step(jax.random.key(1), state, _idle_action(env), params)
        assert int(s1.day) == 7
        np.testing.assert_allclose(s1.price_buy, params.price_buy_table[7])


# ---------------------------------------------------------------------------
# dt invariance (bugfix: facility cost was charged per step, not per hour)
# ---------------------------------------------------------------------------
def test_facility_cost_per_hour_is_dt_invariant():
    hourly = {}
    for dt in (5.0, 15.0, 60.0):
        env = ChargaxEnv(EnvConfig(dt_minutes=dt, episode_hours=2.0))
        params = _no_arrivals(env.default_params)
        _, state = env.reset(jax.random.key(0), params)
        step = jax.jit(env.step)
        a = _idle_action(env)
        key, profit = jax.random.key(1), 0.0
        for _ in range(int(round(60.0 / dt))):  # exactly one hour
            key, k = jax.random.split(key)
            _, state, _, _, info = step(k, state, a, params)
            profit += float(info["profit"])
        hourly[dt] = profit
    # an idle empty station burns exactly the hourly facility cost at any dt
    np.testing.assert_allclose(hourly[5.0], hourly[15.0], rtol=1e-5)
    np.testing.assert_allclose(hourly[5.0], hourly[60.0], rtol=1e-5)
    np.testing.assert_allclose(hourly[5.0], -3.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# V2G round trip + request headroom clamp
# ---------------------------------------------------------------------------
def _one_car_state(env, soc=0.8, cap=60.0, e_remain=0.0):
    _, state = env.reset(jax.random.key(0))
    return replace(
        state,
        occupied=state.occupied.at[0].set(1.0),
        soc=state.soc.at[0].set(soc),
        e_remain=state.e_remain.at[0].set(e_remain),
        t_remain=state.t_remain.at[0].set(10_000),
        cap=state.cap.at[0].set(cap),
        rbar=state.rbar.at[0].set(200.0),
        rhat=state.rhat.at[0].set(200.0),
        tau=state.tau.at[0].set(0.8),
        user_type=state.user_type.at[0].set(0.0),  # time-sensitive: stays
    )


def test_v2g_round_trip_conserves_energy_up_to_path_eff():
    env = ChargaxEnv(EnvConfig(allow_v2g=True))
    params = _no_arrivals(env.default_params)
    state = _one_car_state(env)
    d = env.config.discretization
    step = jax.jit(env.step)

    discharge = _idle_action(env).at[0].set(0)  # port 0: -100%
    recharge = _idle_action(env).at[0].set(2 * d)  # port 0: +100%
    key = jax.random.key(1)
    e_grid_discharge = 0.0
    for _ in range(6):
        key, k = jax.random.split(key)
        _, state, _, _, info = step(k, state, discharge, params)
        e_grid_discharge += float(info["e_grid_net"])
    discharged = float(state.energy_discharged)
    assert discharged > 1.0  # the pack really was drained
    soc_mid = float(state.soc[0])
    assert soc_mid < 0.8
    # the request grew by exactly the discharged energy, within headroom
    np.testing.assert_allclose(float(state.e_remain[0]), discharged, rtol=1e-4)
    assert float(state.e_remain[0]) <= (1.0 - soc_mid) * 60.0 + 1e-3

    e_grid_recharge = 0.0
    for _ in range(60):
        key, k = jax.random.split(key)
        _, state, _, _, info = step(k, state, recharge, params)
        e_grid_recharge += float(info["e_grid_net"])
    # round trip: SoC restored, request refilled to zero
    np.testing.assert_allclose(float(state.soc[0]), 0.8, rtol=1e-4)
    assert float(state.e_remain[0]) < 1e-3
    # grid bookkeeping: export = E * eff, import = E / eff
    eff = float(params.evse_path_eff[0])
    np.testing.assert_allclose(e_grid_discharge, -discharged * eff, rtol=1e-3)
    np.testing.assert_allclose(e_grid_recharge, discharged / eff, rtol=1e-3)
    # the round trip burns energy — never creates it
    assert e_grid_discharge + e_grid_recharge >= discharged * (1.0 / eff - eff) - 1e-4


def test_discharged_request_clamped_to_pack_headroom():
    """A poisoned over-inflated request is pulled back to (1 - SoC) * cap."""
    env = ChargaxEnv(EnvConfig(allow_v2g=True))
    params = env.default_params
    # e_remain = 50 kWh but the pack only has (1 - 0.8) * 60 = 12 kWh headroom
    state = _one_car_state(env, soc=0.8, cap=60.0, e_remain=50.0)
    applied = AppliedActions(
        evse_current=jnp.zeros_like(state.evse_current),
        batt_current=jnp.float32(0.0),
        constraint_excess=jnp.float32(0.0),
    )
    charged = charge_cars(params, state, applied, env.config.dt_hours)
    assert float(charged.state.e_remain[0]) <= (1.0 - 0.8) * 60.0 + 1e-4


def test_discharge_never_inflates_request_beyond_headroom():
    env = ChargaxEnv(EnvConfig(allow_v2g=True))
    params = _no_arrivals(env.default_params)
    # near-full pack with a nearly-met request: discharge for a while
    state = _one_car_state(env, soc=0.95, cap=60.0, e_remain=2.0)
    a = _idle_action(env).at[0].set(0)
    step = jax.jit(env.step)
    key = jax.random.key(3)
    for _ in range(20):
        key, k = jax.random.split(key)
        _, state, _, _, _ = step(k, state, a, params)
        headroom = (1.0 - float(state.soc[0])) * 60.0
        assert float(state.e_remain[0]) <= headroom + 1e-3


# ---------------------------------------------------------------------------
# Idle-port deadline drift (bugfix: t_remain decremented on empty lanes)
# ---------------------------------------------------------------------------
def test_idle_ports_hold_t_remain_at_zero():
    env = ChargaxEnv(EnvConfig())
    params = _no_arrivals(env.default_params)
    _, state = env.reset(jax.random.key(0), params)
    step = jax.jit(env.step)
    a = _idle_action(env)
    key = jax.random.key(1)
    for _ in range(10):
        key, k = jax.random.split(key)
        _, state, _, _, _ = step(k, state, a, params)
    # empty station: deadlines hold at 0 instead of drifting to -10
    assert bool(jnp.all(state.t_remain == 0))


def test_occupied_ports_still_tick_down():
    env = ChargaxEnv(EnvConfig())
    params = _no_arrivals(env.default_params)
    state = _one_car_state(env)
    state = replace(state, t_remain=state.t_remain.at[0].set(5))
    _, s1, _, _, _ = env.step(jax.random.key(1), state, _idle_action(env), params)
    assert int(s1.t_remain[0]) == 4


# ---------------------------------------------------------------------------
# Per-port bidirectional masks (scenario v2g axis)
# ---------------------------------------------------------------------------
def test_v2g_mask_gates_port_discharge():
    env = ChargaxEnv(EnvConfig(allow_v2g=True))
    params = _no_arrivals(env.default_params)
    mask = jnp.zeros_like(params.evse_v2g_mask).at[0].set(1.0)
    params = replace(params, evse_v2g_mask=mask)
    state = _one_car_state(env)
    # plug an identical car into (charge-only) port 1
    state = replace(
        state,
        occupied=state.occupied.at[1].set(1.0),
        soc=state.soc.at[1].set(0.8),
        t_remain=state.t_remain.at[1].set(10_000),
        cap=state.cap.at[1].set(60.0),
        rbar=state.rbar.at[1].set(200.0),
        rhat=state.rhat.at[1].set(200.0),
        tau=state.tau.at[1].set(0.8),
    )
    a = jnp.zeros((env.num_action_heads,), jnp.int32).at[-1].set(
        env.config.discretization
    )  # all ports try -100%, battery idle
    _, s1, _, _, _ = env.step(jax.random.key(1), state, a, params)
    assert float(s1.evse_current[0]) < 0.0  # bidirectional port discharges
    assert float(s1.evse_current[1]) == 0.0  # charge-only port clamps at 0


def test_v2g_churn_cannot_mint_profit():
    """Discharge+recharge on a FLAT price must lose money (grid losses only):
    refills repaying V2G debt settle at p_v2g_comp, not p_sell, so the
    station cannot earn the (p_sell - p_v2g_comp) spread by cycling a pack."""
    env = ChargaxEnv(EnvConfig(allow_v2g=True))
    params = _no_arrivals(env.default_params)
    params = replace(
        params,
        price_buy_table=jnp.full_like(params.price_buy_table, 0.2),
        p_v2g_comp=jnp.float32(0.10),
        grid_sell_discount=jnp.float32(0.95),
    )

    def run(actions):
        _, state = env.reset(jax.random.key(0), params)
        state = replace(
            state,
            occupied=state.occupied.at[0].set(1.0),
            soc=state.soc.at[0].set(0.8),
            t_remain=state.t_remain.at[0].set(10_000),
            cap=state.cap.at[0].set(60.0),
            rbar=state.rbar.at[0].set(200.0),
            rhat=state.rhat.at[0].set(200.0),
            tau=state.tau.at[0].set(0.8),
        )
        step, key, profit = jax.jit(env.step), jax.random.key(1), 0.0
        for a in actions:
            key, k = jax.random.split(key)
            _, state, _, _, info = step(k, state, a, params)
            profit += float(info["profit"])
        return profit, state

    d = env.config.discretization
    idle = _idle_action(env)
    churn = [idle.at[0].set(0)] * 6 + [idle.at[0].set(2 * d)] * 12
    p_churn, s_churn = run(churn)
    p_idle, _ = run([idle] * len(churn))
    assert float(s_churn.energy_discharged) > 1.0  # the cycle really happened
    assert float(s_churn.v2g_debt[0]) < 1e-3  # and was fully repaid
    # churn strictly loses vs idling (round-trip grid losses, zero spread)
    assert p_churn < p_idle - 1e-4


def test_v2g_spread_prices_discharge_revenue():
    """Discharge revenue uses p_v2g_comp, charge revenue p_sell (Eq. 2 split)."""
    env = ChargaxEnv(EnvConfig(allow_v2g=True))
    params = env.default_params
    e_car = jnp.zeros((env.n_evse,)).at[0].set(-2.0).at[1].set(3.0)
    en = step_energies(params, e_car, jnp.float32(0.0))
    np.testing.assert_allclose(float(en.e_car_in), 3.0)
    np.testing.assert_allclose(float(en.e_car_out), 2.0)
    from repro.core.rewards import profit

    params_spread = replace(params, p_v2g_comp=jnp.float32(0.10))
    p0 = profit(params, en, jnp.float32(0.2), env.config.dt_hours)
    p1 = profit(params_spread, en, jnp.float32(0.2), env.config.dt_hours)
    # cheaper owner compensation -> strictly more station profit
    np.testing.assert_allclose(float(p1) - float(p0), (0.75 - 0.10) * 2.0, rtol=1e-5)
