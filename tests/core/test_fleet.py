"""FleetEnv regression tests: padding is inert, the vmapped fleet step is the
single-station step, and a jitted 24h fleet rollout runs in one scan."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChargaxEnv, EnvConfig, FleetEnv
from repro.core.station import ARCHITECTURES, pad_layout

jax.config.update("jax_platform_name", "cpu")

FLEET_ARCHS = ["paper_16", "deep_4x4", "single_dc_8"]  # 16/16/8 lanes, 3/5/1 nodes

# state fields that must match bit-for-bit between padded/unpadded runs
_LANE_FIELDS = (
    "evse_current", "occupied", "soc", "e_remain", "t_remain",
    "rhat", "cap", "rbar", "tau", "user_type",
)
_SCALAR_FIELDS = ("batt_current", "batt_soc", "t", "day")


def _assert_lanes_equal(state_pad, state_ref, n, ctx=""):
    for f in _LANE_FIELDS:
        a = np.asarray(getattr(state_pad, f))[..., :n]
        b = np.asarray(getattr(state_ref, f))
        assert np.array_equal(a, b), f"{ctx}: {f} diverged"
    for f in _SCALAR_FIELDS:
        assert np.array_equal(
            np.asarray(getattr(state_pad, f)), np.asarray(getattr(state_ref, f))
        ), f"{ctx}: {f} diverged"


def test_pad_layout_shapes_and_mask():
    lay = ARCHITECTURES["deep_4x4"]()
    padded = pad_layout(lay, 20, 8)
    assert padded.n_evse == 20 and padded.n_nodes == 8
    assert padded.member.shape == (8, 20)
    np.testing.assert_array_equal(padded.member[: lay.n_nodes, : lay.n_evse], lay.member)
    np.testing.assert_array_equal(padded.mask[: lay.n_evse], 1.0)
    np.testing.assert_array_equal(padded.mask[lay.n_evse :], 0.0)
    with pytest.raises(ValueError):
        pad_layout(lay, lay.n_evse - 1, lay.n_nodes)


def test_padded_env_matches_unpadded():
    """Padding lanes/nodes must not perturb the real lanes' trajectories.

    Discrete fields (occupancy, deadlines, user types, episode clock) must be
    *identical*; continuous fields are compared at last-ulp tolerance because
    the Eq. 5 load matmul reduces over a different lane count when padded,
    which XLA:CPU may vectorise with a different partial-sum grouping.
    """
    cfg = EnvConfig(architecture="deep_4x4")
    env = ChargaxEnv(cfg)
    envp = ChargaxEnv(dataclasses.replace(cfg, pad_evse=24, pad_nodes=9))
    n = env.n_evse

    step = jax.jit(env.step)
    stepp = jax.jit(envp.step)
    key = jax.random.key(3)
    _, state = env.reset(key)
    _, statep = envp.reset(key)
    action = env.sample_action(jax.random.key(4))
    # pad the action with battery head kept last
    actionp = jnp.concatenate(
        [action[:-1], jnp.full((envp.n_evse - n,), 0, action.dtype), action[-1:]]
    )
    # discrete fields and table lookups must be identical; arithmetic-derived
    # floats (incl. rbar = kW * 1000 / V) go in the tolerance group because
    # XLA may emit a reciprocal-multiply in one program and a divide in the
    # other — padded and unpadded envs are different compiled programs.
    exact = ("occupied", "t_remain", "cap", "tau", "user_type")
    for i in range(60):
        k = jax.random.key(1000 + i)
        obs, state, r, d, info = step(k, state, action)
        obsp, statep, rp, dp, infop = stepp(k, statep, actionp)
        for f in exact:
            assert np.array_equal(
                np.asarray(getattr(statep, f))[:n], np.asarray(getattr(state, f))
            ), f"step {i}: {f} diverged"
        for f in ("evse_current", "soc", "e_remain", "rhat", "rbar"):
            np.testing.assert_allclose(
                np.asarray(getattr(statep, f))[:n],
                np.asarray(getattr(state, f)),
                rtol=1e-5, atol=1e-5, err_msg=f"step {i}: {f}",
            )
        # padded lanes never activate
        assert np.asarray(statep.occupied)[n:].max() == 0.0
        assert np.asarray(statep.evse_current)[n:].max() == 0.0
        np.testing.assert_allclose(float(r), float(rp), rtol=1e-5, atol=1e-5)
        assert bool(d) == bool(dp)


def test_fleet_lane_equals_single_station_env():
    """Each fleet lane is bit-for-bit the single-station ChargaxEnv run."""
    fleet = FleetEnv(FLEET_ARCHS)
    params = fleet.default_params
    key = jax.random.key(0)
    fobs, fstate = fleet.reset(key, params)
    faction = fleet.sample_action(jax.random.key(1))
    fstep = jax.jit(fleet.step)

    # reference: each station alone, fed the exact per-station key stream
    refs = []
    for i, env in enumerate(fleet.envs):
        p = fleet.station_params(i, params)
        rk = jax.random.split(key, fleet.n_stations)[i]
        _, s = env.reset(rk, p)
        refs.append((env, jax.jit(env.step), p, s))

    for t in range(40):
        k = jax.random.key(500 + t)
        fobs, fstate, freward, fdone, finfo = fstep(k, fstate, faction, params)
        keys = jax.random.split(k, fleet.n_stations)
        for i, (env, step, p, s) in enumerate(refs):
            obs, s, r, d, info = step(keys[i], s, faction[i], p)
            refs[i] = (env, step, p, s)
            lane = jax.tree_util.tree_map(lambda x: x[i], fstate)
            _assert_lanes_equal(lane, s, env.n_evse, ctx=f"station {i} step {t}")
            assert np.array_equal(np.asarray(fobs)[i], np.asarray(obs)), (i, t)
            assert np.array_equal(float(freward[i]), float(r)), (i, t)
        # fleet aggregates are broadcast to (S,): uniform info leaf shapes
        assert finfo["fleet_reward"].shape == (fleet.n_stations,)
        assert float(finfo["fleet_reward"][0]) == pytest.approx(
            float(jnp.sum(freward)), rel=1e-6
        )


def test_fleet_24h_rollout_single_vmapped_scan():
    """Acceptance: >= 3 heterogeneous architectures, jitted 24h scan rollout."""
    fleet = FleetEnv(
        FLEET_ARCHS,
        scenarios=["shopping_pv_tou", "work_solar_summer", "highway_demand_charge"],
    )
    params = fleet.default_params
    steps = fleet.config.episode_steps

    @jax.jit
    def rollout(key):
        _, state = fleet.reset(key, params)

        def body(carry, _):
            key, state = carry
            key, ka, ks = jax.random.split(key, 3)
            action = jax.random.randint(
                ka, (fleet.n_stations, fleet.num_action_heads),
                0, fleet.num_actions_per_head,
            )
            _, state, r, d, _ = fleet.step(ks, state, action, params)
            return (key, state), (r, d)

        (_, state), (rewards, dones) = jax.lax.scan(
            body, (key, state), None, steps
        )
        return state, rewards, dones

    state, rewards, dones = rollout(jax.random.key(9))
    assert rewards.shape == (steps, fleet.n_stations)
    assert np.all(np.isfinite(np.asarray(rewards)))
    assert np.all(np.asarray(dones)[-1])  # every station finishes its day
    assert np.all(np.asarray(state.t) == steps)
    # heterogeneity survived padding: per-station EVSE masks differ
    masks = np.asarray(params.evse_mask)
    assert masks.shape[0] == 3 and len({int(m.sum()) for m in masks}) >= 2


def test_station_params_round_trip():
    fleet = FleetEnv(FLEET_ARCHS)
    for i, env in enumerate(fleet.envs):
        direct = env.make_params()
        sliced = fleet.station_params(i)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            direct,
            sliced,
        )


def test_fleet_requires_consistent_inputs():
    with pytest.raises(ValueError, match="at least one"):
        FleetEnv([])
    with pytest.raises(ValueError, match="one scenario entry per station"):
        FleetEnv(FLEET_ARCHS, scenarios=["shopping_flat"])


def test_fleet_info_uniform_and_steppable_under_outer_vmap():
    """Every info leaf is (S,), so tree_map stacking works when the fleet is
    nested under an outer vmap (regression: scalar fleet_reward/fleet_profit
    used to break auto-reset/stacking of the info pytree)."""
    fleet = FleetEnv(["paper_16", "deep_4x4"])
    params = fleet.default_params
    _, _, reward, _, info = fleet.step(
        jax.random.key(1),
        fleet.reset(jax.random.key(0), params)[1],
        fleet.sample_action(jax.random.key(2)),
        params,
    )
    shapes = {k: v.shape for k, v in info.items()}
    assert set(shapes.values()) == {(fleet.n_stations,)}, shapes
    np.testing.assert_allclose(
        np.asarray(info["fleet_reward"]),
        np.full(fleet.n_stations, float(jnp.sum(reward))),
        rtol=1e-6,
    )

    # outer vmap over a batch of fleet replicas: one program, (B, S) outputs
    B = 3
    keys = jax.random.split(jax.random.key(3), B)
    obs_b, state_b = jax.vmap(fleet.reset, in_axes=(0, None))(keys, params)
    act_b = jnp.stack(
        [fleet.sample_action(k) for k in jax.random.split(jax.random.key(4), B)]
    )
    step_b = jax.jit(jax.vmap(fleet.step, in_axes=(0, 0, 0, None)))
    obs_b, state_b, reward_b, done_b, info_b = step_b(keys, state_b, act_b, params)
    assert reward_b.shape == (B, fleet.n_stations)
    for k, v in info_b.items():
        assert v.shape == (B, fleet.n_stations), k
    # stacked aggregates match per-replica sums
    np.testing.assert_allclose(
        np.asarray(info_b["fleet_reward"])[:, 0],
        np.asarray(reward_b).sum(axis=1),
        rtol=1e-6,
    )
    # tree_map-based auto-reset composes: where() over uniform (B, S) leaves
    masked = jax.tree_util.tree_map(
        lambda x: jnp.where(done_b, jnp.zeros_like(x), x), info_b
    )
    assert jax.tree_util.tree_structure(masked) == jax.tree_util.tree_structure(
        info_b
    )


def test_fleet_mixed_none_and_named_scenarios():
    """None entries lower through the config's own world and stack cleanly."""
    fleet = FleetEnv(
        ["paper_16", "deep_4x4"], scenarios=[None, "shopping_pv_tou"]
    )
    params = fleet.default_params
    # scenario-normalised shapes fleet-wide: drift table + padded car rows
    assert params.car_probs.ndim == 3  # (S, 365, MAX_CAR_MODELS)
    _, state = fleet.reset(jax.random.key(0), params)
    _, state, r, _, _ = fleet.step(
        jax.random.key(1), state, fleet.sample_action(jax.random.key(2)), params
    )
    assert np.all(np.isfinite(np.asarray(r)))
