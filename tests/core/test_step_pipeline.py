"""Golden bit-identity: the staged pipeline vs the pre-refactor monolithic step.

``_monolithic_step`` below is a verbatim copy of the pre-refactor
``ChargaxEnv.step`` body (the single inline function this PR decomposed into
``decode -> request -> allocate -> deliver -> depart_arrive -> settle ->
advance_time -> observe``).  A jitted multi-step rollout through both must be
**bit-identical** — obs, full state pytree, reward, done, and every shared
info scalar — for the direct, delta and V2G configurations.  That is the
acceptance proof that the refactor (including the unified battery-as-pole
physics helpers and the inert default allocate stage) changed nothing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChargaxEnv, EnvConfig
from repro.core.rewards import compute_reward, step_energies
from repro.core.transition import (
    apply_actions,
    arrive_cars,
    charge_cars,
    decode_action,
    depart_cars,
)
from repro.utils import replace

jax.config.update("jax_platform_name", "cpu")


def _monolithic_step(env, key, state, action, params):
    """The pre-refactor ChargaxEnv.step body, verbatim (golden reference)."""
    cfg = env.config
    dt = cfg.dt_hours

    # -- decode action ------------------------------------------------
    if cfg.action_mode == "direct":
        tgt_evse, tgt_batt = decode_action(
            action,
            cfg.discretization,
            cfg.allow_v2g,
            params.evse_max_current,
            params.batt_max_current,
            v2g_mask=params.evse_v2g_mask,
        )
    elif cfg.action_mode == "delta":  # paper's additive form
        d_evse, d_batt = decode_action(
            action,
            cfg.discretization,
            True,  # deltas may be negative even without v2g...
            params.evse_max_current,
            params.batt_max_current,
        )
        tgt_evse = state.evse_current + d_evse
        if not cfg.allow_v2g:
            tgt_evse = jnp.maximum(tgt_evse, 0.0)  # ...but targets may not
        else:  # charge-only hardware never targets negative amps
            tgt_evse = jnp.where(
                params.evse_v2g_mask > 0.5, tgt_evse, jnp.maximum(tgt_evse, 0.0)
            )
        tgt_batt = state.batt_current + d_batt
    else:
        raise ValueError(f"unknown action_mode {cfg.action_mode!r}")

    # -- 4-stage transition -------------------------------------------
    applied = apply_actions(params, state, tgt_evse, tgt_batt, dt)
    charged = charge_cars(params, state, applied, dt)
    departed = depart_cars(charged.state)
    key, k_arr = jax.random.split(key)
    arrived = arrive_cars(params, departed.state, k_arr)

    # -- reward ---------------------------------------------------------
    spd = state.price_buy.shape[0]
    e_pv = (
        params.pv_kw_table[
            jnp.mod(state.day, params.pv_kw_table.shape[0]),
            jnp.mod(state.t, spd),
        ]
        * dt
    )
    energies = step_energies(
        params, charged.e_car, charged.e_batt_net, e_pv, charged.e_repaid
    )
    p_buy = state.price_buy[jnp.mod(state.t, spd)]
    reward, pi, pen = compute_reward(
        params,
        energies,
        p_buy,
        applied.constraint_excess,
        departed.missing_kwh,
        departed.overtime_steps,
        departed.early_steps,
        arrived.n_rejected,
        charged.e_car,
        state.t,
        state.price_buy,
        dt,
    )

    # -- calendar rollover -----------------------------------------------
    t_next = state.t + 1
    n_days = params.price_buy_table.shape[0]
    midnight = jnp.mod(t_next, spd) == 0
    day_next = jnp.where(midnight, jnp.mod(state.day + 1, n_days), state.day)
    price_next = jnp.where(
        midnight, params.price_buy_table[day_next], state.price_buy
    )
    new_state = replace(
        arrived.state,
        t=t_next,
        day=day_next,
        price_buy=price_next,
        profit_cum=state.profit_cum + pi,
    )
    done = new_state.t >= cfg.episode_steps
    info = {
        "profit": pi,
        "reward": reward,
        "e_net": energies.e_net,
        "e_grid_net": energies.e_grid_net,
        "e_pv": energies.e_pv,
        "constraint_excess": pen.constraint,
        "missing_kwh": pen.satisfaction_time,
        "overtime_steps": departed.overtime_steps,
        "rejected": pen.rejected,
        "arrived": arrived.n_arrived.astype(jnp.float32),
        "price_buy": p_buy,
        "energy_delivered": jnp.sum(jnp.maximum(charged.e_car, 0.0)),
        "energy_discharged": jnp.sum(jnp.maximum(-charged.e_car, 0.0)),
        "v2g_debt": jnp.sum(new_state.v2g_debt),
    }
    obs = env.observe(new_state, params)
    return obs, new_state, reward, done, info


CONFIGS = {
    "direct": EnvConfig(),
    "delta": EnvConfig(action_mode="delta"),
    "v2g": EnvConfig(allow_v2g=True),
    "delta_v2g_nobatt": EnvConfig(action_mode="delta", allow_v2g=True, battery=False),
}


def _rollout(step_fn, env, params, n_steps=40, seed=0):
    obs0, state = env.reset(jax.random.key(seed), params)

    @jax.jit
    def run(state):
        def body(carry, k):
            state, _ = carry
            action = env.sample_action(jax.random.fold_in(k, 1))
            out = step_fn(k, state, action, params)
            obs, new_state, reward, done, info = out
            return (new_state, reward), (obs, reward, done, info)

        keys = jax.random.split(jax.random.key(seed + 100), n_steps)
        (state_f, _), traj = jax.lax.scan(body, (state, jnp.float32(0.0)), keys)
        return state_f, traj

    return run(state)


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_staged_pipeline_bit_identical_to_monolithic_step(name):
    env = ChargaxEnv(CONFIGS[name])
    params = env.default_params

    state_new, (obs_n, rew_n, done_n, info_n) = _rollout(env.step, env, params)
    state_old, (obs_o, rew_o, done_o, info_o) = _rollout(
        lambda k, s, a, p: _monolithic_step(env, k, s, a, p), env, params
    )

    np.testing.assert_array_equal(np.asarray(obs_n), np.asarray(obs_o))
    np.testing.assert_array_equal(np.asarray(rew_n), np.asarray(rew_o))
    np.testing.assert_array_equal(np.asarray(done_n), np.asarray(done_o))
    for k in info_o:  # golden info keys; the pipeline adds grid/* on top
        np.testing.assert_array_equal(
            np.asarray(info_n[k]), np.asarray(info_o[k]), err_msg=f"info[{k!r}]"
        )
    for f, a, b in zip(
        state_new._fields if hasattr(state_new, "_fields") else [],
        jax.tree_util.tree_leaves(state_new),
        jax.tree_util.tree_leaves(state_old),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=f)


def test_pipeline_adds_grid_kpis_to_info():
    env = ChargaxEnv(EnvConfig())
    obs, state = env.reset(jax.random.key(0))
    ts = env.step(jax.random.key(1), state, env.sample_action(jax.random.key(2)))
    for k in ("grid/power_drawn", "grid/cap", "grid/violation", "grid/setpoint_dev"):
        assert k in ts.info, k
    # default params: unlimited cap, nothing curtailed, nothing violated
    assert float(ts.info["grid/violation"]) == 0.0
    assert float(ts.info["grid/cap"]) == pytest.approx(1e9)
