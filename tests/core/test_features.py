"""Paper optional features: V2G discharging, delta action mode, scenarios."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChargaxEnv, EnvConfig, RewardWeights
from repro.utils import replace


def _plugged_state(env, key, soc=0.8):
    _, state = env.reset(key)
    n = env.n_evse
    occ = jnp.ones((n,), jnp.float32)
    return replace(
        state,
        occupied=occ,
        soc=occ * soc,
        e_remain=occ * 20.0,
        t_remain=jnp.full((n,), 50, jnp.int32),
        cap=occ * 60.0,
        rbar=occ * 200.0,
        rhat=occ * 200.0,
        tau=occ * 0.8,
        user_type=occ * 0.0,
    )


def test_v2g_discharging_feeds_grid():
    """allow_v2g: min action level discharges cars; energy flows to grid."""
    env = ChargaxEnv(EnvConfig(allow_v2g=True))
    state = _plugged_state(env, jax.random.key(0))
    a = jnp.zeros((env.num_action_heads,), jnp.int32)  # level 0 = -100%
    a = a.at[-1].set(env.config.discretization)  # battery idle
    _, s2, r, _, info = env.step(jax.random.key(1), state, a)
    assert float(info["e_net"]) < 0  # net energy OUT of cars
    assert float(info["e_grid_net"]) < 0  # pushed into the grid
    # SoC dropped on (still-plugged) discharged cars
    assert bool(jnp.all(s2.soc[s2.occupied > 0.5] < 0.8))


def test_no_v2g_blocks_discharge():
    env = ChargaxEnv(EnvConfig(allow_v2g=False))
    state = _plugged_state(env, jax.random.key(0))
    a = jnp.zeros((env.num_action_heads,), jnp.int32)
    a = a.at[-1].set(env.config.discretization)
    _, s2, _, _, info = env.step(jax.random.key(1), state, a)
    assert float(info["e_net"]) >= 0.0


def test_battery_discharge_offsets_grid_draw():
    """Station battery discharging reduces net grid energy (peak shaving)."""
    env = ChargaxEnv(EnvConfig(battery=True))
    state = _plugged_state(env, jax.random.key(0), soc=0.3)
    d = env.config.discretization
    charge_only = jnp.full((env.num_action_heads,), 2 * d, jnp.int32).at[-1].set(d)
    with_batt = charge_only.at[-1].set(0)  # battery full discharge
    _, _, _, _, i1 = env.step(jax.random.key(1), state, charge_only)
    _, _, _, _, i2 = env.step(jax.random.key(1), state, with_batt)
    assert float(i2["e_grid_net"]) < float(i1["e_grid_net"])


def test_delta_action_mode_accumulates():
    """Paper's additive formulation: I(t) = clip(I(t-1) + a)."""
    env = ChargaxEnv(EnvConfig(action_mode="delta"))
    state = _plugged_state(env, jax.random.key(0), soc=0.3)
    d = env.config.discretization
    # +50% of Imax each step on port 0, hold elsewhere
    a = jnp.full((env.num_action_heads,), d, jnp.int32).at[0].set(d + d // 2)
    _, s1, _, _, _ = env.step(jax.random.key(1), state, a)
    i_first = float(s1.evse_current[0])
    assert i_first > 0
    s1 = replace(s1, t_remain=jnp.maximum(s1.t_remain, 10))  # keep car plugged
    _, s2, _, _, _ = env.step(jax.random.key(2), s1, a)
    # current accumulated (until clipped by car curve / port limit)
    assert float(s2.evse_current[0]) >= i_first - 1e-3


@pytest.mark.parametrize("scenario", ["highway", "residential", "work", "shopping"])
@pytest.mark.parametrize("traffic", ["low", "high"])
def test_all_bundled_scenarios_run(scenario, traffic):
    env = ChargaxEnv(EnvConfig(scenario=scenario, traffic=traffic))
    key = jax.random.key(0)
    obs, state = env.reset(key)
    step = jax.jit(env.step)
    for _ in range(24):
        key, ka, ks = jax.random.split(key, 3)
        obs, state, r, _, _ = step(ks, state, env.sample_action(ka))
    assert bool(jnp.isfinite(obs).all()) and bool(jnp.isfinite(r))


@pytest.mark.parametrize("arch", ["single_ac_16", "single_dc_16", "mixed_8_8", "deep_4x4"])
def test_all_bundled_architectures_run(arch):
    env = ChargaxEnv(EnvConfig(architecture=arch))
    obs, state = env.reset(jax.random.key(0))
    _, s2, r, _, _ = env.step(jax.random.key(1), state, env.sample_action(jax.random.key(2)))
    assert bool(jnp.isfinite(r))


def test_reward_weights_sweep_no_recompile():
    """alpha sweeps ride through params — same jitted step (paper flexibility)."""
    env = ChargaxEnv(EnvConfig())
    step = jax.jit(env.step, static_argnums=())
    p1 = env.make_params(weights=RewardWeights(satisfaction_time=0.0))
    p2 = env.make_params(weights=RewardWeights(satisfaction_time=5.0, rejected=2.0))
    _, state = env.reset(jax.random.key(0))
    a = env.sample_action(jax.random.key(1))
    _, _, r1, _, _ = step(jax.random.key(2), state, a, p1)
    _, _, r2, _, _ = step(jax.random.key(2), state, a, p2)
    assert np.isfinite(float(r1)) and np.isfinite(float(r2))
