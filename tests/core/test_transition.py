"""Unit tests for the 4-stage transition function (paper App. A.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChargaxEnv, EnvConfig, make_baseline_max_action
from repro.core.transition import (
    charge_rate,
    constraint_scale,
    decode_action,
    discharge_rate,
)
from repro.utils import replace


def _max_action(env):
    """The max-charge baseline policy's (constant, unbatched) action."""
    obs = jnp.zeros(env.observation_space.shape)
    return make_baseline_max_action(env)(None, None, obs)


@pytest.fixture(scope="module")
def env():
    return ChargaxEnv(EnvConfig())


@pytest.fixture(scope="module")
def params(env):
    return env.default_params


def test_charge_curve_piecewise_linear():
    rbar, tau = 100.0, 0.8
    # bulk region: full rate
    assert charge_rate(jnp.float32(0.3), rbar, tau) == 100.0
    assert charge_rate(jnp.float32(0.8), rbar, tau) == 100.0
    # absorption region: linear taper to 0 at SoC=1
    np.testing.assert_allclose(charge_rate(jnp.float32(0.9), rbar, tau), 50.0, rtol=1e-5)
    np.testing.assert_allclose(charge_rate(jnp.float32(1.0), rbar, tau), 0.0, atol=1e-4)


def test_discharge_curve_is_flip():
    rbar, tau = 80.0, 0.75
    for soc in [0.1, 0.4, 0.9]:
        np.testing.assert_allclose(
            discharge_rate(jnp.float32(soc), rbar, tau),
            charge_rate(jnp.float32(1.0 - soc), rbar, tau),
            rtol=1e-6,
        )


def test_decode_action_direct_levels():
    imax = jnp.array([10.0, 20.0])
    bmax = jnp.float32(5.0)
    # level 2D = +100%, level D = 0, level 0 = -100%
    a = jnp.array([20, 10, 0], dtype=jnp.int32)
    e, b = decode_action(a, 10, True, imax, bmax)
    np.testing.assert_allclose(e, [10.0, 0.0])
    np.testing.assert_allclose(b, -5.0)
    # without v2g, port targets clip at 0
    e2, _ = decode_action(jnp.array([0, 0, 0], jnp.int32), 10, False, imax, bmax)
    np.testing.assert_allclose(e2, [0.0, 0.0])


def test_constraint_scale_enforces_budget():
    member = jnp.array([[1.0, 1.0, 1.0], [1.0, 1.0, 0.0]])
    budget = jnp.array([30.0, 10.0])
    currents = jnp.array([20.0, 20.0, 20.0])
    scale, excess = constraint_scale(currents, member, budget)
    scaled = currents * scale
    assert float(member @ jnp.abs(scaled) - budget)[0] if False else True
    loads = member @ jnp.abs(scaled)
    assert bool(jnp.all(loads <= budget + 1e-3))
    assert excess > 0


def test_constraint_scale_noop_when_within_budget():
    member = jnp.ones((1, 4))
    budget = jnp.array([100.0])
    currents = jnp.array([10.0, -5.0, 0.0, 3.0])
    scale, excess = constraint_scale(currents, member, budget)
    np.testing.assert_allclose(scale, 1.0)
    assert excess == 0.0


def test_empty_ports_draw_nothing(env, params):
    key = jax.random.key(1)
    _, state = env.reset(key)
    a = _max_action(env)
    _, s2, _, _, _ = env.step(key, state, a)
    # no cars at t=0 -> all port currents zero even at max action
    np.testing.assert_allclose(s2.evse_current, 0.0)


def test_charging_decreases_remaining_energy(env, params):
    key = jax.random.key(2)
    _, state = env.reset(key)
    n = env.n_evse
    # plug a car into port 0 manually
    state = replace(
        state,
        occupied=state.occupied.at[0].set(1.0),
        soc=state.soc.at[0].set(0.3),
        e_remain=state.e_remain.at[0].set(30.0),
        t_remain=state.t_remain.at[0].set(100),
        cap=state.cap.at[0].set(60.0),
        rbar=state.rbar.at[0].set(200.0),
        rhat=state.rhat.at[0].set(200.0),
        tau=state.tau.at[0].set(0.8),
        user_type=state.user_type.at[0].set(0.0),
    )
    a = _max_action(env)
    _, s2, r, _, info = env.step(key, state, a)
    assert s2.e_remain[0] < 30.0
    assert s2.soc[0] > 0.3
    # energy bookkeeping: delta soc * cap == delivered energy
    delivered = 30.0 - s2.e_remain[0]
    np.testing.assert_allclose((s2.soc[0] - 0.3) * 60.0, delivered, rtol=1e-4)


def test_time_sensitive_car_departs_at_deadline(env, params):
    key = jax.random.key(3)
    _, state = env.reset(key)
    state = replace(
        state,
        occupied=state.occupied.at[0].set(1.0),
        soc=state.soc.at[0].set(0.5),
        e_remain=state.e_remain.at[0].set(10.0),
        t_remain=state.t_remain.at[0].set(1),  # leaves after this step
        cap=state.cap.at[0].set(60.0),
        rbar=state.rbar.at[0].set(0.0),  # cannot charge: all 10 kWh go missing
        user_type=state.user_type.at[0].set(0.0),
    )
    zero_a = jnp.full((env.num_action_heads,), env.config.discretization, jnp.int32)
    _, s2, _, _, info = env.step(key, state, zero_a)
    # possibly a new arrival takes the port, but the missing-kWh stat recorded
    assert float(s2.missing_kwh_cum) == pytest.approx(10.0, rel=1e-5)


def test_charge_sensitive_car_departs_when_full(env, params):
    key = jax.random.key(4)
    _, state = env.reset(key)
    state = replace(
        state,
        occupied=state.occupied.at[0].set(1.0),
        soc=state.soc.at[0].set(0.9),
        e_remain=state.e_remain.at[0].set(0.5),  # tiny remaining request
        t_remain=state.t_remain.at[0].set(50),
        cap=state.cap.at[0].set(60.0),
        rbar=state.rbar.at[0].set(300.0),
        rhat=state.rhat.at[0].set(300.0),
        tau=state.tau.at[0].set(0.95),
        user_type=state.user_type.at[0].set(1.0),
    )
    a = _max_action(env)
    _, s2, _, _, _ = env.step(key, state, a)
    # car got its 0.5 kWh and left: port free or re-occupied by a new arrival,
    # but its early-finish recorded nothing in overtime
    assert float(s2.overtime_steps_cum) == 0.0


def test_episode_terminates(env):
    key = jax.random.key(5)
    _, state = env.reset(key)
    a = _max_action(env)
    step = jax.jit(env.step)
    done = False
    for i in range(env.config.episode_steps):
        key, k = jax.random.split(key)
        _, state, _, done, _ = step(k, state, a)
    assert bool(done)


def test_exploring_starts_vary_day(env):
    days = set()
    for seed in range(8):
        _, state = env.reset(jax.random.key(seed))
        days.add(int(state.day))
    assert len(days) > 2  # paper App. B.1: random day per episode
