"""Property tests for the allocate stage and the grid-coupled fleet step.

Satellite acceptance (ISSUE 8):
  * fleet draw never exceeds the cap (recomputed from curtailed currents),
  * curtailment conserves energy: requested - delivered == shed == violation
    when the cap binds,
  * coupled-step with an infinite cap is bit-identical to the uncoupled vmap
    path,
all at dt in {5, 15, 60} minutes; plus the grid_aware baseline holding
``grid/violation == 0`` on the tight-transformer scenario and the grid KPIs
riding the LogWrapper metrics accumulator.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios
from repro.core import ChargaxEnv, EnvConfig, FleetEnv
from repro.core import transition
from repro.envs import LogWrapper

jax.config.update("jax_platform_name", "cpu")

DTS = [5.0, 15.0, 60.0]


def _busy_state_and_applied(dt_minutes, seed=0, n_steps=8):
    """Roll a max-charge env a few steps so ports are occupied, then return
    (env, params, state, applied) with everyone requesting max current."""
    env = ChargaxEnv(EnvConfig(dt_minutes=dt_minutes, traffic="high"))
    params = env.default_params
    obs, state = env.reset(jax.random.key(seed), params)
    # fast-forward to midday so the arrival process actually fills ports
    from repro.utils import replace

    state = replace(state, t=jnp.int32(env.config.steps_per_day // 2))
    d = env.config.discretization
    a = jnp.full(env.action_space.shape, 2 * d, env.action_space.dtype)
    a = a.at[-1].set(d)
    for i in range(n_steps):
        state = env.step(jax.random.key(seed * 100 + i), state, a, params).state
    applied = env.request_stage(state, a, params)
    return env, params, state, applied


@pytest.mark.parametrize("dt", DTS)
def test_allocate_draw_never_exceeds_cap(dt):
    env, params, state, applied = _busy_state_and_applied(dt)
    p_req = float(transition.requested_power_kw(params, applied))
    assert p_req > 0.0  # occupied ports actually draw
    for cap in [0.5 * p_req, 0.9 * p_req, p_req, 2.0 * p_req]:
        alloc = transition.allocate(params, state, applied, cap_kw=jnp.float32(cap))
        # recompute the draw from the *curtailed* currents — the invariant is
        # on physics, not on the reported power_kw field
        p_drawn = float(transition.requested_power_kw(params, alloc.applied))
        assert p_drawn <= cap * (1.0 + 1e-5), (cap, p_drawn)
        assert float(alloc.power_kw) == pytest.approx(min(p_req, cap), rel=1e-6)


@pytest.mark.parametrize("dt", DTS)
def test_allocate_conserves_power(dt):
    """Shed power is exactly accounted: requested - drawn == violation when
    the cap binds, 0 when it does not (nothing vanishes, nothing appears)."""
    env, params, state, applied = _busy_state_and_applied(dt)
    p_req = float(transition.requested_power_kw(params, applied))
    for cap in [0.4 * p_req, p_req, 3.0 * p_req]:
        alloc = transition.allocate(params, state, applied, cap_kw=jnp.float32(cap))
        shed = p_req - float(alloc.power_kw)
        assert shed == pytest.approx(float(alloc.violation_kw), abs=1e-4 * p_req)
        # and the curtailed currents deliver what power_kw reports
        p_drawn = float(transition.requested_power_kw(params, alloc.applied))
        assert p_drawn == pytest.approx(float(alloc.power_kw), rel=1e-5)


@pytest.mark.parametrize("dt", DTS)
def test_allocate_unlimited_cap_is_bitwise_noop(dt):
    env, params, state, applied = _busy_state_and_applied(dt)
    alloc = transition.allocate(params, state, applied)  # default: unlimited
    for a, b in zip(alloc.applied, applied):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(alloc.violation_kw) == 0.0


@pytest.mark.parametrize("dt", DTS)
def test_coupled_fleet_infinite_cap_bit_identical_to_uncoupled(dt):
    cfg = EnvConfig(dt_minutes=dt)
    archs = ["paper_16", "deep_4x4"]
    plain = FleetEnv(archs, cfg)
    coupled = FleetEnv(archs, cfg, couple_grid=True)
    params = plain.default_params

    def rollout(fleet):
        obs, state = fleet.reset(jax.random.key(3), params)

        @jax.jit
        def run(state):
            def body(state, k):
                action = fleet.sample_action(jax.random.fold_in(k, 7))
                obs, state, reward, done, info = fleet.step(k, state, action, params)
                return state, (obs, reward, info["profit"], info["grid/violation"])

            keys = jax.random.split(jax.random.key(11), 24)
            return jax.lax.scan(body, state, keys)

        return run(state)

    state_a, (obs_a, rew_a, prof_a, viol_a) = rollout(plain)
    state_b, (obs_b, rew_b, prof_b, viol_b) = rollout(coupled)
    np.testing.assert_array_equal(np.asarray(obs_a), np.asarray(obs_b))
    np.testing.assert_array_equal(np.asarray(rew_a), np.asarray(rew_b))
    np.testing.assert_array_equal(np.asarray(prof_a), np.asarray(prof_b))
    assert float(np.abs(np.asarray(viol_b)).max()) == 0.0
    for a, b in zip(
        jax.tree_util.tree_leaves(state_a), jax.tree_util.tree_leaves(state_b)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_coupled_fleet_shared_cap_binds():
    """With a shared tight feeder, total fleet draw stays under the fleet cap
    and violations are attributed pro-rata (sum equals total excess)."""
    sc = scenarios.make("grid_tight_transformer").evolve(traffic="high")
    fleet = FleetEnv(
        ["paper_16", "paper_16"], scenarios=[sc, sc], couple_grid=True
    )
    params = fleet.default_params
    cap_kw = 300.0  # the scenario's feeder cap, shared fleet-wide
    obs, state = fleet.reset(jax.random.key(0), params)
    # fast-forward every station's clock to midday so ports fill up
    from repro.utils import replace

    state = replace(
        state, t=jnp.full_like(state.t, fleet.config.steps_per_day // 2)
    )
    d = fleet.config.discretization
    a = jnp.full((fleet.n_stations, fleet.num_action_heads), 2 * d, jnp.int32)
    a = a.at[:, -1].set(d)
    saw_binding = False
    for i in range(16):
        obs, state, reward, done, info = fleet.step(jax.random.key(i), state, a, params)
        total_drawn = float(jnp.sum(info["grid/power_drawn"]))
        assert total_drawn <= cap_kw * (1.0 + 1e-5)
        if float(jnp.sum(info["grid/violation"])) > 0.0:
            saw_binding = True
    assert saw_binding  # two max-charging paper_16s cannot fit in 300 kW


def test_grid_aware_baseline_zero_violation_on_tight_transformer():
    """Acceptance: grid/violation == 0 for grid_aware on grid_tight_transformer."""
    from repro.rl.baselines import BASELINES

    env = ChargaxEnv(EnvConfig())
    params = scenarios.make("grid_tight_transformer").make_params(env)
    policy = BASELINES["grid_aware"](env, params)
    max_policy = BASELINES["max_charge"](env)

    @jax.jit
    def rollout(pol_action):
        obs, state = env.reset(jax.random.key(0), params)

        def body(carry, k):
            obs, state = carry
            ts = env.step(k, state, pol_action, params)
            return (ts.obs, ts.state), (ts.info["grid/violation"], ts.info["profit"])

        keys = jax.random.split(jax.random.key(1), env.config.episode_steps)
        _, (viol, profit) = jax.lax.scan(body, (obs, state), keys)
        return viol, profit

    obs0, _ = env.reset(jax.random.key(0), params)
    viol_aware, _ = rollout(policy(None, jax.random.key(2), obs0))
    viol_max, _ = rollout(max_policy(None, jax.random.key(2), obs0))
    assert float(jnp.max(viol_aware)) == 0.0
    assert float(jnp.max(viol_max)) > 0.0  # the naive baseline does overshoot


def test_grid_kpis_ride_the_log_wrapper_accumulator():
    env = LogWrapper(
        ChargaxEnv(EnvConfig()),
        metrics=("grid/power_drawn", "grid/violation", "profit"),
    )
    params = scenarios.make("grid_tight_transformer").make_params(env.unwrapped)
    obs, state = env.reset(jax.random.key(0), params)
    for i in range(4):
        ts = env.step(jax.random.key(i), state, env.sample_action(jax.random.key(i + 50)), params)
        state = ts.state
    acc = state.metrics
    assert acc is not None
    assert set(acc.names) >= {"grid/power_drawn", "grid/violation", "profit"}
    assert float(acc.count) == 4.0
    assert np.isfinite(float(acc.sums["grid/power_drawn"]))
