"""Unit tests for the station-tree flattening."""
import numpy as np
import pytest

from repro.core import station


def test_single_type_layout():
    lay = station.single_charger_type(8, dc=True)
    assert lay.n_evse == 8
    assert lay.n_nodes == 1
    assert lay.member.shape == (1, 8)
    assert np.all(lay.member == 1.0)
    assert np.all(lay.evse_is_dc == 1.0)
    # undersized grid: root cap < sum of port caps
    assert lay.node_limit[0] < lay.evse_max_current.sum()


def test_paper_16_layout():
    lay = station.multi_charger_type(10, 6)
    assert lay.n_evse == 16
    assert lay.n_nodes == 3  # root + per-type splitters
    # root contains every leaf
    assert np.all(lay.member[0] == 1.0)
    # the two type splitters partition the leaves
    assert np.all(lay.member[1] + lay.member[2] == 1.0)
    assert lay.member[1].sum() == 10  # DC group
    assert np.all(lay.evse_is_dc[:10] == 1.0)
    assert np.all(lay.evse_is_dc[10:] == 0.0)


def test_deep_split_nesting():
    lay = station.deep_split(4, 4)
    assert lay.n_evse == 16
    assert lay.n_nodes == 5
    for g in range(1, 5):
        assert lay.member[g].sum() == 4
    # nested: every group leaf is also a root leaf
    assert np.all((lay.member[1:].sum(axis=0) == 1.0))


def test_path_efficiency_is_product():
    lay = station.multi_charger_type(2, 2)
    # root eta=0.98, group eta=0.99, port eta=0.95
    expected = 0.98 * 0.99 * 0.95
    np.testing.assert_allclose(lay.evse_path_eff, expected, rtol=1e-6)


def test_custom_tree():
    root = station.Node(
        max_current=100.0,
        efficiency=0.97,
        children=[
            station.Node(max_current=40.0, children=[station.ac_evse(), station.ac_evse()]),
            station.dc_evse(),
        ],
    )
    lay = station.flatten_tree(root)
    assert lay.n_evse == 3
    assert lay.n_nodes == 2
    assert lay.member[0].sum() == 3
    assert lay.member[1].sum() == 2


def test_empty_tree_raises():
    with pytest.raises(ValueError):
        station.flatten_tree(station.Node(max_current=10.0, children=[]))


def test_max_power():
    assert station.ac_evse().max_power_kw == pytest.approx(11.08, abs=0.05)
    assert station.dc_evse().max_power_kw == pytest.approx(150.0)
