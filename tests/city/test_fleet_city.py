"""City-coupled FleetEnv: zero-pop inertness, arrival injection, sweep, no-recompile."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.city import make_city, sweep_layouts
from repro.core import EnvConfig, FleetEnv
from repro.rl.baselines import max_charge_policy

jax.config.update("jax_platform_name", "cpu")

ARCHS = ["paper_16", "deep_4x4", "single_dc_8"]


def _rollout(fleet, n_steps=50):
    """Jitted rollout; returns stacked obs + rewards for bit comparison."""
    params = fleet.default_params
    step = jax.jit(fleet.step)
    _, state = fleet.reset(jax.random.key(0), params)
    obs_t, rew_t = [], []
    for i in range(n_steps):
        a = fleet.sample_action(jax.random.key(1000 + i))
        obs, state, r, _, info = step(jax.random.key(i), state, a, params)
        obs_t.append(np.asarray(obs))
        rew_t.append(np.asarray(r))
    return np.stack(obs_t), np.stack(rew_t), state, info


def test_zero_population_city_is_bit_identical_to_uncoupled():
    """Acceptance: a city-coupled fleet at population=0 produces *bit-identical*
    trajectories to the uncoupled fleet — coupling adds exactly 0.0 to every
    station's Poisson rate, so the draws (same key) cannot move."""
    city0 = make_city(n_stations=len(ARCHS), population=0.0)
    ref_obs, ref_rew, ref_state, _ = _rollout(FleetEnv(ARCHS, EnvConfig()))
    got_obs, got_rew, got_state, info = _rollout(
        FleetEnv(ARCHS, EnvConfig(), city=city0)
    )
    assert np.array_equal(got_obs, ref_obs)
    assert np.array_equal(got_rew, ref_rew)
    assert np.array_equal(
        np.asarray(got_state.cars_served), np.asarray(ref_state.cars_served)
    )
    # the coupling seam is live (info keys present), just inert
    assert np.all(np.asarray(info["city/arrival_rate"]) == 0.0)


def test_coupled_fleet_receives_city_arrivals():
    """A real population injects demand: per-station rates conserve the
    stream, and the fleet serves strictly more cars than the uncoupled run."""
    city = make_city(
        "city_ring_evening", n_stations=len(ARCHS), population=5000.0
    )
    _, _, ref_state, _ = _rollout(FleetEnv(ARCHS, EnvConfig()))
    _, _, got_state, info = _rollout(FleetEnv(ARCHS, EnvConfig(), city=city))

    rates = np.asarray(info["city/arrival_rate"])
    assert rates.shape == (len(ARCHS),)
    assert np.all(rates >= 0.0)
    # conservation at the fleet seam: rates + overflow == stream (broadcast)
    total = rates.sum() + float(np.asarray(info["city/overflow"])[0])
    np.testing.assert_allclose(total, float(np.asarray(info["city/stream"])[0]), rtol=1e-4)
    assert np.sum(np.asarray(got_state.cars_served)) > np.sum(
        np.asarray(ref_state.cars_served)
    )


def test_fleet_builds_city_from_scenario_name():
    fleet = FleetEnv(ARCHS, EnvConfig(), city="city_clustered_core")
    assert fleet.city is not None
    assert fleet.city.n_stations == len(ARCHS)
    assert float(fleet.city.population) == 3200.0


def test_fleet_rejects_station_count_mismatch():
    with pytest.raises(ValueError):
        FleetEnv(ARCHS, EnvConfig(), city=make_city(n_stations=5))


def test_city_swap_is_a_pure_array_swap():
    """Swapping which city a fleet serves must not recompile the step — the
    same one-jit-entry contract the scenario catalog keeps."""
    from repro.obs import cache_entries, compile_guard

    fleet = FleetEnv(ARCHS, EnvConfig())
    params = fleet.default_params
    step = jax.jit(fleet.step_with_city)
    _, state = fleet.reset(jax.random.key(0), params)
    a = fleet.sample_action(jax.random.key(1))

    cities = [
        make_city(n, n_stations=len(ARCHS))
        for n in ("city_ring_evening", "city_grid_commuters", "city_price_shoppers")
    ]
    step(jax.random.key(2), state, a, params, cities[0])  # the one compile
    assert cache_entries(step) == 1
    with compile_guard("city swap"):
        for c in cities[1:]:
            step(jax.random.key(2), state, a, params, c)
    assert cache_entries(step) == 1


def test_sweep_layouts_scores_candidates():
    fleet = FleetEnv(ARCHS, EnvConfig(), city="city_ring_evening")
    cities = [
        make_city("city_ring_evening", n_stations=len(ARCHS), layout=kind)
        for kind in ("ring", "clustered")
    ]
    # constant per-station policy from the padded single-station template;
    # its (H,) action broadcasts over the fleet's (S, obs_dim) observations
    out = sweep_layouts(
        fleet, cities, max_charge_policy(fleet.template), steps=24,
        key=jax.random.key(3),
    )
    assert out["profit"].shape == (2,)
    assert out["cars_served"].shape == (2,)
    assert out["overflow"].shape == (2,)
    assert int(out["best"]) in (0, 1)
    assert int(out["best"]) == int(np.argmax(np.asarray(out["profit"])))
