"""Demand-allocation invariants: conservation, determinism, zero-pop inertness.

The CI sharding job re-runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` so the 2-device
station-axis split is exercised on every push; the device-count-gated test
activates there.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.city import (
    CityParams,
    StationFeatures,
    allocate_demand,
    choice_logits,
    demand_zones,
    layout_xy,
    make_city,
    stream_rate,
)
from repro.utils import stack_pytrees

jax.config.update("jax_platform_name", "cpu")


def _city(population=2000.0, n_stations=4, **kw):
    return make_city(n_stations=n_stations, population=population, **kw)


def _features(n_stations=4, free=6.0):
    return StationFeatures(
        price=jnp.linspace(0.2, 0.5, n_stations),
        occupancy=jnp.linspace(0.0, 0.9, n_stations),
        free_ports=jnp.full((n_stations,), jnp.float32(free)),
    )


def test_conservation_and_nonnegativity():
    city = _city()
    for t in (0, 90, 200):
        stream = stream_rate(city, jnp.int32(3), jnp.int32(t))
        alloc = allocate_demand(stream, city, _features())
        total = float(jnp.sum(alloc.rates) + alloc.overflow)
        np.testing.assert_allclose(total, float(stream), rtol=1e-5)
        assert np.all(np.asarray(alloc.rates) >= 0.0)
        assert float(alloc.overflow) >= 0.0
        np.testing.assert_allclose(float(jnp.sum(alloc.shares)), 1.0, rtol=1e-5)


def test_capacity_clamp_and_overflow():
    """A station absorbs at most its free ports; an over-capacity stream
    produces city-wide overflow (balking drivers), never over-assignment."""
    city = _city(population=50_000.0)
    feats = _features(free=2.0)
    stream = jnp.float32(100.0)  # >> 4 stations x 2 free ports
    alloc = allocate_demand(stream, city, feats)
    assert np.all(np.asarray(alloc.rates) <= 2.0 + 1e-5)
    np.testing.assert_allclose(float(jnp.sum(alloc.rates)), 8.0, rtol=1e-5)
    np.testing.assert_allclose(float(alloc.overflow), 92.0, rtol=1e-5)


def test_zero_population_yields_exact_zero_rates():
    """Not approximately zero — *exactly* 0.0 bits, the property the fleet's
    zero-pop bit-identity (tests/city/test_fleet_city.py) rests on."""
    city = _city(population=0.0)
    stream = stream_rate(city, jnp.int32(0), jnp.int32(100))
    assert float(stream) == 0.0
    alloc = allocate_demand(stream, city, _features())
    assert np.all(np.asarray(alloc.rates) == 0.0)
    assert float(alloc.overflow) == 0.0


def test_allocation_bit_deterministic_under_vmap():
    """The same city/features give bit-identical splits whether allocated
    one-at-a-time or as a vmapped stack (the sweep_layouts access pattern)."""
    cities = [_city(population=p) for p in (800.0, 2000.0, 5000.0)]
    feats = _features()
    stream = jnp.float32(40.0)
    solo = [allocate_demand(stream, c, feats) for c in cities]
    stacked = jax.jit(jax.vmap(lambda c: allocate_demand(stream, c, feats)))(
        stack_pytrees(cities)
    )
    for i, ref in enumerate(solo):
        assert np.array_equal(np.asarray(stacked.rates[i]), np.asarray(ref.rates))
        assert np.array_equal(
            np.asarray(stacked.overflow[i]), np.asarray(ref.overflow)
        )


def test_price_and_queue_shift_shares():
    """Gravity/queue logits point the right way: a pricier or busier station
    attracts a smaller share, all else equal."""
    city = _city(w_dist=0.0)
    base = StationFeatures(
        price=jnp.full((4,), 0.3),
        occupancy=jnp.zeros(4),
        free_ports=jnp.full((4,), 100.0),
    )
    ref = allocate_demand(jnp.float32(10.0), city, base)
    pricey = allocate_demand(
        jnp.float32(10.0), city, base._replace(price=base.price.at[0].add(0.2))
    )
    busy = allocate_demand(
        jnp.float32(10.0), city, base._replace(occupancy=base.occupancy.at[0].set(0.8))
    )
    assert float(pricey.shares[0]) < float(ref.shares[0])
    assert float(busy.shares[0]) < float(ref.shares[0])


def test_choice_logits_shape_and_distance_decay():
    city = _city(w_price=0.0, w_queue=0.0)
    lg = choice_logits(city, _features())
    assert lg.shape == (city.n_zones, city.n_stations)
    # zone 0 is the core; with only distance in play, nearer stations win
    d = jnp.linalg.norm(city.station_xy - city.zone_xy[0], axis=-1)
    order_lg = np.argsort(np.asarray(lg[0]))
    order_d = np.argsort(-np.asarray(d))
    assert list(order_lg) == list(order_d)


def test_layout_and_zone_builders_validate():
    assert layout_xy("ring", 6).shape == (6, 2)
    assert layout_xy("grid", 5).shape == (5, 2)
    assert layout_xy("clustered", 3).shape == (3, 2)
    with pytest.raises(ValueError):
        layout_xy("hexagonal", 4)
    with pytest.raises(ValueError):
        layout_xy("ring", 0)
    xy, frac = demand_zones(4)
    assert xy.shape == (4, 2) and frac.shape == (4,)
    np.testing.assert_allclose(frac.sum(), 1.0, rtol=1e-6)
    with pytest.raises(ValueError):
        demand_zones(0)


def test_make_city_from_scenario_and_overrides():
    city = make_city("city_grid_commuters", n_stations=6)
    assert isinstance(city, CityParams)
    assert city.n_stations == 6
    assert float(city.population) == 2400.0
    np.testing.assert_allclose(float(jnp.sum(city.arrival_profile)), 1.0, rtol=1e-5)
    override = make_city("city_grid_commuters", n_stations=6, population=7.0)
    assert float(override.population) == 7.0
    with pytest.raises(ValueError):
        make_city(layout=np.zeros((3, 2)), n_stations=4)


@pytest.mark.skipif(jax.device_count() < 2, reason="needs a 2-device mesh")
def test_sharded_city_coupled_fleet_matches_unsharded():
    """The stream split must respect the station-axis sharding: a 2-device
    city-coupled rollout reproduces the single-device one (same key)."""
    from repro.core import FleetEnv
    from repro.distributed import env_sharding, sharding
    from repro.launch.mesh import make_data_mesh

    n_dev = jax.device_count()
    archs = ["paper_16", "deep_4x4"] * n_dev
    city = make_city("city_ring_evening", n_stations=len(archs))

    def rollout(fleet, params):
        params = params if params is not None else fleet.default_params
        step = jax.jit(fleet.step)
        _, state = fleet.reset(jax.random.key(0), params)
        rates = []
        for i in range(20):
            a = fleet.sample_action(jax.random.key(1000 + i))
            _, state, r, _, info = step(jax.random.key(i), state, a, params)
            rates.append(np.asarray(info["city/arrival_rate"]))
        return np.stack(rates), np.asarray(state.profit_cum)

    ref_rates, ref_profit = rollout(FleetEnv(archs, city=city, shard=False), None)
    fleet = FleetEnv(archs, city=city)
    mesh = make_data_mesh()
    with sharding.set_mesh(mesh):
        params = env_sharding.place_env_batch(fleet.default_params, mesh)
        got_rates, got_profit = rollout(fleet, params)

    np.testing.assert_allclose(got_rates, ref_rates, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_profit, ref_profit, rtol=1e-5, atol=1e-5)
