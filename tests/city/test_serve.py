"""Serving-shaped inference: batched step correctness + one-jit-entry cache."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ChargaxEnv, EnvConfig
from repro.obs import cache_entries
from repro.rl import make_ppo_policy, make_serve, networks, serve

jax.config.update("jax_platform_name", "cpu")


def _policy_setup():
    env = ChargaxEnv(EnvConfig())
    params = networks.init_actor_critic(
        jax.random.key(7),
        env.obs_dim,
        env.action_space.shape[-1],
        env.action_space.num_categories,
    )
    return env, make_ppo_policy(env, greedy=True), params


def test_serve_step_matches_policy_bitwise():
    """The serving path is the policy — jit + (optional) donation must not
    change a single bit of the actions."""
    env, policy, params = _policy_setup()
    obs = jax.random.normal(jax.random.key(1), (256, env.obs_dim), jnp.float32)
    key = jax.random.key(5)
    ref = policy(params, key, obs)
    got = make_serve(policy, donate=False)(params, key, obs)
    assert np.array_equal(np.asarray(got), np.asarray(ref))
    # the convenience wrapper routes through the same compiled step
    got2 = serve(policy, params, obs, key=key)
    assert np.array_equal(np.asarray(got2), np.asarray(ref))


def test_serve_cache_is_one_jit_entry():
    """Repeated serve() calls for one policy + one batch shape hit a single
    compiled executable (the control-plane steady state)."""
    from repro.rl import eval as rl_eval

    env, policy, params = _policy_setup()
    obs = jax.random.normal(jax.random.key(2), (128, env.obs_dim), jnp.float32)
    for i in range(4):
        serve(policy, params, obs + jnp.float32(i))
    fn = rl_eval._SERVE_CACHE.get(policy)
    assert fn is not None
    assert cache_entries(fn) == 1

    # a second policy gets its own cached step, not a recompile of the first
    policy2 = make_ppo_policy(env, greedy=False)
    serve(policy2, params, obs)
    assert rl_eval._SERVE_CACHE.get(policy2) is not fn
    assert cache_entries(fn) == 1


def test_serve_handles_large_concurrent_batch():
    """Smoke the acceptance shape class: one step over a big (B, obs_dim)
    batch returns one action row per observation."""
    env, policy, params = _policy_setup()
    batch = 4096  # full O(1e5) scale is benchmarks/serve.py's job
    obs = jax.random.normal(jax.random.key(3), (batch, env.obs_dim), jnp.float32)
    actions = serve(policy, params, obs)
    assert actions.shape[0] == batch
    assert np.all(np.asarray(actions) >= 0)
