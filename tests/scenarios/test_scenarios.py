"""Scenario subsystem tests: registry round-trips, array shapes/invariants,
no-recompile guarantee, PV energy conservation under vmap, PPO wiring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios
from repro.core import ChargaxEnv, EnvConfig
from repro.scenarios import MAX_CAR_MODELS, Scenario, processes

jax.config.update("jax_platform_name", "cpu")

ENV = ChargaxEnv(EnvConfig())
SPD = ENV.config.steps_per_day


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
def test_catalog_has_at_least_six_scenarios():
    assert len(scenarios.names()) >= 6
    for name in scenarios.names():
        assert scenarios.make(name).name == name


def test_make_unknown_name_raises_with_listing():
    with pytest.raises(KeyError, match="shopping_flat"):
        scenarios.make("nope_not_a_scenario")


def test_register_rejects_duplicates_unless_overwrite():
    original = scenarios.make("shopping_flat")
    sc = Scenario(name="shopping_flat")
    try:
        with pytest.raises(ValueError, match="already registered"):
            scenarios.register(sc)
        assert scenarios.register(sc, overwrite=True) is sc
    finally:  # restore the catalog entry for other tests / same-process users
        scenarios.register(original, overwrite=True)


def test_scenario_dict_round_trip():
    for name in scenarios.names():
        sc = scenarios.make(name)
        assert Scenario.from_dict(sc.to_dict()) == sc


def test_from_dict_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown Scenario fields"):
        Scenario.from_dict({"name": "x", "wind_turbines": 3})


def test_evolve_keeps_declarative_identity():
    base = scenarios.make("shopping_flat")
    hot = base.evolve(pv_peak_kw=99.0)
    assert hot.pv_peak_kw == 99.0 and base.pv_peak_kw == 0.0


# ---------------------------------------------------------------------------
# Lowered array shapes & invariants
# ---------------------------------------------------------------------------
def test_all_scenarios_share_param_shapes():
    shapes = {
        name: jax.tree_util.tree_map(
            lambda x: jnp.shape(x), scenarios.make(name).make_params(ENV)
        )
        for name in scenarios.names()
    }
    first = next(iter(shapes.values()))
    for name, s in shapes.items():
        assert s == first, f"{name} deviates from the common shape"


def test_scenario_swap_does_not_recompile():
    step = jax.jit(ENV.step)
    params = [scenarios.make(n).make_params(ENV) for n in scenarios.names()]
    _, state = ENV.reset(jax.random.key(0), params[0])
    action = ENV.sample_action(jax.random.key(1))
    step(jax.random.key(2), state, action, params[0])
    n_compiled = step._cache_size()
    for p in params[1:]:
        step(jax.random.key(2), state, action, p)
    assert step._cache_size() == n_compiled


def test_pv_table_shape_and_daynight_structure():
    pv = processes.pv_table(150.0, ENV.config.dt_minutes)
    assert pv.shape == (365, SPD) and pv.dtype == np.float32
    assert np.all(pv >= 0.0) and np.max(pv) <= 150.0
    midnight = pv[:, 0]  # no sun at 00:00 anywhere in the year
    assert np.all(midnight == 0.0)
    noon_idx = SPD // 2
    # summer noon outproduces winter noon (seasonal declination cycle)
    assert pv[172, noon_idx] > pv[355, noon_idx] > 0.0


def test_pv_table_cache_normalises_scalar_types():
    """np.float32/np.float64 callers share one cache entry with float
    callers (the raw-float lru_cache keying used to fragment the cache)."""
    a = processes.pv_table(150.0, 60.0)
    b = processes.pv_table(np.float32(150.0), np.float64(60.0))
    c = processes.pv_table(np.int64(150), 60.0)
    assert b is a and c is a


def test_tou_overlay_moves_peak_and_valley():
    base = np.ones((365, SPD), np.float32) * 0.10
    tou = processes.tou_overlay(base, ENV.config.dt_minutes)
    hour = np.arange(SPD) * 24.0 / SPD
    peak = (hour > 18.0) & (hour < 20.0)
    valley = (hour > 1.0) & (hour < 5.0)
    assert np.all(tou[:, peak] > base[:, peak])
    assert np.all(tou[:, valley] < base[:, valley])


def test_seasonal_scale_weekend_factor():
    s = processes.seasonal_arrival_scale("summer_peak", 0.2, weekend_factor=0.5)
    assert s.shape == (365,)
    day = np.arange(365)
    weekend = np.isin(day % 7, [5, 6])
    assert s[weekend].mean() < s[~weekend].mean()
    with pytest.raises(ValueError):
        processes.seasonal_arrival_scale("monsoon")


def test_fleet_drift_rows_are_distributions_and_shift_capacity():
    p = scenarios.make("shopping_fleet_drift").make_params(ENV)
    table = np.asarray(p.car_probs)
    assert table.shape == (365, MAX_CAR_MODELS)
    np.testing.assert_allclose(table.sum(axis=1), 1.0, rtol=1e-5)
    cap = np.asarray(p.car_capacity)
    mean_cap = table @ cap
    assert mean_cap[-1] > mean_cap[0]  # drift toward bigger batteries


# ---------------------------------------------------------------------------
# Physics through the env: conservation + economics under vmap
# ---------------------------------------------------------------------------
def _rollout_info(params_stacked, n_scen, steps=30):
    from repro.utils import replace

    v_reset = jax.vmap(ENV.reset, in_axes=(0, 0))
    v_step = jax.jit(jax.vmap(ENV.step, in_axes=(0, 0, 0, 0)))
    keys = jax.random.split(jax.random.key(0), n_scen)
    _, state = v_reset(keys, params_stacked)
    # start mid-morning so daylight processes (PV) are exercised
    state = replace(state, t=jnp.full_like(state.t, int(SPD * 10 / 24)))
    action = jnp.stack([ENV.sample_action(jax.random.key(7))] * n_scen)
    infos = []
    for i in range(steps):
        ks = jax.random.split(jax.random.key(100 + i), n_scen)
        _, state, _, _, info = v_step(ks, state, action, params_stacked)
        infos.append(info)
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *infos)


def test_pv_conservation_under_vmap():
    names = list(scenarios.names())
    params = scenarios.stack_params(
        [scenarios.make(n).make_params(ENV) for n in names]
    )
    info = _rollout_info(params, len(names))
    # PV appears in the info and only for scenarios that declare a plant
    pv_by_scen = np.asarray(info["e_pv"]).sum(axis=0)
    for i, n in enumerate(names):
        if scenarios.make(n).pv_peak_kw == 0.0:
            assert pv_by_scen[i] == 0.0
    assert pv_by_scen.sum() > 0.0  # catalog includes PV scenarios
    assert np.all(np.asarray(info["e_pv"]) >= 0.0)


def test_pv_reduces_net_grid_energy():
    base = scenarios.make("shopping_flat")
    solar = base.evolve(name="tmp_solar", pv_peak_kw=200.0)
    params = scenarios.stack_params(
        [base.make_params(ENV), solar.make_params(ENV)]
    )
    info = _rollout_info(params, 2, steps=SPD // 2)  # first 12h of a day
    e_net = np.asarray(info["e_grid_net"]).sum(axis=0)
    assert e_net[1] < e_net[0]


def test_demand_charge_lowers_profit():
    base = scenarios.make("shopping_flat")
    charged = base.evolve(
        name="tmp_dc", demand_charge_rate=1.0, demand_contract_kw=0.0
    )
    params = scenarios.stack_params(
        [base.make_params(ENV), charged.make_params(ENV)]
    )
    info = _rollout_info(params, 2, steps=20)
    profit = np.asarray(info["profit"]).sum(axis=0)
    assert profit[1] < profit[0]


# ---------------------------------------------------------------------------
# PPO wiring: train across a scenario distribution
# ---------------------------------------------------------------------------
def test_ppo_trains_across_scenario_distribution():
    from repro.rl import PPOConfig, make_train

    names = ["shopping_flat", "shopping_pv_tou", "highway_demand_charge"]
    stacked = scenarios.stack_params(
        [scenarios.make(n).make_params(ENV) for n in names]
    )
    cfg = PPOConfig(
        total_timesteps=6 * 16, num_envs=6, rollout_steps=16,
        num_minibatches=2, update_epochs=1, hidden=(16,),
    )
    out = jax.jit(make_train(cfg, ENV, scenario_params=stacked))(jax.random.key(0))
    loss = np.asarray(out["metrics"]["loss"])
    assert np.all(np.isfinite(loss))

    with pytest.raises(ValueError, match="not both"):
        make_train(cfg, ENV, env_params=ENV.default_params, scenario_params=stacked)

    # fewer envs than scenarios would silently drop worlds: refuse loudly
    with pytest.raises(ValueError, match="drop scenarios"):
        make_train(
            PPOConfig(num_envs=2, rollout_steps=16), ENV, scenario_params=stacked
        )


# ---------------------------------------------------------------------------
# V2G scenario pack
# ---------------------------------------------------------------------------
def test_catalog_spans_twelve_scenarios_including_v2g_pack():
    assert len(scenarios.names()) >= 12
    assert len(scenarios.V2G_PACK) >= 4
    for name in scenarios.V2G_PACK:
        assert name in scenarios.names()
    for name in scenarios.V2G_MIXED_PACK:
        assert name in scenarios.names()


def test_v2g_axis_lowers_to_params():
    sc = scenarios.make("v2g_work_solar_split")
    p = sc.make_params(ENV)
    mask = np.asarray(p.evse_v2g_mask)
    n_real = int(np.asarray(p.evse_mask).sum())
    assert mask.sum() == round(0.5 * n_real)
    # bidirectional lanes are a subset of real lanes
    assert np.all(mask <= np.asarray(p.evse_mask))
    np.testing.assert_allclose(float(p.p_v2g_comp), 0.10)

    guard = scenarios.make("v2g_degradation_guard").make_params(ENV)
    assert float(guard.weights.degradation) == pytest.approx(0.05)

    # no spread declared -> owner compensation collapses to p_sell (Eq. 2)
    flat = scenarios.make("shopping_flat").make_params(ENV)
    np.testing.assert_allclose(float(flat.p_v2g_comp), float(flat.p_sell))

    with pytest.raises(ValueError, match="v2g_port_fraction"):
        sc.evolve(name="bad", v2g_port_fraction=1.5).make_params(ENV)


def test_real_pack_lowers_with_catalog_under_one_compiled_step():
    """REAL_PACK (ingested ENTSO-E/PVGIS tables) + the full synthetic
    catalog share identical EnvParams shapes and ONE jitted step."""
    assert len(scenarios.REAL_PACK) >= 4
    for name in scenarios.REAL_PACK:
        assert name in scenarios.names()
    all_names = list(scenarios.names())
    assert len(all_names) >= 17  # 13 synthetic/V2G + the real-data pack
    params = [scenarios.make(n).make_params(ENV) for n in all_names]
    step = jax.jit(ENV.step)
    _, state = ENV.reset(jax.random.key(0), params[0])
    action = ENV.sample_action(jax.random.key(1))
    step(jax.random.key(2), state, action, params[0])
    n_compiled = step._cache_size()
    for p in params[1:]:
        step(jax.random.key(2), state, action, p)
    assert step._cache_size() == n_compiled


def test_real_axis_lowers_ingested_tables():
    from repro.data import ingest

    p = scenarios.make("real_nl_2024_office").make_params(ENV)
    dtm = ENV.config.dt_minutes
    # prices are exactly the ingested table (no tariff overlay declared)
    np.testing.assert_array_equal(
        np.asarray(p.price_buy_table), ingest.load_price_table("nl_2024", dtm)
    )
    # PV is the peak-normalised PVGIS shape scaled by the declared plant size
    pv = np.asarray(p.pv_kw_table)
    np.testing.assert_allclose(
        pv, 120.0 * ingest.load_pv_table("pvgis_nl_delft", dtm), rtol=1e-6
    )
    assert float(pv.max()) == pytest.approx(120.0)

    # a tariff overlay composes ON TOP of the real curve
    tou = scenarios.make("real_nl_2024_shopping_tou").make_params(ENV)
    raw = ingest.load_price_table("nl_2024", dtm)
    overlaid = np.asarray(tou.price_buy_table)
    spd = raw.shape[1]
    peak = int(19.0 / 24.0 * spd)  # inside the 17:00-21:00 peak window
    assert np.all(overlaid[:, peak] >= raw[:, peak])

    # unknown sources fail loudly at lowering time
    with pytest.raises(KeyError, match="not a registered name"):
        scenarios.make("real_nl_2024_office").evolve(
            name="bad", price_source="entsoe_mars_2099"
        ).make_params(ENV)


def test_ppo_trains_mixed_v2g_distribution_one_compile():
    """allow_v2g PPO across the mixed v2g/non-v2g pack: one jitted train."""
    from repro.core import ChargaxEnv as _Env, EnvConfig as _Cfg
    from repro.rl import PPOConfig, make_train

    env = _Env(_Cfg(allow_v2g=True))
    stacked = scenarios.stack_params(
        [scenarios.make(n).make_params(env) for n in scenarios.V2G_MIXED_PACK]
    )
    n_mix = len(scenarios.V2G_MIXED_PACK)
    cfg = PPOConfig(
        total_timesteps=n_mix * 16, num_envs=n_mix, rollout_steps=16,
        num_minibatches=2, update_epochs=1, hidden=(16,),
    )
    train_fn = make_train(cfg, env, scenario_params=stacked)
    # exogenous tables stay one-copy-per-scenario (never per-env)
    assert train_fn.scenario_shape == (n_mix, 1)
    out = jax.jit(train_fn)(jax.random.key(0))
    assert np.all(np.isfinite(np.asarray(out["metrics"]["loss"])))
