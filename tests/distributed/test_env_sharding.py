"""Determinism + mesh-sharding equivalence for env-batch placement.

On a 1-device mesh every helper must degrade gracefully (constraints lower
to no-ops) and the sharded program must reproduce the unsharded one.  The CI
sharding job re-runs this file under ``JAX_PLATFORMS=cpu`` with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` so the genuinely
multi-device path (station/env axis split across 2 host devices) is
exercised on every push; the device-count-gated asserts activate there.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios
from repro.core import ChargaxEnv, EnvConfig, FleetEnv
from repro.distributed import env_sharding, sharding
from repro.launch.mesh import make_data_mesh
from repro.rl import PPOConfig, make_train

jax.config.update("jax_platform_name", "cpu")

ENV = ChargaxEnv(EnvConfig())
SCEN_NAMES = ["shopping_flat", "shopping_pv_tou", "highway_demand_charge"]


def _tiny_cfg(num_envs=6, updates=2):
    return PPOConfig(
        total_timesteps=num_envs * 16 * updates,
        num_envs=num_envs,
        rollout_steps=16,
        num_minibatches=2,
        update_epochs=1,
        hidden=(16,),
    )


def _stacked():
    return scenarios.stack_params(
        [scenarios.make(n).make_params(ENV) for n in SCEN_NAMES]
    )


# ---------------------------------------------------------------------------
# tentpole acceptance: tables materialise with leading axis S, not num_envs
# ---------------------------------------------------------------------------
def test_scenario_tables_one_copy_per_scenario():
    stacked = _stacked()
    cfg = _tiny_cfg(num_envs=6)
    train = make_train(cfg, ENV, scenario_params=stacked)
    assert train.scenario_shape == (3, 2)
    lowered = jax.tree_util.tree_leaves(train.lowered_env_params)
    source = jax.tree_util.tree_leaves(stacked)
    assert len(lowered) == len(source)
    for got, src in zip(lowered, source):
        assert got.shape == src.shape  # identical to the (S, ...) catalog
        assert got.shape[0] == len(SCEN_NAMES)
        assert got.shape[0] != cfg.num_envs  # never one copy per env
    # and the nested-vmap program actually trains
    out = jax.jit(train)(jax.random.key(0))
    assert np.isfinite(np.asarray(out["metrics"]["loss"])).all()


def test_scenario_envs_must_divide():
    with pytest.raises(ValueError, match="drop scenarios"):
        make_train(_tiny_cfg(num_envs=4), ENV, scenario_params=_stacked())


# ---------------------------------------------------------------------------
# determinism: same key => bit-identical PPO metrics on CPU
# ---------------------------------------------------------------------------
def test_ppo_metrics_bit_identical_same_key():
    cfg = _tiny_cfg(num_envs=6)
    stacked = _stacked()
    key = jax.random.key(7)
    runs = []
    for _ in range(2):  # two fresh train closures, two fresh jits
        train = jax.jit(make_train(cfg, ENV, scenario_params=stacked))
        runs.append(jax.device_get(train(key)["metrics"]))
    a, b = runs
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# sharding equivalence: mesh-sharded programs match unsharded ones
# ---------------------------------------------------------------------------
def _fleet_rollout(fleet, params, steps=48):
    @jax.jit
    def rollout(key):
        _, state = fleet.reset(key, params)

        def body(carry, _):
            key, state = carry
            key, ka, ks = jax.random.split(key, 3)
            action = jax.random.randint(
                ka,
                (fleet.n_stations, fleet.num_action_heads),
                0,
                fleet.num_actions_per_head,
            )
            _, state, r, d, info = fleet.step(ks, state, action, params)
            return (key, state), (r, info["fleet_profit"])

        (_, state), (rewards, fprofit) = jax.lax.scan(body, (key, state), None, steps)
        return state.profit_cum, rewards, fprofit

    return jax.device_get(rollout(jax.random.key(11)))


def test_sharded_fleet_rollout_matches_unsharded():
    n_dev = jax.device_count()
    # station count a multiple of the device count so the mesh engages
    archs = ["paper_16", "deep_4x4"] * n_dev
    mesh = make_data_mesh()
    assert mesh.shape["data"] == n_dev

    ref = _fleet_rollout(FleetEnv(archs, shard=False), None)
    fleet = FleetEnv(archs)
    with sharding.set_mesh(mesh):
        params = env_sharding.place_env_batch(fleet.default_params, mesh)
        if n_dev > 1:
            # tables really are distributed over the devices
            leaf = params.evse_mask
            assert len(leaf.sharding.device_set) == n_dev
        got = _fleet_rollout(fleet, params)

    for a, b, name in zip(got, ref, ("profit_cum", "rewards", "fleet_profit")):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5, err_msg=name)


def test_sharded_scenario_ppo_matches_unsharded():
    """Nested-vmap PPO with the env batch constrained onto the mesh must
    reproduce the single-device run to float tolerance."""
    n_dev = jax.device_count()
    cfg = _tiny_cfg(num_envs=3 * 2 * n_dev)
    stacked = _stacked()
    key = jax.random.key(3)

    ref = jax.device_get(
        jax.jit(make_train(cfg, ENV, scenario_params=stacked))(key)["metrics"]
    )
    mesh = make_data_mesh()
    with sharding.set_mesh(mesh):
        train = make_train(
            cfg,
            ENV,
            scenario_params=stacked,
            shard_envs=env_sharding.make_shard_envs(mesh),
        )
        got = jax.device_get(jax.jit(train)(key)["metrics"])

    for la, lb in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(
            np.asarray(la), np.asarray(lb), rtol=1e-4, atol=1e-5
        )


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >= 2 devices (CI sharding job)")
def test_two_device_mesh_distributes_env_batch():
    """Acceptance: a 2-host-device CPU mesh really splits the station axis."""
    mesh = make_data_mesh()
    n_dev = jax.device_count()
    fleet = FleetEnv(["paper_16"] * (2 * n_dev))
    with sharding.set_mesh(mesh):
        params = env_sharding.place_env_batch(fleet.default_params, mesh)
        obs, state = jax.jit(fleet.reset)(jax.random.key(0), params)
    assert len(params.evse_mask.sharding.device_set) == n_dev
    assert len(obs.sharding.device_set) == n_dev
    # per-device shard covers 1/n of the stations
    shard = obs.addressable_shards[0]
    assert shard.data.shape[0] == obs.shape[0] // n_dev


# ---------------------------------------------------------------------------
# graceful fallback
# ---------------------------------------------------------------------------
def test_constrain_env_batch_noop_without_mesh():
    x = jnp.ones((4, 3))
    tree = {"a": x, "b": jnp.float32(1.0)}
    out = env_sharding.constrain_env_batch(tree)
    assert out["a"] is x  # literally untouched: no annotation, no copy


def test_env_shardings_replicate_indivisible_leaves():
    mesh = make_data_mesh()
    tree = {"big": jnp.ones((4 * jax.device_count(), 2)), "odd": jnp.ones((3,))}
    sh = env_sharding.env_shardings(tree, mesh)
    if jax.device_count() > 1:
        assert sh["big"].spec == jax.sharding.PartitionSpec("data")
        assert sh["odd"].spec == jax.sharding.PartitionSpec()
    placed = env_sharding.place_env_batch(tree, mesh)
    np.testing.assert_array_equal(np.asarray(placed["big"]), np.asarray(tree["big"]))
