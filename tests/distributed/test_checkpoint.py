"""Checkpoint manager: atomicity, rotation, async, elastic restore, resume."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "opt": {"mu": jnp.ones((8, 16)) * seed, "step": jnp.int32(seed)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(3)
    mgr.save(3, tree, extras={"data_step": 42})
    restored, extras = mgr.restore(jax.eval_shape(lambda: tree))
    assert extras["data_step"] == 42
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(7, _tree(7), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 7


def test_atomicity_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1))
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    # manifest must parse and enumerate every leaf
    man = json.load(open(tmp_path / "step_0000000001" / "manifest.json"))
    assert len(man["leaves"]) == 4


def test_elastic_restore_new_sharding(tmp_path):
    """Restore onto explicit (trivial 1-dev) shardings — the reshard path."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(5)
    mgr.save(5, tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)
    restored, _ = mgr.restore(jax.eval_shape(lambda: tree), shardings=sh)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert all(
        l.sharding == NamedSharding(mesh, P())
        for l in jax.tree_util.tree_leaves(restored)
    )


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore({})


def test_crash_mid_save_preserves_previous(tmp_path):
    """A stale .tmp dir from a crash must not shadow the published step."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1))
    os.makedirs(tmp_path / "step_0000000002.tmp")  # simulated crash debris
    assert mgr.latest_step() == 1
    mgr.save(2, _tree(2))  # overwrites debris cleanly
    assert mgr.latest_step() == 2
