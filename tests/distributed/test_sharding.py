"""Sharding-rule tests: every param of every arch gets a valid PartitionSpec."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCH_IDS, build_model, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_test_mesh


@pytest.fixture(scope="module")
def mesh():
    # 1-device CPU: build an abstract 16x16 mesh for spec computation only
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    from jax.sharding import Mesh

    return Mesh(devs, ("data", "model"))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch, mesh):
    """Every spec must divide the dim it shards (full config shapes)."""
    cfg = get_config(arch)
    model = build_model(cfg)
    params_abs = jax.eval_shape(model.init, jax.random.key(0))
    flat = jax.tree_util.tree_flatten_with_path(params_abs)[0]
    n_model_sharded = 0
    for path, leaf in flat:
        spec = shd.param_spec(path, leaf.shape, mesh)
        assert len(spec) <= len(leaf.shape)
        for dim, entry in zip(leaf.shape, spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (jax.tree_util.keystr(path), leaf.shape, spec)
            if "model" in axes:
                n_model_sharded += 1
    # TP must actually engage on the big tensors
    assert n_model_sharded >= 4, arch


def test_tp_rules_hit_expected_dims(mesh):
    spec = shd.param_spec(
        (jax.tree_util.DictKey("layers"), jax.tree_util.DictKey("attn"), jax.tree_util.DictKey("q_proj")),
        (22, 2048, 4096),
        mesh,
    )
    assert spec[2] == "model"  # head dim TP
    assert spec[1] == "data"  # FSDP on d_model

    spec = shd.param_spec(
        (jax.tree_util.DictKey("layers"), jax.tree_util.DictKey("moe"), jax.tree_util.DictKey("expert_w_gate")),
        (48, 128, 2048, 768),
        mesh,
    )
    assert spec[1] == "model"  # expert parallel


def test_batch_spec(mesh):
    assert shd.batch_spec(mesh, 256) == P("data")
    assert shd.batch_spec(mesh, 1) == P(None)


def test_cache_spec_shards_sequence(mesh):
    path = (jax.tree_util.DictKey("k"),)
    spec = shd.cache_spec(path, (22, 128, 4, 32768, 128), mesh, 128)
    assert spec[1] == "data"
    assert spec[3] == "model"
    # batch=1 long-context: sequence takes both axes
    spec = shd.cache_spec(path, (22, 1, 4, 524288, 128), mesh, 1)
    assert spec[3] == ("model", "data")


def test_param_shardings_buildable(mesh):
    cfg = get_config("tinyllama-1.1b", smoke=True)
    model = build_model(cfg)
    params_abs = jax.eval_shape(model.init, jax.random.key(0))
    sh = shd.param_shardings(params_abs, mesh)
    leaves = jax.tree_util.tree_leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(leaves) == len(jax.tree_util.tree_leaves(params_abs))
