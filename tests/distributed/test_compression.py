"""Gradient-compression codec tests incl. the error-feedback convergence property."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (
    compress_decompress_with_feedback,
    dequantize_int8,
    quantize_int8,
)


def test_int8_roundtrip_error_bound():
    x = jax.random.normal(jax.random.key(0), (256, 256)) * 3.0
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.51  # half-ulp of the quant grid


def test_error_feedback_accumulates_unbiased():
    """Sum of decompressed grads over T steps tracks the true sum (EF property)."""
    key = jax.random.key(1)
    g_true_sum = jnp.zeros((64,))
    g_sent_sum = jnp.zeros((64,))
    ef = {"g": jnp.zeros((64,))}
    for t in range(50):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (64,)) * 0.01
        g_true_sum += g
        sent, ef = compress_decompress_with_feedback({"g": g}, ef)
        g_sent_sum += sent["g"]
    # residual is bounded by one quantisation step, so sums converge
    np.testing.assert_allclose(g_sent_sum, g_true_sum, atol=5e-3)


def test_feedback_residual_carried():
    # one big element sets the scale; the tiny ones fall below resolution
    g = {"w": jnp.array([1.0, 1e-8, 1e-8, 1e-8])}
    ef = {"w": jnp.zeros((4,))}
    sent, ef = compress_decompress_with_feedback(g, ef)
    np.testing.assert_allclose(np.asarray(sent["w"])[1:], 0.0, atol=1e-12)
    np.testing.assert_allclose(np.asarray(ef["w"])[1:], 1e-8, rtol=1e-3)


def test_train_step_with_compression_runs():
    from repro.configs.registry import build_model, get_config
    from repro.distributed.train_step import (
        TrainStepConfig,
        init_train_state,
        make_train_step,
    )

    cfg = get_config("tinyllama-1.1b", smoke=True)
    model = build_model(cfg)
    ts_cfg = TrainStepConfig(compress_grads=True, num_microbatches=2)
    state = init_train_state(model, jax.random.key(0), ts_cfg)
    step = jax.jit(make_train_step(model, ts_cfg))
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(m1["loss"]) and np.isfinite(m2["loss"])
    assert float(m2["loss"]) < float(m1["loss"])  # same batch twice -> improves
    # error feedback is live
    ef_norm = sum(
        float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(state.error_feedback)
    )
    assert ef_norm > 0
