"""Real-data ingest tests: golden-file parses of the vendored extracts,
resampling/DST/gap invariants, format coverage (CSV+XML, CSV+JSON), and the
fixture-size budget.  Everything runs offline."""
import datetime as dt
import collections

import numpy as np
import pytest

from repro.data import ingest
from repro.data.ingest import entsoe, pvgis, resample

SPD = {5.0: 288, 15.0: 96, 60.0: 24}


# ---------------------------------------------------------------------------
# Golden-file parses of the vendored extracts
# ---------------------------------------------------------------------------
def test_entsoe_fixture_parses_to_canonical_table():
    table = ingest.load_price_table("nl_2024", 60.0)
    assert table.shape == (365, 24) and table.dtype == np.float32
    # EUR/kWh plausibility: NL 2024 averaged ~77 EUR/MWh day-ahead
    assert 0.05 < float(table.mean()) < 0.15
    assert float(table.max()) < 1.0  # even spikes stay below 1 EUR/kWh
    # 2024 had negative midday hours; the extract (and parse) keeps them
    assert float(table.min()) < 0.0
    # evening peak exceeds the midday solar depression on average
    assert table[:, 19].mean() > table[:, 13].mean()


def test_pvgis_fixtures_parse_to_normalised_shapes():
    for name in ("pvgis_nl_delft", "pvgis_es_seville"):
        shape = ingest.load_pv_table(name, 60.0)
        assert shape.shape == (365, 24) and shape.dtype == np.float32
        assert float(shape.max()) == pytest.approx(1.0)
        assert float(shape.min()) == 0.0
        assert np.all(shape[:, 0] == 0.0)  # local midnight is dark all year
    delft = ingest.load_pv_table("pvgis_nl_delft", 60.0)
    seville = ingest.load_pv_table("pvgis_es_seville", 60.0)
    # southern site: higher capacity factor, longer winter days
    assert seville.mean() > delft.mean()
    winter = slice(0, 60)
    assert (seville[winter] > 0).sum() > (delft[winter] > 0).sum()


def test_loaders_return_copies_and_cache():
    a = ingest.load_price_table("nl_2024", 60.0)
    a[:] = 0.0
    b = ingest.load_price_table("nl_2024", 60.0)
    assert float(b.mean()) > 0.0  # cache entry not clobbered by the caller


def test_unknown_source_raises_with_listing():
    with pytest.raises(KeyError, match="nl_2024"):
        ingest.load_price_table("nope_no_such_source")
    with pytest.raises(ValueError, match="pvgis"):
        ingest.load_pv_table("nl_2024")  # wrong kind, helpful error


# ---------------------------------------------------------------------------
# DST-transition days
# ---------------------------------------------------------------------------
def test_dst_days_regularise_to_steps_per_day():
    text = ingest.read_text(ingest.SOURCES["nl_2024"].path)
    recs = entsoe.parse_csv(text)
    counts = collections.Counter(d for d, _, _ in recs)
    assert counts[dt.date(2024, 3, 31)] == 23  # spring forward: hour missing
    assert counts[dt.date(2024, 10, 27)] == 25  # fall back: hour duplicated
    for dtm, spd in SPD.items():
        table = ingest.load_price_table("nl_2024", dtm)
        assert table.shape == (365, spd)
        assert np.isfinite(table).all()


def test_fall_back_duplicate_hour_is_averaged():
    rows = [(dt.date(2024, 10, 27), h, 10.0) for h in range(24)]
    rows.append((dt.date(2024, 10, 27), 2, 30.0))  # second 02:00-03:00
    hourly = resample.canonical_year(rows)
    assert hourly[0, 2] == pytest.approx(20.0)  # time-weighted mean
    assert hourly[0, 3] == pytest.approx(10.0)


def test_spring_forward_hole_is_interpolated():
    rows = [
        (dt.date(2024, 3, 31), h, float(h)) for h in range(24) if h != 2
    ]
    hourly = resample.canonical_year(rows)
    assert hourly[0, 2] == pytest.approx(2.0)  # between hours 1 and 3


# ---------------------------------------------------------------------------
# Gap interpolation + leap/partial years
# ---------------------------------------------------------------------------
def test_gap_interpolation_inline_csv():
    csv = "\n".join(
        [
            '"MTU (CET/CEST)","Day-ahead Price [EUR/MWh]","Currency","BZN|NL"',
            '"01.01.2024 00:00 - 01.01.2024 01:00","100.00","EUR","NL"',
            '"01.01.2024 01:00 - 01.01.2024 02:00","N/A","EUR","NL"',
            '"01.01.2024 02:00 - 01.01.2024 03:00","N/A","EUR","NL"',
            '"01.01.2024 03:00 - 01.01.2024 04:00","400.00","EUR","NL"',
        ]
    )
    table = entsoe.price_table(csv, dt_minutes=60.0)
    np.testing.assert_allclose(table[0, :4], [0.1, 0.2, 0.3, 0.4], rtol=1e-5)


def test_missing_whole_day_keeps_calendar_alignment():
    """A day the platform never published must become an interpolated NaN
    row, not silently shift every later day one index earlier."""
    rows = []
    for i, val in [(0, 1.0), (2, 5.0)]:  # Jan 2 entirely absent
        d = dt.date(2024, 1, 1) + dt.timedelta(days=i)
        rows += [(d, h, val) for h in range(24)]
    hourly = resample.canonical_year(rows)
    np.testing.assert_allclose(hourly[0], 1.0)
    np.testing.assert_allclose(hourly[2], 5.0)  # Jan 3 stays at index 2
    # the missing Jan 2 interpolates between its neighbours
    assert 1.0 < hourly[1].mean() < 5.0


def test_leap_day_dropped_and_partial_year_tiled():
    # leap year: Feb 29 present in the fixture, absent from the table
    text = ingest.read_text(ingest.SOURCES["nl_2024"].path)
    recs = entsoe.parse_csv(text)
    assert any(d == dt.date(2024, 2, 29) for d, _, _ in recs)
    assert ingest.load_price_table("nl_2024", 60.0).shape[0] == 365
    # partial extract: two days tile periodically to a full year
    rows = [(dt.date(2024, 1, 1), h, 1.0) for h in range(24)]
    rows += [(dt.date(2024, 1, 2), h, 3.0) for h in range(24)]
    hourly = resample.canonical_year(rows)
    assert hourly.shape == (365, 24)
    np.testing.assert_allclose(hourly[::2], 1.0)
    np.testing.assert_allclose(hourly[1::2], 3.0)


# ---------------------------------------------------------------------------
# Energy-conserving resampling
# ---------------------------------------------------------------------------
def test_resampling_conserves_daily_totals_across_grids():
    for source, loader in [
        ("nl_2024", ingest.load_price_table),
        ("pvgis_nl_delft", ingest.load_pv_table),
        ("pvgis_es_seville", ingest.load_pv_table),
    ]:
        daily = {}
        for dtm, spd in SPD.items():
            table = loader(source, dtm)
            assert table.shape == (365, spd)
            daily[dtm] = table.mean(axis=1)  # mean * 24h = daily total
        np.testing.assert_allclose(daily[5.0], daily[60.0], rtol=1e-5)
        np.testing.assert_allclose(daily[15.0], daily[60.0], rtol=1e-5)


def test_regrid_splits_straddling_hours_proportionally():
    hourly = np.zeros((1, 24))
    # 16 steps/day = 90-minute steps: hour 13 (= [13h, 14h)) straddles the
    # steps [12h, 13.5h) and [13.5h, 15h)
    hourly[0, 13] = 6.0
    out = resample.regrid_table(hourly, 16)
    assert out.shape == (1, 16)
    np.testing.assert_allclose(out.sum() * (24 / 16), 6.0, rtol=1e-12)
    assert (out > 0).sum() == 2


# ---------------------------------------------------------------------------
# Format coverage: ENTSO-E XML, PVGIS JSON/CSV equivalence
# ---------------------------------------------------------------------------
def test_entsoe_xml_matches_csv():
    ns = 'xmlns="urn:iec62325.351:tc57wg16:451-3:publicationdocument:7:0"'
    points = "".join(
        f"<Point><position>{i+1}</position><price.amount>{(i+1)*10}.0"
        "</price.amount></Point>"
        for i in range(24)
    )
    xml = (
        f'<?xml version="1.0"?><Publication_MarketDocument {ns}><TimeSeries>'
        "<Period><timeInterval><start>2024-06-01T22:00Z</start>"
        "<end>2024-06-02T22:00Z</end></timeInterval>"
        f"<resolution>PT60M</resolution>{points}</Period>"
        "</TimeSeries></Publication_MarketDocument>"
    )
    recs = entsoe.parse_xml(xml)
    assert len(recs) == 24
    # prices follow the civil clock: UTC 22:00 + CET(+1) + EU summer hour
    # -> local midnight, i.e. the delivery day starts exactly at 00:00 CEST
    # (which is why summer API periods start at 22:00Z in the first place)
    assert recs[0] == (dt.date(2024, 6, 2), 0, pytest.approx(0.010))
    assert recs[-1] == (dt.date(2024, 6, 2), 23, pytest.approx(0.240))
    # winter stamps get the bare standard-time offset
    winter = entsoe.parse_xml(xml.replace("-06-", "-01-"))
    assert winter[0] == (dt.date(2024, 1, 1), 23, pytest.approx(0.010))
    # price_table dispatches on leading '<'
    table = entsoe.price_table(xml, dt_minutes=60.0)
    assert table.shape == (365, 24)


def test_entsoe_xml_curve_a03_forward_fills_positions():
    xml = (
        "<doc><Period><timeInterval><start>2024-06-01T00:00Z</start></timeInterval>"
        "<resolution>PT60M</resolution>"
        "<Point><position>1</position><price.amount>50.0</price.amount></Point>"
        "<Point><position>4</position><price.amount>80.0</price.amount></Point>"
        "</Period></doc>"
    )
    recs = entsoe.parse_xml(xml, tz_offset_hours=0)
    assert [round(v * 1000) for _, _, v in recs] == [50, 50, 50, 80]


def test_entsoe_xml_a03_trailing_omission_fills_to_period_end():
    """Trailing positions omitted under A03 repeat the last value to the
    declared timeInterval end instead of truncating the day."""
    xml = (
        "<doc><Period><timeInterval><start>2024-06-01T00:00Z</start>"
        "<end>2024-06-02T00:00Z</end></timeInterval>"
        "<resolution>PT60M</resolution>"
        "<Point><position>1</position><price.amount>50.0</price.amount></Point>"
        "<Point><position>20</position><price.amount>90.0</price.amount></Point>"
        "</Period></doc>"
    )
    recs = entsoe.parse_xml(xml, tz_offset_hours=0)
    assert len(recs) == 24  # hours 21-24 forward-filled from position 20
    assert [round(v * 1000) for _, _, v in recs[19:]] == [90, 90, 90, 90, 90]


def test_tz_offset_override_shifts_pv_clock():
    src = ingest.SOURCES["pvgis_es_seville"].path
    east = ingest.load_pv_table(src, 60.0, tz_offset_hours=1)
    west = ingest.load_pv_table(src, 60.0, tz_offset_hours=-7)
    assert not np.array_equal(east, west)
    # a US-mountain offset pushes solar noon 8 hours earlier on the local
    # clock relative to the CET default
    noon_east = int(east.mean(axis=0).argmax())
    noon_west = int(west.mean(axis=0).argmax())
    assert (noon_east - noon_west) % 24 == 8


def test_pvgis_json_and_csv_parse_identically():
    rows = [("20230701:0011", 0.0), ("20230701:1211, extra", None)]
    csv = "\n".join(
        [
            "Latitude (decimal degrees):\t52.0",
            "",
            "time,P,G(i),T2m",
            "20230701:0011,0.0,0.0,15.2",
            "20230701:1211,4321.0,880.0,22.4",
            "",
            "P: PV system power (W)",
        ]
    )
    json_text = (
        '{"inputs":{},"outputs":{"hourly":['
        '{"time":"20230701:0011","P":0.0,"G(i)":0.0},'
        '{"time":"20230701:1211","P":4321.0,"G(i)":880.0}]},"meta":{}}'
    )
    assert pvgis.parse_csv(csv) == pvgis.parse_json(json_text)
    assert pvgis.parse_csv(csv)[1] == (dt.date(2023, 7, 1), 12, 4321.0)


# ---------------------------------------------------------------------------
# Fixture budget: vendored extracts must stay tiny (CI guards this too)
# ---------------------------------------------------------------------------
def test_vendored_fixtures_within_100kb_budget():
    total = ingest.check_fixture_budget()  # raises if over FIXTURE_BUDGET_BYTES
    assert 0 < total <= ingest.FIXTURE_BUDGET_BYTES
