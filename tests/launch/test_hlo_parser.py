"""HLO collective-parser unit tests (the §Roofline third-term source)."""
from repro.analysis.hlo import collective_stats


HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[16,128]{1,0} parameter(0)
  %ag = bf16[16,2048]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[256,256]{1,0} all-reduce(%x), to_apply=%add
  %rs = f32[16,64]{1,0} reduce-scatter(%y), dimensions={1}
  %a2a = bf16[8,128]{1,0} all-to-all(%z), dimensions={0}
  %cp-start = bf16[4,4]{1,0} collective-permute-start(%w)
  %cp-done = bf16[4,4]{1,0} collective-permute-done(%cp-start)
  %tup = (f32[128]{0}, f32[64]{0}) all-reduce(%a, %b), to_apply=%add
}
"""


def test_collective_stats_counts_and_bytes():
    s = collective_stats(HLO)
    assert s["all-gather"]["count"] == 1
    assert s["all-gather"]["bytes"] == 16 * 2048 * 2
    assert s["all-reduce"]["count"] == 2  # plain + tuple
    assert s["all-reduce"]["bytes"] == 256 * 256 * 4 + (128 + 64) * 4
    assert s["reduce-scatter"]["bytes"] == 16 * 64 * 4
    assert s["all-to-all"]["bytes"] == 8 * 128 * 2
    # -start counted once, -done skipped
    assert s["collective-permute"]["count"] == 1
    assert s["total_count"] == 6
    assert s["total_bytes"] == sum(
        v["bytes"] for k, v in s.items() if isinstance(v, dict)
    )


def test_empty_hlo():
    s = collective_stats("ENTRY main { ROOT %r = f32[2]{0} parameter(0) }")
    assert s["total_count"] == 0
    assert s["total_bytes"] == 0
