"""Trainer integration: loss goes down, checkpoints land, resume is bit-exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import build_model, get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.train_step import (
    TrainStepConfig,
    init_train_state,
    make_train_step,
)


def _setup(arch="tinyllama-1.1b", mb=1):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    ts_cfg = TrainStepConfig(lr=1e-3, total_steps=50, num_microbatches=mb)
    state = init_train_state(model, jax.random.key(0), ts_cfg)
    step = jax.jit(make_train_step(model, ts_cfg))
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, batch=4, seq_len=32))
    return cfg, model, state, step, data


@pytest.mark.slow
def test_loss_decreases_over_steps():
    cfg, model, state, step, data = _setup()
    losses = []
    for i in range(30):
        state, m = step(state, data.batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_microbatched_equals_full_batch_grads():
    """mb=2 grad accumulation == single big batch (same data)."""
    cfg, model, s1, step1, data = _setup(mb=1)
    _, _, s2, step2, _ = _setup(mb=2)
    batch = data.batch(0)
    s1b, m1 = step1(s1, batch)
    s2b, m2 = step2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(
        jax.tree_util.tree_leaves(s1b.params), jax.tree_util.tree_leaves(s2b.params)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)


@pytest.mark.slow
def test_resume_is_bit_exact(tmp_path):
    cfg, model, state, step, data = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=2)

    # run 6 steps, checkpoint at 3
    s = state
    for i in range(3):
        s, _ = step(s, data.batch(i))
    mgr.save(3, s, extras={"step": 3})
    for i in range(3, 6):
        s, m_direct = step(s, data.batch(i))

    # restore and replay
    s2, extras = mgr.restore(jax.eval_shape(lambda: state))
    assert extras["step"] == 3
    for i in range(3, 6):
        s2, m_resumed = step(s2, data.batch(i))

    np.testing.assert_array_equal(
        np.asarray(m_direct["loss"]), np.asarray(m_resumed["loss"])
    )
    for a, b in zip(
        jax.tree_util.tree_leaves(s.params), jax.tree_util.tree_leaves(s2.params)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic_and_shaped():
    data = SyntheticTokens(DataConfig(vocab=128, batch=4, seq_len=16, seed=7))
    b1, b2 = data.batch(5), data.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (4, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(b1["tokens"][:, 1:]), np.asarray(b1["labels"][:, :-1])
    )
    # different index -> different batch
    b3 = data.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_serve_generate_roundtrip():
    from repro.launch.serve import generate

    cfg = get_config("tinyllama-1.1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    seqs = generate(model, params, prompts, max_new_tokens=4)
    assert seqs.shape == (2, 12)
    assert bool((seqs[:, :8] == prompts).all())
