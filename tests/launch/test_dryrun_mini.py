"""Dry-run machinery on a forced 8-device CPU mesh (2x4) — proves the
lower+compile+analysis path itself, independent of the 512-device runs.

NOTE: the 8-device forcing must happen before jax initialises, so this test
module is run in a subprocess by the wrapper test below when the parent
session already holds a 1-device backend.
"""
import json
import os
import subprocess
import sys

import pytest

_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import build_model, get_config
from repro.distributed import sharding as shd
from repro.distributed.train_step import TrainStepConfig, TrainState, make_train_step, make_serve_step
from repro.optim import AdamWState
from repro.analysis.hlo import collective_stats, cost_analysis_dict

mesh = jax.make_mesh((2, 4), ("data", "model"))
assert mesh.devices.size == 8

cfg = dataclasses.replace(
    get_config("tinyllama-1.1b", smoke=True),
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
)
model = build_model(cfg)
params_abs = jax.eval_shape(model.init, jax.random.key(0))
params_sh = shd.param_shardings(params_abs, mesh)
rep = NamedSharding(mesh, P())

step = make_train_step(model, TrainStepConfig(num_microbatches=2))
opt_abs = jax.eval_shape(
    lambda p: AdamWState(
        step=jnp.int32(0),
        mu=jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
        nu=jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
    ),
    params_abs,
)
state_abs = TrainState(params=params_abs, opt=opt_abs, error_feedback={})
state_sh = TrainState(params=params_sh, opt=AdamWState(step=rep, mu=params_sh, nu=params_sh), error_feedback={})
tok = jax.ShapeDtypeStruct((8, 64), jnp.int32, sharding=NamedSharding(mesh, P("data", None)))
batch = {"tokens": tok, "labels": tok}
batch_sh = jax.tree_util.tree_map(lambda s: s.sharding, batch)

lowered = jax.jit(step, in_shardings=(state_sh, batch_sh), donate_argnums=(0,)).lower(state_abs, batch)
compiled = lowered.compile()
cost = cost_analysis_dict(compiled)
coll = collective_stats(compiled.as_text())
mem = compiled.memory_analysis()

# ALSO run it for real on the 8 fake devices (tiny): numbers must be finite
import numpy as np
params = jax.jit(model.init, out_shardings=params_sh)(jax.random.key(0))
from repro.optim import adamw_init
opt = adamw_init(params)
state = TrainState(params=params, opt=opt, error_feedback={})
tokens = jax.device_put(jnp.ones((8, 64), jnp.int32), NamedSharding(mesh, P("data", None)))
state, metrics = jax.jit(step, in_shardings=(state_sh, batch_sh), donate_argnums=(0,))(state, {"tokens": tokens, "labels": tokens})
assert bool(jnp.isfinite(metrics["loss"])), metrics

print("RESULT", {
    "flops": float(cost.get("flops", -1)),
    "collective_count": coll["total_count"],
    "collective_bytes": coll["total_bytes"],
    "loss": float(metrics["loss"]),
})
"""


@pytest.mark.slow
def test_mini_mesh_dryrun_and_real_step(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
        timeout=420,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    result = eval(line[len("RESULT ") :])
    assert result["flops"] > 0
    # a sharded train step must actually communicate
    assert result["collective_count"] > 0
    assert result["collective_bytes"] > 0
    import math

    assert math.isfinite(result["loss"])
