"""The repro.core baseline alias must warn and forward to rl.baselines."""
import jax
import numpy as np
import pytest

from repro import core
from repro.core import ChargaxEnv, EnvConfig
from repro.rl import baselines

jax.config.update("jax_platform_name", "cpu")


def test_core_max_action_alias_warns_and_forwards():
    env = ChargaxEnv(EnvConfig())
    with pytest.warns(DeprecationWarning, match="repro.rl.baselines"):
        legacy = core.make_baseline_max_action(env)
    canonical = baselines.make_baseline_max_action(env)

    obs, _ = env.reset(jax.random.key(0))
    key = jax.random.key(1)
    assert np.array_equal(
        np.asarray(legacy(None, key, obs)), np.asarray(canonical(None, key, obs))
    )
    # and the canonical entry is what the registry serves
    assert baselines.BASELINES["max_charge"] is baselines.max_charge_policy
