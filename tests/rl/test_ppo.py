"""RL stack tests: networks, GAE oracle, learning on an easy objective."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChargaxEnv, EnvConfig
from repro.rl import PPOConfig, evaluate, make_ppo_policy, make_train
from repro.rl import networks
from repro.rl.baselines import BASELINES


def test_actor_critic_shapes():
    key = jax.random.key(0)
    params = networks.init_actor_critic(key, obs_dim=33, n_heads=5, n_actions=7, hidden=(32,))
    obs = jnp.ones((4, 33))
    out = networks.apply_actor_critic(params, obs, 5, 7)
    assert out.logits.shape == (4, 5, 7)
    assert out.value.shape == (4,)


def test_factorized_logprob_and_entropy():
    key = jax.random.key(1)
    logits = jax.random.normal(key, (3, 2, 4))
    action = jnp.zeros((3, 2), jnp.int32)
    lp = networks.log_prob(logits, action)
    expected = jax.nn.log_softmax(logits, -1)[:, :, 0].sum(-1)
    np.testing.assert_allclose(lp, expected, rtol=1e-5)
    # uniform logits -> entropy = heads * log(K)
    ent = networks.entropy(jnp.zeros((1, 2, 4)))
    np.testing.assert_allclose(ent, 2 * np.log(4), rtol=1e-5)


def test_orthogonal_init_is_orthogonal():
    w = networks.orthogonal(jax.random.key(2), (16, 16), scale=1.0)
    np.testing.assert_allclose(np.asarray(w @ w.T), np.eye(16), atol=1e-4)


def test_gae_matches_oracle():
    """GAE inside make_train is scanned; check the recurrence on a toy case."""
    gamma, lam = 0.9, 0.8
    rewards = np.array([1.0, 0.0, 2.0], np.float32)
    values = np.array([0.5, 0.4, 0.3], np.float32)
    dones = np.array([0.0, 0.0, 0.0], np.float32)
    last_val = 0.2
    # oracle: backward recursion
    adv = np.zeros(3, np.float32)
    next_v, gae = last_val, 0.0
    for t in reversed(range(3)):
        delta = rewards[t] + gamma * next_v * (1 - dones[t]) - values[t]
        gae = delta + gamma * lam * (1 - dones[t]) * gae
        adv[t] = gae
        next_v = values[t]

    def scan_fn(carry, t):
        gae, next_value = carry
        r, v, d = t
        delta = r + gamma * next_value * (1 - d) - v
        gae = delta + gamma * lam * (1 - d) * gae
        return (gae, v), gae

    _, out = jax.lax.scan(
        scan_fn,
        (jnp.float32(0.0), jnp.float32(last_val)),
        (jnp.asarray(rewards), jnp.asarray(values), jnp.asarray(dones)),
        reverse=True,
    )
    np.testing.assert_allclose(out, adv, rtol=1e-5)


@pytest.mark.slow
def test_ppo_improves_reward():
    """A short run must improve mean rollout reward over its own start."""
    env = ChargaxEnv(EnvConfig(traffic="high"))
    cfg = PPOConfig(total_timesteps=90_000, num_envs=6, rollout_steps=150, hidden=(64, 64))
    train = jax.jit(make_train(cfg, env))
    out = train(jax.random.key(0))
    rr = np.asarray(out["metrics"]["rollout_reward"])
    assert np.isfinite(rr).all()
    # compare mean of first vs last quartile of updates
    q = max(len(rr) // 4, 1)
    assert rr[-q:].mean() > rr[:q].mean()


def test_baselines_produce_valid_actions():
    env = ChargaxEnv(EnvConfig())
    obs, _ = env.reset(jax.random.key(0))
    for name, make in BASELINES.items():
        pol = make(env)
        a = pol(None, jax.random.key(1), obs)
        assert a.shape == (env.num_action_heads,), name
        assert bool((a >= 0).all() and (a < env.num_actions_per_head).all()), name


def test_evaluate_runs():
    env = ChargaxEnv(EnvConfig())
    res = evaluate(env, BASELINES["max_charge"](env), None, jax.random.key(0), 4)
    assert res["cars_served"] > 0
    assert np.isfinite(res["episode_reward"])


def test_evaluate_params_axis_maps_stacked_params():
    """Regression: evaluate used to hard-code in_axes=(0, 0, 0, None), so a
    stacked (S, ...) scenario/fleet parameter pytree could not be evaluated
    per-episode.  params_axis=0 maps one stacked slice per episode."""
    from repro import scenarios

    env = ChargaxEnv(EnvConfig())
    names = ["shopping_flat", "highway_demand_charge"]
    stacked = scenarios.stack_params(
        [scenarios.make(n).make_params(env) for n in names]
    )
    pol = BASELINES["max_charge"](env)
    res = evaluate(
        env, pol, None, jax.random.key(0),
        num_episodes=len(names), env_params=stacked, params_axis=0,
    )
    assert res["cars_served"] > 0
    assert np.isfinite(res["episode_reward"])

    # the two worlds genuinely differ: per-episode metrics must not collapse
    # to the broadcast single-params result for both scenarios
    res_flat = evaluate(
        env, pol, None, jax.random.key(0),
        num_episodes=len(names),
        env_params=scenarios.make("shopping_flat").make_params(env),
    )
    assert res["daily_profit"] != pytest.approx(res_flat["daily_profit"])

    # stacked size must match num_episodes, loudly
    with pytest.raises(ValueError, match="must equal the stacked"):
        evaluate(
            env, pol, None, jax.random.key(0),
            num_episodes=4, env_params=stacked, params_axis=0,
        )
