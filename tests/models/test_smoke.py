"""Per-architecture smoke tests (assignment requirement).

For every assigned arch: instantiate the REDUCED same-family config, run one
forward and one train step on CPU, assert output shapes + no NaNs.  For a
representative subset, additionally check decode==train per-position logits
(the strongest cache-correctness probe).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, build_model, get_config
from repro.optim import AdamWConfig, adamw_init, adamw_update, apply_updates

B, L = 2, 32


def _data(key, cfg):
    kt, kf = jax.random.split(key)
    tokens = jax.random.randint(kt, (B, L), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)
    frames = (
        jax.random.normal(kf, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
        if cfg.family == "encdec"
        else None
    )
    return tokens, labels, frames


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens, labels, frames = _data(jax.random.key(1), cfg)

    if cfg.family == "encdec":
        logits, aux = model.apply_train(params, tokens, frames)
    else:
        logits, aux = model.apply_train(params, tokens)
    assert logits.shape == (B, L, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens, labels, frames = _data(jax.random.key(1), cfg)

    if cfg.family == "encdec":
        loss_fn = lambda p: model.loss(p, tokens, labels, frames)[0]
    else:
        loss_fn = lambda p: model.loss(p, tokens, labels)[0]

    loss0, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss0))
    gnorm_leaves = [jnp.abs(g).max() for g in jax.tree_util.tree_leaves(grads)]
    assert all(bool(jnp.isfinite(g)) for g in gnorm_leaves)

    opt = adamw_init(params)
    updates, opt, gn = adamw_update(grads, opt, params, 1e-3, AdamWConfig(max_grad_norm=1.0))
    params = apply_updates(params, updates)
    loss1 = jax.jit(loss_fn)(params)
    assert bool(jnp.isfinite(loss1))
    # a single step on random data should reduce loss (lr small, fresh init)
    assert float(loss1) < float(loss0) + 0.5


@pytest.mark.parametrize(
    "arch",
    ["tinyllama-1.1b", "gemma2-9b", "qwen3-moe-30b-a3b", "rwkv6-3b", "zamba2-1.2b"],
)
def test_decode_matches_train(arch):
    """Sequential decode with cache reproduces the teacher-forced logits."""
    import dataclasses

    cfg = get_config(arch, smoke=True)
    if cfg.family == "moe":
        # train-path MoE drops tokens over expert capacity; decode is exact
        # top-k.  For the equivalence check disable dropping.
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens, _, _ = _data(jax.random.key(1), cfg)
    seq = 8
    tokens = tokens[:, :seq]

    logits_train, _ = model.apply_train(params, tokens, remat=False)

    cache = model.init_cache(B, seq)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(seq):
        lg, cache = step(params, cache, tokens[:, t : t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_train), rtol=2e-3, atol=2e-3
    )


def test_whisper_decode_matches_train():
    cfg = get_config("whisper-base", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens, _, frames = _data(jax.random.key(1), cfg)
    seq = 8
    tokens = tokens[:, :seq]

    enc_out = model.encode(params, frames)
    logits_train = model.decode_train(params, tokens, enc_out)

    cache = model.init_cache(params, B, seq, enc_out)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(seq):
        lg, cache = step(params, cache, tokens[:, t : t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_train), rtol=2e-3, atol=2e-3
    )


def test_moe_expert_utilisation():
    """Top-k routing touches many experts; aux loss near 1 for balanced load."""
    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(2), (B, 32), 0, cfg.vocab)
    _, aux = model.apply_train(params, tokens)
    # Switch aux loss is ~1.0 under uniform routing
    assert 0.5 < float(aux) / cfg.n_layers < 2.0
