"""Unit tests for the noise-robust wrapper-overhead estimator (ISSUE 10).

Synthetic timing grids only — no rollouts, no jit.  The estimator exists
because a naive best-of-N ratio on a shared machine reported a 2.41%
"overhead" for a wrapper already PROVEN free by HLO identity; these tests
pin down the properties that make the min-over-round-medians form immune to
that failure.
"""
import numpy as np
import pytest

from benchmarks.speed_table import estimate_overhead


def test_clean_grids_recover_true_overhead():
    raw = [[1.00, 1.00, 1.00]] * 4
    wrapped = [[1.01, 1.01, 1.01]] * 4
    assert estimate_overhead(raw, wrapped) == pytest.approx(0.01, abs=1e-12)


def test_zero_overhead_on_identical_grids():
    rng = np.random.default_rng(0)
    times = rng.uniform(1.0, 1.2, size=(8, 3))
    assert estimate_overhead(times, times) == pytest.approx(0.0, abs=1e-12)


def test_rep_spikes_are_discarded_by_round_medians():
    # one GC/scheduler spike per round, alternating columns: a min-over-all-
    # reps estimator would pair a clean raw rep with a clean wrapped rep from
    # DIFFERENT rounds; the per-round median never sees the spike at all
    raw = [[1.0, 1.0, 9.0], [1.0, 1.0, 1.0]]
    wrapped = [[1.0, 1.0, 1.0], [1.0, 1.0, 9.0]]
    assert estimate_overhead(raw, wrapped) == pytest.approx(0.0, abs=1e-12)


def test_one_sided_load_drift_cannot_inflate_overhead():
    # rounds 0-2 ran while the host was busy (both columns slow, equally —
    # interleaving guarantees that); round 3 is quiet.  The min over rounds
    # reads the quiet round's ratio, not the noisy ones'.
    raw = [[2.0] * 3, [1.8] * 3, [1.5] * 3, [1.00] * 3]
    wrapped = [[2.3] * 3, [2.0] * 3, [1.7] * 3, [1.005] * 3]
    est = estimate_overhead(raw, wrapped)
    assert est == pytest.approx(0.005, abs=1e-12)
    assert est <= 0.02  # the <=2% target holds despite 15% noisy-round ratios


def test_real_overhead_survives_noise():
    # a genuine 5% overhead plus multiplicative noise: the estimate stays
    # near 5% (it is an upper-bound-tightest estimator, within noise floor)
    rng = np.random.default_rng(7)
    base = rng.uniform(1.0, 1.05, size=(8, 3))
    raw = base
    wrapped = base * 1.05 * rng.uniform(1.0, 1.01, size=(8, 3))
    est = estimate_overhead(raw, wrapped)
    assert 0.03 <= est <= 0.07


def test_single_rep_rounds_accepted_as_1d():
    assert estimate_overhead([1.0, 1.0], [1.02, 1.03]) == pytest.approx(0.02)


def test_mismatched_grids_rejected():
    with pytest.raises(ValueError):
        estimate_overhead([[1.0, 1.0]], [[1.0]])
    with pytest.raises(ValueError):
        estimate_overhead([], [])
