"""Wrapper-stack equivalence: every wrapper reproduces the hand-rolled
pattern it absorbed BIT-FOR-BIT under the same keys.

The references below are verbatim copies of the pre-protocol consumer code:
PPO's flat vmap, PPO's nested scenario×env vmap (``nest``/``flat``), PPO's
where(done) auto-reset, and FleetEnv's tuple-returning step.  Both sides are
jitted with identical structure, so identical jaxprs compile to identical
programs and the comparison is exact equality, not tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios
from repro.core import ChargaxEnv, EnvConfig, FleetEnv
from repro.envs import (
    AutoReset,
    FleetAdapter,
    LogWrapper,
    TimeStep,
    VmapWrapper,
)

jax.config.update("jax_platform_name", "cpu")

ENV = ChargaxEnv(EnvConfig())
PARAMS = ENV.default_params
# one-hour episodes so auto-reset boundaries happen inside short rollouts
SHORT_ENV = ChargaxEnv(EnvConfig(episode_hours=1.0))
SHORT_PARAMS = SHORT_ENV.default_params


def _assert_trees_equal(got, ref, ctx=""):
    g = jax.tree_util.tree_leaves(got)
    r = jax.tree_util.tree_leaves(ref)
    assert len(g) == len(r), ctx
    for i, (a, b) in enumerate(zip(g, r)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), f"{ctx}: leaf {i}"


# ---------------------------------------------------------------------------
# VmapWrapper — flat batch
# ---------------------------------------------------------------------------
def test_vmap_wrapper_flat_bit_identical():
    N = 4
    venv = VmapWrapper(ENV, N)
    # the hand-rolled path every consumer used to build
    v_reset = jax.jit(jax.vmap(ENV.reset, in_axes=(0, None)))
    v_step = jax.jit(jax.vmap(ENV.step, in_axes=(0, 0, 0, None)))
    w_reset = jax.jit(venv.reset)
    w_step = jax.jit(venv.step)

    key = jax.random.key(0)
    obs_w, st_w = w_reset(key, PARAMS)
    obs_r, st_r = v_reset(jax.random.split(key, N), PARAMS)
    assert np.array_equal(np.asarray(obs_w), np.asarray(obs_r))
    _assert_trees_equal(st_w, st_r, "reset state")

    for t in range(20):
        k = jax.random.key(100 + t)
        a = venv.sample_action(jax.random.key(200 + t))
        ts = w_step(k, st_w, a, PARAMS)
        assert isinstance(ts, TimeStep)
        ref = v_step(jax.random.split(k, N), st_r, a, PARAMS)
        _assert_trees_equal(tuple(ts), tuple(ref), f"step {t}")
        st_w, st_r = ts.state, ref.state


def test_vmap_wrapper_params_axis_maps_stacked_params():
    names = ["shopping_flat", "highway_demand_charge"]
    stacked = scenarios.stack_params(
        [scenarios.make(n).make_params(ENV) for n in names]
    )
    venv = VmapWrapper(ENV, len(names), params_axis=0)
    v_reset = jax.jit(jax.vmap(ENV.reset, in_axes=(0, 0)))
    key = jax.random.key(1)
    obs_w, st_w = jax.jit(venv.reset)(key, stacked)
    obs_r, st_r = v_reset(jax.random.split(key, len(names)), stacked)
    assert np.array_equal(np.asarray(obs_w), np.asarray(obs_r))
    _assert_trees_equal(st_w, st_r)
    # the two worlds genuinely differ through the per-episode mapping
    assert not np.array_equal(np.asarray(obs_w)[0], np.asarray(obs_w)[1])

    with pytest.raises(ValueError, match="needs explicit params"):
        venv.reset(key)


# ---------------------------------------------------------------------------
# VmapWrapper — nested scenario×env layout (PR 2 semantics)
# ---------------------------------------------------------------------------
def _hand_rolled_nested(env, n_scen, num_envs):
    """Verbatim pre-protocol PPO plumbing (nest/flat/nested vmaps)."""
    n_env_per = num_envs // n_scen

    def nest(x):
        return x.reshape((n_scen, n_env_per) + x.shape[1:])

    def flat(x):
        return x.reshape((num_envs,) + x.shape[2:])

    nested_reset = jax.vmap(jax.vmap(env.reset, in_axes=(0, None)), in_axes=(0, 0))
    nested_step = jax.vmap(
        jax.vmap(env.step, in_axes=(0, 0, 0, None)), in_axes=(0, 0, 0, 0)
    )

    def v_reset(keys, params):
        obs, state = nested_reset(nest(keys), params)
        return flat(obs), jax.tree_util.tree_map(flat, state)

    def v_step(keys, state, action, params):
        obs, state, reward, done, info = nested_step(
            nest(keys), jax.tree_util.tree_map(nest, state), nest(action), params
        )
        return (
            flat(obs),
            jax.tree_util.tree_map(flat, state),
            flat(reward),
            flat(done),
            jax.tree_util.tree_map(flat, info),
        )

    return v_reset, v_step


def test_vmap_wrapper_nested_scenario_bit_identical():
    names = ["shopping_flat", "shopping_pv_tou", "highway_demand_charge"]
    stacked = scenarios.stack_params(
        [scenarios.make(n).make_params(ENV) for n in names]
    )
    n_scen, num_envs = len(names), 6
    venv = VmapWrapper(ENV, num_envs, num_scenarios=n_scen)
    v_reset, v_step = _hand_rolled_nested(ENV, n_scen, num_envs)
    v_reset, v_step = jax.jit(v_reset), jax.jit(v_step)
    w_reset, w_step = jax.jit(venv.reset), jax.jit(venv.step)

    key = jax.random.key(2)
    obs_w, st_w = w_reset(key, stacked)
    obs_r, st_r = v_reset(jax.random.split(key, num_envs), stacked)
    assert obs_w.shape == (num_envs, ENV.observation_space.shape[0])
    assert np.array_equal(np.asarray(obs_w), np.asarray(obs_r))
    _assert_trees_equal(st_w, st_r, "reset")

    for t in range(12):
        k = jax.random.key(300 + t)
        a = venv.sample_action(jax.random.key(400 + t))
        ts = w_step(k, st_w, a, stacked)
        ref = v_step(jax.random.split(k, num_envs), st_r, a, stacked)
        _assert_trees_equal(tuple(ts), tuple(ref), f"step {t}")
        st_w, st_r = ts.state, ref[1]

    with pytest.raises(ValueError, match="not a multiple"):
        VmapWrapper(ENV, 4, num_scenarios=3)
    with pytest.raises(ValueError, match="not both"):
        VmapWrapper(ENV, 6, params_axis=0, num_scenarios=3)


# ---------------------------------------------------------------------------
# AutoReset — the where(done) restart pattern
# ---------------------------------------------------------------------------
def test_autoreset_bit_identical_across_episode_boundary():
    N = 3
    env, params = SHORT_ENV, SHORT_PARAMS
    venv = VmapWrapper(env, N)
    wenv = AutoReset(venv)
    v_reset = jax.vmap(env.reset, in_axes=(0, None))
    v_step = jax.vmap(env.step, in_axes=(0, 0, 0, None))

    def hand_rolled(key, state, action, params):
        """Verbatim pre-protocol PPO auto-reset: step, reset, select.

        ``params`` stays an argument (not a closure) so both jitted programs
        see the same constant structure and compile identically.
        """
        k_step, k_reset = jax.random.split(key)
        n_obs, n_state, reward, done, info = v_step(
            jax.random.split(k_step, N), state, action, params
        )
        r_obs, r_state = v_reset(jax.random.split(k_reset, N), params)
        n_obs = jnp.where(done[:, None], r_obs, n_obs)
        n_state = jax.tree_util.tree_map(
            lambda r, n: jnp.where(
                done.reshape(done.shape + (1,) * (n.ndim - 1)), r, n
            ),
            r_state,
            n_state,
        )
        return n_obs, n_state, reward, done, info

    hand_rolled = jax.jit(hand_rolled)
    w_step = jax.jit(wenv.step)

    key = jax.random.key(3)
    _, st_w = wenv.reset(key, params)
    st_r = jax.tree_util.tree_map(lambda x: x, st_w)
    n_done = 0
    for t in range(2 * env.config.episode_steps + 3):
        k = jax.random.key(500 + t)
        a = venv.sample_action(jax.random.key(600 + t))
        ts = w_step(k, st_w, a, params)
        ref = hand_rolled(k, st_r, a, params)
        _assert_trees_equal(tuple(ts), tuple(ref), f"step {t}")
        n_done += int(np.asarray(ts.done).sum())
        # where done, the state really restarted (episode clock back to 0)
        t_next = np.asarray(ts.state.t)
        assert np.all((t_next == 0) == np.asarray(ts.done))
        st_w, st_r = ts.state, ref[1]
    assert n_done >= 2 * N  # the rollout crossed episode boundaries


def test_autoreset_nested_scenario_stack():
    """AutoReset(VmapWrapper(num_scenarios=S)) — the exact PPO stack."""
    names = ["shopping_flat", "shopping_pv_tou"]
    stacked = scenarios.stack_params(
        [scenarios.make(n).make_params(SHORT_ENV) for n in names]
    )
    wenv = AutoReset(VmapWrapper(SHORT_ENV, 4, num_scenarios=2))
    step = jax.jit(wenv.step)
    key = jax.random.key(4)
    _, state = wenv.reset(key, stacked)
    dones = 0
    for t in range(SHORT_ENV.config.episode_steps + 2):
        a = wenv.sample_action(jax.random.key(700 + t))
        ts = step(jax.random.key(800 + t), state, a, stacked)
        state = ts.state
        dones += int(np.asarray(ts.done).sum())
    assert dones == 4  # every env finished exactly one episode and restarted
    assert np.all(np.isfinite(np.asarray(ts.reward)))


# ---------------------------------------------------------------------------
# LogWrapper — episode accounting
# ---------------------------------------------------------------------------
def test_log_wrapper_reports_episode_totals():
    env, params = SHORT_ENV, SHORT_PARAMS
    wenv = LogWrapper(AutoReset(env))
    step = jax.jit(wenv.step)
    key = jax.random.key(5)
    obs, state = wenv.reset(key, params)
    rewards = []
    T = env.config.episode_steps
    for t in range(T + 3):
        a = env.sample_action(jax.random.key(900 + t))
        ts = step(jax.random.key(1000 + t), state, a, params)
        state = ts.state
        rewards.append(float(ts.reward))
        if t < T - 1:  # mid-episode: nothing returned yet
            assert not bool(ts.info["returned_episode"])
            assert float(ts.info["episode_return"]) == 0.0
        elif t == T - 1:  # episode end: totals surface in info
            assert bool(ts.info["returned_episode"])
            np.testing.assert_allclose(
                float(ts.info["episode_return"]), sum(rewards), rtol=1e-5
            )
            assert int(ts.info["episode_length"]) == T
            ep_total = float(ts.info["episode_return"])
        else:  # next episode: returned stats stay frozen
            assert not bool(ts.info["returned_episode"])
            assert float(ts.info["episode_return"]) == ep_total
            assert int(ts.info["episode_length"]) == T


# ---------------------------------------------------------------------------
# FleetAdapter — the protocol view of FleetEnv
# ---------------------------------------------------------------------------
def test_fleet_adapter_bit_identical_to_fleet_env():
    fleet = FleetEnv(["paper_16", "deep_4x4"])
    adapter = FleetAdapter(fleet)
    params = fleet.default_params
    key = jax.random.key(6)

    obs_a, st_a = adapter.reset(key, params)
    obs_f, st_f = fleet.reset(key, params)
    assert np.array_equal(np.asarray(obs_a), np.asarray(obs_f))
    _assert_trees_equal(st_a, st_f)

    a = adapter.sample_action(jax.random.key(7))
    ts = jax.jit(adapter.step)(jax.random.key(8), st_a, a, params)
    ref = jax.jit(fleet.step)(jax.random.key(8), st_f, a, params)
    assert isinstance(ts, TimeStep)
    _assert_trees_equal(tuple(ts), tuple(ref))

    # typed (S, ...) spaces derived from the template station
    S = fleet.n_stations
    assert adapter.observation_space.shape == (S, fleet.template.obs_dim)
    assert adapter.action_space.shape == (S, fleet.template.num_action_heads)
    assert adapter.action_space.contains(np.asarray(a))
    assert adapter.unwrapped is fleet


def test_autoreset_composes_over_fleet_adapter():
    fleet = FleetEnv(["paper_16", "single_dc_8"], EnvConfig(episode_hours=1.0))
    wenv = AutoReset(FleetAdapter(fleet))
    params = fleet.default_params
    _, state = wenv.reset(jax.random.key(9), params)
    step = jax.jit(wenv.step)
    T = fleet.config.episode_steps
    for t in range(T):
        a = wenv.sample_action(jax.random.key(1100 + t))
        ts = step(jax.random.key(1200 + t), state, a, params)
        state = ts.state
    # the per-station dones fired at the horizon and every station restarted
    assert np.all(np.asarray(ts.done))
    assert np.all(np.asarray(ts.state.t) == 0)


# ---------------------------------------------------------------------------
# GymnasiumBridge — optional non-JAX surface
# ---------------------------------------------------------------------------
def test_gymnasium_bridge_smoke():
    gym = pytest.importorskip("gymnasium")
    from repro.envs import GymnasiumBridge

    env = GymnasiumBridge(SHORT_ENV, seed=0)
    assert isinstance(env, gym.Env)
    assert env.observation_space.shape == SHORT_ENV.observation_space.shape
    obs, info = env.reset(seed=17)
    assert env.observation_space.contains(obs)
    truncations = 0
    for t in range(SHORT_ENV.config.episode_steps):
        obs, reward, terminated, truncated, info = env.step(
            env.action_space.sample()
        )
        assert env.observation_space.contains(obs)
        assert isinstance(reward, float) and not terminated
        truncations += int(truncated)
    assert truncations == 1  # fixed horizon -> exactly one truncation
    obs2, _ = env.reset()
    assert env.observation_space.contains(obs2)
