"""Protocol conformance: every registered scenario runs through the wrapper
stack under ONE jit entry; consumers contain no env-specific vmap plumbing.

This file is the CI protocol-conformance job (``.github/workflows/ci.yml``).
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np

from repro import scenarios
from repro.core import ChargaxEnv, EnvConfig, FleetEnv
from repro.envs import (
    AutoReset,
    Environment,
    FleetAdapter,
    LogWrapper,
    TimeStep,
    VmapWrapper,
)

jax.config.update("jax_platform_name", "cpu")


def test_chargax_env_implements_the_protocol():
    env = ChargaxEnv(EnvConfig())
    assert isinstance(env, Environment)
    ts = env.step(
        jax.random.key(0),
        env.reset(jax.random.key(1))[1],
        env.sample_action(jax.random.key(2)),
    )
    assert isinstance(ts, TimeStep)
    # NamedTuple: typed access AND the historical 5-tuple unpacking
    obs, state, reward, done, info = ts
    assert obs is ts.obs and state is ts.state and info is ts.info
    assert env.unwrapped is env


def test_wrappers_preserve_identity_and_spaces():
    env = ChargaxEnv(EnvConfig())
    stack = VmapWrapper(LogWrapper(AutoReset(env)), 3)
    assert isinstance(stack, Environment)
    assert stack.unwrapped is env
    assert stack.observation_space.shape == (3,) + env.observation_space.shape
    assert stack.action_space.shape == (3,) + env.action_space.shape
    # attribute delegation reaches the innermost env
    assert stack.config is env.config
    assert stack.n_evse == env.n_evse


def test_catalog_one_jit_entry_through_wrapper_stack():
    """Acceptance: every registered scenario steps through the FULL wrapper
    stack (AutoReset -> LogWrapper -> VmapWrapper) with one compilation —
    enforced by the recompile sentinel, which names the offending function
    and avals if a scenario swap ever recompiles."""
    from repro.obs import cache_entries, compile_guard

    env = ChargaxEnv(EnvConfig())
    wenv = VmapWrapper(LogWrapper(AutoReset(env)), 2)
    step = jax.jit(wenv.step)
    all_params = [scenarios.make(n).make_params(env) for n in scenarios.names()]
    assert len(all_params) >= 25  # full catalog incl. V2G/REAL/GRID/CITY packs
    assert set(scenarios.GRID_PACK) <= set(scenarios.names())
    assert set(scenarios.CITY_PACK) <= set(scenarios.names())

    obs, state = wenv.reset(jax.random.key(0), all_params[0])
    action = wenv.sample_action(jax.random.key(1))
    ts = step(jax.random.key(2), state, action, all_params[0])  # the one compile
    assert cache_entries(step) == 1
    with compile_guard(f"{len(all_params)}-scenario catalog"):
        for p in all_params[1:]:
            ts = step(jax.random.key(2), state, action, p)
            assert np.isfinite(float(np.asarray(ts.reward).sum()))
    assert cache_entries(step) == 1  # pure array swaps, no recompile


def test_catalog_one_jit_entry_fused_step():
    """Acceptance (ISSUE 10): with ``fused_step=True`` the whole scenario
    catalog still steps through the full wrapper stack under ONE compiled
    step — the hoisted pole pack is a pure array leaf of params, so scenario
    swaps never retrace the fused route."""
    from repro.obs import cache_entries, compile_guard

    env = ChargaxEnv(EnvConfig(fused_step=True))
    wenv = VmapWrapper(LogWrapper(AutoReset(env)), 2)
    step = jax.jit(wenv.step)
    all_params = [scenarios.make(n).make_params(env) for n in scenarios.names()]
    assert len(all_params) >= 25
    for p in all_params:  # the hoisted pack survives scenario lowering
        assert p.pole is not None

    obs, state = wenv.reset(jax.random.key(0), all_params[0])
    action = wenv.sample_action(jax.random.key(1))
    ts = step(jax.random.key(2), state, action, all_params[0])  # the one compile
    assert cache_entries(step) == 1
    with compile_guard(f"{len(all_params)}-scenario fused catalog"):
        for p in all_params[1:]:
            ts = step(jax.random.key(2), state, action, p)
            assert np.isfinite(float(np.asarray(ts.reward).sum()))
    assert cache_entries(step) == 1


def test_fused_flag_off_step_hlo_unchanged():
    """Acceptance (ISSUE 10): ``fused_step=False`` envs lower to byte-identical
    HLO — the flag (and the ``EnvParams.pole=None`` slot it leaves empty) is
    invisible to the staged path, including after a with_fused_step round
    trip."""
    env_default = ChargaxEnv(EnvConfig())
    env_off = env_default.with_fused_step(True).with_fused_step(False)
    p_default = env_default.default_params
    p_off = env_off.default_params
    assert p_default.pole is None and p_off.pole is None
    # pole=None is an empty pytree subtree: no extra leaves for jit to see
    assert jax.tree_util.tree_structure(p_default) == jax.tree_util.tree_structure(p_off)

    _, state = env_default.reset(jax.random.key(0))
    action = env_default.sample_action(jax.random.key(1))

    def hlo(env, params):
        return jax.jit(env.step).lower(
            jax.random.key(2), state, action, params
        ).as_text()

    assert hlo(env_default, p_default) == hlo(env_off, p_off)


def test_fleet_adapter_conforms():
    fleet = FleetEnv(["paper_16", "deep_4x4"])
    adapter = FleetAdapter(fleet)
    assert isinstance(adapter, Environment)
    obs, state = adapter.reset(jax.random.key(0))
    ts = adapter.step(jax.random.key(1), state, adapter.sample_action(jax.random.key(2)))
    assert isinstance(ts, TimeStep)
    assert adapter.observation_space.contains(np.asarray(ts.obs))


def test_coupled_fleet_one_jit_entry_over_catalog_with_grid_pack():
    """Acceptance: the grid-coupled FleetEnv steps the WHOLE catalog — GRID_PACK
    included — under one compiled step.  Per-station scenario params are
    stacked (S, ...) slices; swapping which scenarios the fleet runs is a pure
    array swap through the shared-feeder curtailment seam."""
    from repro.obs import assert_one_compiled_step

    fleet = FleetEnv(["paper_16", "deep_4x4"], couple_grid=True)
    adapter = FleetAdapter(fleet)
    all_names = scenarios.names()
    assert set(scenarios.GRID_PACK) <= set(all_names)

    def fleet_params(name):
        sc = scenarios.make(name)
        return scenarios.stack_params(
            [sc.make_params(env) for env in fleet.envs]
        )

    params_list = [fleet_params(n) for n in all_names]
    assert_one_compiled_step(adapter, params_list, num_envs=2)


def test_stacking_helper_is_shared():
    """Satellite: ONE pytree-stacking util consumed by fleets and scenarios."""
    from repro import utils
    from repro.core import fleet

    assert scenarios.stack_params is utils.stack_pytrees
    assert fleet.stack_params is utils.stack_pytrees


def test_ppo_contains_no_env_vmap_plumbing():
    """Acceptance: the hand-rolled nest/flat/v_reset/v_step glue is gone from
    rl/ppo.py — batching lives in the wrapper stack only."""
    from repro.rl import ppo

    src = inspect.getsource(ppo)
    for needle in ("def nest", "def flat", "def v_reset", "def v_step",
                   "nested_reset", "nested_step", "jax.vmap(env."):
        assert needle not in src, f"ppo.py still hand-rolls {needle!r}"
    assert "VmapWrapper" in src and "AutoReset" in src


def test_baselines_are_policies_under_the_action_space():
    """Satellite: every baseline (incl. the historical bare-array max-charge
    helper) is a policy(params, key, obs) -> action under action_space."""
    from repro.core import make_baseline_max_action
    from repro.rl.baselines import BASELINES

    env = ChargaxEnv(EnvConfig())
    obs, _ = env.reset(jax.random.key(0))
    factories = dict(BASELINES)
    factories["core_max_action"] = make_baseline_max_action
    for name, make in factories.items():
        pol = make(env)
        a = pol(None, jax.random.key(1), obs)
        assert env.action_space.contains(np.asarray(a)), name
        # batched obs -> batched actions with the space's trailing shape
        ab = pol(None, jax.random.key(1), jnp.stack([obs] * 4))
        assert ab.shape == (4,) + env.action_space.shape, name
