"""Typed spaces: shapes, sampling, membership, batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChargaxEnv, EnvConfig
from repro.envs import spaces

jax.config.update("jax_platform_name", "cpu")


def test_box_sample_and_contains():
    b = spaces.Box(-1.0, 2.0, (3, 2))
    x = b.sample(jax.random.key(0))
    assert x.shape == (3, 2) and x.dtype == jnp.float32
    assert b.contains(np.asarray(x))
    assert not b.contains(np.full((3, 2), 5.0))
    assert not b.contains(np.zeros((2, 3)))


def test_box_unbounded_axes_sample_finite():
    b = spaces.Box(-np.inf, np.inf, (4,))
    x = b.sample(jax.random.key(1))
    assert np.all(np.isfinite(np.asarray(x)))
    assert b.contains(np.asarray(x))


def test_discrete():
    d = spaces.Discrete(5)
    x = d.sample(jax.random.key(2))
    assert d.contains(np.asarray(x))
    assert not d.contains(np.asarray(7))


def test_multidiscrete_uniform_grid():
    m = spaces.MultiDiscrete(np.full((6,), 11))
    assert m.shape == (6,) and m.num_categories == 11
    x = m.sample(jax.random.key(3))
    assert m.contains(np.asarray(x))
    assert not m.contains(np.full((6,), 11))  # out of range
    assert not m.contains(np.zeros((6,)))  # float dtype rejected
    # uniform sampling matches the historical randint draws exactly
    ref = jax.random.randint(jax.random.key(3), (6,), 0, 11)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(ref))


def test_multidiscrete_non_uniform():
    m = spaces.MultiDiscrete([2, 3, 5])
    with pytest.raises(ValueError, match="non-uniform"):
        _ = m.num_categories
    x = np.asarray(m.sample(jax.random.key(4)))
    assert m.contains(x)


def test_batch_prepends_axis():
    b = spaces.batch(spaces.Box(0.0, 1.0, (3,)), 4)
    assert b.shape == (4, 3)
    m = spaces.batch(spaces.MultiDiscrete(np.full((2,), 7)), 5)
    assert m.shape == (5, 2) and m.num_categories == 7
    d = spaces.batch(spaces.Discrete(3), 2)
    assert d.shape == (2,) and d.num_categories == 3


def test_chargax_spaces_describe_the_env():
    env = ChargaxEnv(EnvConfig())
    obs, _ = env.reset(jax.random.key(0))
    assert env.observation_space.shape == obs.shape
    assert env.observation_space.contains(np.asarray(obs))
    a = env.sample_action(jax.random.key(1))
    assert env.action_space.contains(np.asarray(a))
    # the legacy integer properties are aliases derived from the spaces
    assert env.obs_dim == env.observation_space.shape[0]
    assert env.num_action_heads == env.action_space.shape[0] == env.n_evse + 1
    assert (
        env.num_actions_per_head
        == env.action_space.num_categories
        == 2 * env.config.discretization + 1
    )
