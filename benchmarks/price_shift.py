"""Paper Figure 5: distribution shift across electricity-price years.

Trains PPO on each year in {2021, 2022, 2023} of the synthetic NL price data
(2022 = energy-crisis regime) and evaluates every agent on every year.
Validation claims: (i) off-diagonal generalisation gap exists, (ii) training
on the crisis year (2022) is hard — 2021/2023-trained agents can match or
beat the 2022-trained agent even when evaluated on 2022."""
from __future__ import annotations

import jax

from repro.core import ChargaxEnv, EnvConfig
from repro.rl import PPOConfig, evaluate, make_ppo_policy, make_train

YEARS = (2021, 2022, 2023)


def run(quick: bool = True) -> list[tuple[str, float, str]]:
    rows = []
    timesteps = 300_000 if quick else 1_500_000
    env = ChargaxEnv(EnvConfig(scenario="shopping", traffic="medium"))
    eval_params = {y: env.make_params(price_year=y) for y in YEARS}

    for train_year in YEARS:
        cfg = PPOConfig(total_timesteps=timesteps, num_envs=12, rollout_steps=300)
        train = jax.jit(make_train(cfg, env, env_params=eval_params[train_year]))
        out = train(jax.random.key(0))
        pol = make_ppo_policy(env)
        evals = {}
        for eval_year in YEARS:
            res = evaluate(
                env, pol, out["runner_state"].params, jax.random.key(7),
                32, env_params=eval_params[eval_year],
            )
            evals[eval_year] = res["episode_reward"]
        rows.append(
            (
                f"fig5_train_{train_year}",
                evals[train_year],
                " ".join(f"eval{y}={evals[y]:.0f}" for y in YEARS),
            )
        )
    return rows


if __name__ == "__main__":
    for name, v, d in run():
        print(f"{name},{v:.2f},{d}")
