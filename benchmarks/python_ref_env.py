"""Pure-Python/numpy reference implementation of the Chargax MDP.

This is the Table-2 comparison baseline: the *same* environment semantics
written the way CPU gym environments are written (per-env Python object,
numpy scalar math, host RNG).  EV2Gym/Chargym/SustainGym are not installable
offline; this is the generous stand-in — it has no gym-wrapper overhead and
implements the identical transition, so the measured speedup is attributable
to the paper's contribution (JAX vectorisation + JIT), not API differences.
"""
from __future__ import annotations

import numpy as np

from repro.core import ChargaxEnv, EnvConfig
from repro.core.datasets import (
    arrival_rate_curve,
    car_table,
    price_profile,
    user_profile_params,
)


class PythonChargax:
    """Single-environment, object-style port of ChargaxEnv."""

    def __init__(self, config: EnvConfig | None = None, seed: int = 0):
        self.cfg = config or EnvConfig()
        jax_env = ChargaxEnv(self.cfg)
        p = jax_env.default_params
        self.member = np.asarray(p.member)
        self.node_budget = np.asarray(p.node_budget)
        self.voltage = np.asarray(p.evse_voltage)
        self.imax = np.asarray(p.evse_max_current)
        self.path_eff = np.asarray(p.evse_path_eff)
        self.is_dc = np.asarray(p.evse_is_dc)
        self.n = len(self.voltage)
        self.batt = dict(
            v=float(p.batt_voltage), imax=float(p.batt_max_current),
            cap=float(p.batt_capacity), eff=float(p.batt_eff),
            tau=float(p.batt_tau), soc0=float(p.batt_init_soc),
        )
        self.prices = price_profile(self.cfg.price_region, self.cfg.price_year, self.cfg.dt_minutes)
        self.arrivals = arrival_rate_curve(self.cfg.scenario, self.cfg.traffic, self.cfg.dt_minutes)
        self.cars = car_table(self.cfg.car_region)
        self.user = user_profile_params(self.cfg.scenario)
        self.p_sell, self.sell_disc, self.c_dt = 0.75, 0.9, 0.25
        self.dt = self.cfg.dt_hours
        self.rng = np.random.default_rng(seed)
        self.spd = self.cfg.steps_per_day

    # ------------------------------------------------------------------
    def reset(self):
        self.t = 0
        self.day = int(self.rng.integers(0, 365))
        self.price_day = self.prices[self.day]
        n = self.n
        self.occ = np.zeros(n)
        self.cur = np.zeros(n)
        self.soc = np.zeros(n)
        self.e_rem = np.zeros(n)
        self.t_rem = np.zeros(n, np.int64)
        self.cap = np.zeros(n)
        self.rbar = np.zeros(n)
        self.tau = np.zeros(n)
        self.utype = np.zeros(n)
        self.b_soc = self.batt["soc0"]
        self.b_cur = 0.0
        return self._obs()

    def _obs(self):
        # observation content mirrors ChargaxEnv.observe (shape parity only):
        # 8 features per port — the 5th (v2g_debt/cap) is always 0 here, the
        # reference env has no V2G settlement
        feats = []
        for i in range(self.n):
            feats += [
                self.occ[i], self.cur[i] / self.imax[i], self.soc[i],
                self.e_rem[i] / max(self.cap[i], 1.0),
                0.0,  # v2g_debt / cap
                np.clip(self.t_rem[i] / self.spd, -1, 1),
                self._rhat(i) / self.imax[i], self.utype[i],
            ]
        feats += [self.b_soc, self.b_cur / self.batt["imax"]]
        ph = 2 * np.pi * self.t / self.spd
        feats += [np.sin(ph), np.cos(ph), float(self.day % 7 < 5), self.day / 365.0]
        idx = self.t % self.spd
        feats += [self.price_day[idx], self.price_day[idx], float(self.price_day.mean())]
        return np.array(feats, np.float32)

    def _rhat(self, i, soc=None):
        soc = self.soc[i] if soc is None else soc
        if self.occ[i] < 0.5:
            return 0.0
        if soc <= self.tau[i]:
            return self.rbar[i]
        return self.rbar[i] * (1 - soc) / max(1 - self.tau[i], 1e-6)

    # ------------------------------------------------------------------
    def step(self, action: np.ndarray):
        d = self.cfg.discretization
        frac = (action.astype(np.float64) - d) / d
        port_t = np.maximum(frac[:-1], 0.0) * self.imax
        batt_t = frac[-1] * self.batt["imax"]

        # stage 1: clips per port
        cur = np.zeros(self.n)
        for i in range(self.n):
            if self.occ[i] < 0.5:
                continue
            amp_per_kwh = 1000.0 / (self.voltage[i] * self.dt)
            up = min(
                self._rhat(i), self.imax[i],
                self.e_rem[i] * amp_per_kwh,
                (1 - self.soc[i]) * self.cap[i] * amp_per_kwh,
            )
            cur[i] = np.clip(port_t[i], 0.0, max(up, 0.0))
        # battery
        b = self.batt
        bsoc = self.b_soc
        b_chg = b["imax"] if bsoc <= b["tau"] else b["imax"] * (1 - bsoc) / (1 - b["tau"])
        b_dis = b["imax"] if (1 - bsoc) <= b["tau"] else b["imax"] * bsoc / (1 - b["tau"])
        apk = 1000.0 / (b["v"] * self.dt)
        b_up = min(b_chg, (1 - bsoc) * b["cap"] * apk / b["eff"])
        b_dn = min(b_dis, bsoc * b["cap"] * b["eff"] * apk)
        b_cur = float(np.clip(batt_t, -b_dn, b_up))

        # Eq. 5 rescale
        leaf = np.append(cur, b_cur)
        load = self.member @ np.abs(leaf)
        s_node = np.minimum(1.0, self.node_budget / np.maximum(load, 1e-9))
        excess = float(np.max(np.maximum(load - self.node_budget, 0.0)))
        scale = np.ones(self.n + 1)
        for k in range(len(self.node_budget)):
            mask = self.member[k] > 0
            scale[mask] = np.minimum(scale[mask], s_node[k])
        leaf *= scale
        cur, b_cur = leaf[:-1], leaf[-1]

        # stage 2: charge
        e_car = self.voltage * cur * self.dt / 1000.0
        self.soc = np.clip(self.soc + e_car / np.maximum(self.cap, 1e-6), 0, 1)
        self.e_rem = np.maximum(self.e_rem - e_car, 0.0)
        self.t_rem -= 1
        self.cur = cur
        e_b = b["v"] * b_cur * self.dt / 1000.0
        self.b_soc = np.clip(
            self.b_soc + (e_b * b["eff"] if e_b >= 0 else e_b / b["eff"]) / b["cap"], 0, 1
        )
        self.b_cur = b_cur

        # stage 3: departures
        missing = over = 0.0
        for i in range(self.n):
            if self.occ[i] < 0.5:
                continue
            leave = (self.utype[i] < 0.5 and self.t_rem[i] <= 0) or (
                self.utype[i] >= 0.5 and self.e_rem[i] <= 1e-6
            )
            if leave:
                if self.utype[i] < 0.5:
                    missing += max(self.e_rem[i], 0.0)
                else:
                    over += max(-self.t_rem[i], 0)
                self.occ[i] = self.cur[i] = self.soc[i] = self.e_rem[i] = 0.0
                self.cap[i] = self.rbar[i] = self.tau[i] = self.utype[i] = 0.0
                self.t_rem[i] = 0

        # stage 4: arrivals
        rate = self.arrivals[self.t % self.spd]
        m = int(self.rng.poisson(rate))
        free = [i for i in range(self.n) if self.occ[i] < 0.5]
        rejected = max(m - len(free), 0)
        for j in range(min(m, len(free))):
            i = free[j]
            row = self.cars[self.rng.choice(len(self.cars), p=self.cars[:, 0])]
            _, cap_kwh, ac_kw, dc_kw, tau = row
            kw = dc_kw if self.is_dc[i] > 0.5 else ac_kw
            stay_mu, stay_sig = self.user["stay"]
            stay_h = float(
                np.exp(np.log(stay_mu) - 0.5 * stay_sig**2 + stay_sig * self.rng.normal())
            )
            soc0 = float(np.clip(self.rng.beta(*self.user["soc0"]), 0.02, 0.95))
            tgt = float(
                np.clip(
                    self.user["target"][0] + self.user["target"][1] * self.rng.normal(),
                    soc0 + 0.05, 1.0,
                )
            )
            self.occ[i] = 1.0
            self.soc[i] = soc0
            self.cap[i] = cap_kwh
            self.rbar[i] = kw * 1000.0 / self.voltage[i]
            self.tau[i] = tau
            self.e_rem[i] = (tgt - soc0) * cap_kwh
            self.t_rem[i] = max(int(stay_h * self.spd / 24), 1)
            self.utype[i] = 0.0 if self.rng.random() < self.user["p_time_sensitive"] else 1.0

        # reward (Eq. 1-3, alpha = 0)
        e_net = float(e_car.sum())
        e_in = float(np.where(e_car > 0, e_car / self.path_eff, 0).sum())
        e_out = float(np.where(e_car < 0, e_car * self.path_eff, 0).sum())
        e_grid = e_in + e_out + e_b
        p_buy = float(self.price_day[self.t % self.spd])
        grid_cost = p_buy * e_grid if e_grid > 0 else self.sell_disc * p_buy * e_grid
        reward = self.p_sell * e_net - grid_cost - self.c_dt

        self.t += 1
        done = self.t >= self.cfg.episode_steps
        return self._obs(), reward, done, {"rejected": rejected, "missing": missing}

    def sample_action(self):
        return self.rng.integers(0, 2 * self.cfg.discretization + 1, self.n + 1)
