"""Mesh-sharded fleet throughput: the station axis placed over the devices.

Host-count-aware companion to ``benchmarks/fleet_throughput.py``: a data
mesh is built over EVERY visible device (``launch.mesh.make_data_mesh``),
the stacked fleet parameters are ``device_put`` onto it, and ``FleetEnv``'s
ambient-mesh constraints keep the whole jitted 24h rollout sharded — no host
transfers, the paper's on-device-rollout claim across chips.  Fleet sizes
scale with the device count so the station axis always divides the mesh.

On 1 device this measures the constraint overhead (~zero: the annotations
lower to no-ops); under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
or on a real slice it exercises the multi-device path.  Emits a
machine-readable ``FLEET_SHARDED_JSON`` line and sets ``LAST_SUMMARY`` for
``benchmarks/run.py`` to persist as ``BENCH_fleet_sharded.json``.
"""
from __future__ import annotations

import jax

from benchmarks.fleet_throughput import bench_fleet
from repro.launch.mesh import make_data_mesh
from repro.obs import emit_json_line

LAST_SUMMARY: dict | None = None


def bench_sharded_fleet(n_replicas: int, n_days: int = 1):
    """Seconds for a jitted ``n_days``-day rollout, stations sharded on the mesh."""
    return bench_fleet(n_replicas, n_days, mesh=make_data_mesh())


def run(quick: bool = True):
    """Benchmark-harness entry point: list of (name, us_per_call, derived)."""
    global LAST_SUMMARY
    n_dev = jax.device_count()
    sizes = (n_dev, 4 * n_dev) if quick else (n_dev, 4 * n_dev, 16 * n_dev)
    rows = []
    summary = []
    for n in sizes:
        secs, fleet = bench_sharded_fleet(n)
        steps = fleet.config.episode_steps * fleet.n_stations
        sps = steps / secs
        rows.append(
            (
                f"fleet_sharded_{fleet.n_stations}_stations",
                secs * 1e6 / fleet.config.episode_steps,
                f"{sps:.0f} station-steps/s over {n_dev} device(s)",
            )
        )
        summary.append(
            {
                "n_stations": fleet.n_stations,
                "steps_per_sec": round(sps, 1),
                "seconds_per_24h_rollout": round(secs, 4),
            }
        )
    LAST_SUMMARY = {
        "num_envs": summary[-1]["n_stations"],
        "steps_per_sec": summary[-1]["steps_per_sec"],
        "device_count": n_dev,
        "process_count": jax.process_count(),
        "fleet_sharded": summary,
    }
    emit_json_line("FLEET_SHARDED_JSON", LAST_SUMMARY)
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(",".join(str(x) for x in row))
