"""Paper Figure 4a: PPO vs always-max-charge baseline, shopping scenario,
three traffic levels.  Validation claim: the RL agent's daily profit meets or
exceeds the baseline, and profit grows with traffic."""
from __future__ import annotations

import jax

from repro.core import ChargaxEnv, EnvConfig
from repro.rl import PPOConfig, evaluate, make_ppo_policy, make_train
from repro.rl.baselines import max_charge_policy


def run(quick: bool = True, seeds: int = 2) -> list[tuple[str, float, str]]:
    rows = []
    timesteps = 400_000 if quick else 2_000_000
    for traffic in ("low", "medium", "high"):
        env = ChargaxEnv(EnvConfig(scenario="shopping", traffic=traffic))
        base = evaluate(env, max_charge_policy(env), None, jax.random.key(99), 32)

        ppo_profit = []
        for seed in range(seeds):
            cfg = PPOConfig(
                total_timesteps=timesteps, num_envs=12, rollout_steps=300, hidden=(128, 128)
            )
            train = jax.jit(make_train(cfg, env))
            out = train(jax.random.key(seed))
            pol = make_ppo_policy(env)
            res = evaluate(env, pol, out["runner_state"].params, jax.random.key(100 + seed), 32)
            ppo_profit.append(res["daily_profit"])
        mean_ppo = sum(ppo_profit) / len(ppo_profit)
        rows.append(
            (
                f"fig4a_{traffic}",
                mean_ppo,
                f"ppo_daily_profit={mean_ppo:.0f} baseline={base['daily_profit']:.0f} "
                f"ratio={mean_ppo/max(base['daily_profit'],1e-9):.2f}",
            )
        )
    return rows


if __name__ == "__main__":
    for name, v, d in run():
        print(f"{name},{v:.2f},{d}")
