"""Paper Table 2 / Figure 1: environment + training throughput.

Rows (this container is a single CPU core; ratios, not absolutes, are the
validation target — the paper reports 27x-2820x vs CPU gym envs on a GPU):

  random   — transition-function throughput: vmapped-jitted Chargax vs the
             pure-Python reference env taking random actions,
  ppo_1    — PPO wall-time per 100k env steps, 1 env,
  ppo_16   — PPO wall-time per 100k env steps, 16 vectorized envs (the
             paper's "typical training scenario"); the Python row drives the
             Python env with the same jitted PPO maths (rollout on host —
             the SB3+CUDA analogue).

Also records the ``repro.envs`` wrapper-stack overhead: the same random
rollout through ``VmapWrapper`` vs the raw hand-vmapped step.  The wrapper
is trace-time sugar, so the benchmark first PROVES the two paths compile to
byte-identical HLO (``wrapper_hlo_identical``) — any timing delta is then
measurement noise, bounded by :func:`estimate_overhead` (interleaved
(rounds, reps) grids, min over per-round median ratios; target: <= 2%).
Persisted to ``BENCH_speed.json`` as ``wrapper_overhead_frac`` (0 when the
HLO proof holds) plus ``wrapper_overhead_noise_residual_frac``.

And the fused-step row (ISSUE 10): the identical wrapped rollout with
``EnvConfig.fused_step`` routing the pole physics through
``kernels/chargax_step`` — persisted as ``fused_vs_staged_frac`` with the
resolved backend (``fused_impl``: pallas on TPU/GPU, ref on CPU).

And the real-data row: a ``REAL_PACK`` scenario (ingested ENTSO-E prices +
PVGIS solar) swapped into the same compiled rollout as the synthetic
baseline — guarded by the recompile sentinel (``repro.obs.compile_guard``),
timed interleaved.  Persisted as ``real_vs_synthetic_frac`` (table
provenance must be perf-neutral).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.python_ref_env import PythonChargax
from repro.core import ChargaxEnv, EnvConfig
from repro.envs import VmapWrapper
from repro.obs import cache_entries, compile_guard
from repro.rl import PPOConfig, make_train


def _make_random_rollout(env, venv, n_steps: int, n_envs: int, wrapped: bool):
    """Jitted random rollout: via ``VmapWrapper`` (protocol path) or the
    hand-vmapped ``env.step`` — identical computation, identical compiled
    program.  ``params`` is a call argument so swapping exogenous tables
    (synthetic vs real-data scenarios) reuses one compiled program."""

    @jax.jit
    def rollout(key, state, params):
        def body(carry, _):
            key, state = carry
            key, ka, ks = jax.random.split(key, 3)
            actions = jax.random.randint(
                ka, (n_envs, env.num_action_heads), 0, env.num_actions_per_head
            )
            if wrapped:
                _, state, r, d, _ = venv.step(ks, state, actions, params)
            else:
                keys = jax.random.split(ks, n_envs)
                _, state, r, d, _ = jax.vmap(env.step, in_axes=(0, 0, 0, None))(
                    keys, state, actions, params
                )
            return (key, state), r.sum()

        (_, state), rs = jax.lax.scan(body, (key, state), None, n_steps // n_envs)
        return state, rs.sum()

    return rollout


def bench_jax_random(
    n_steps: int = 100_000, n_envs: int = 1024, wrapped: bool = False,
    repeats: int = 1,
) -> float:
    """Seconds per n_steps env transitions, vmapped + jitted (best of N)."""
    env = ChargaxEnv(EnvConfig())
    params = env.default_params
    venv = VmapWrapper(env, n_envs)
    rollout = _make_random_rollout(env, venv, n_steps, n_envs, wrapped)
    key = jax.random.key(0)
    _, state = venv.reset(key, params)
    st, s = rollout(key, state, params)  # compile
    jax.block_until_ready(s)
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        _, s = rollout(key, state, params)
        jax.block_until_ready(s)
        best = min(best, time.perf_counter() - t0)
    return best


def estimate_overhead(raw_times, wrapped_times) -> float:
    """Noise-robust overhead estimator: min over rounds of per-round
    median ratios, minus one.

    ``raw_times`` / ``wrapped_times`` are (rounds, reps) grids of seconds
    collected *interleaved* (raw rep, wrapped rep, raw rep, ...), so load
    drift on a shared machine hits both columns of a round equally.  The
    per-round median discards rep-level spikes (GC, scheduler); the min
    over rounds then picks the quietest round — host noise can only
    INFLATE a ratio built from two equal programs, never deflate it, so
    the smallest observed round-ratio is the tightest upper bound on the
    true overhead.  A global min-over-all-reps would instead compare a
    lucky raw rep from one round with a lucky wrapped rep from another,
    which is exactly the cross-round drift the interleaving paid to
    cancel.
    """
    raw = np.asarray(raw_times, dtype=float)
    wrapped = np.asarray(wrapped_times, dtype=float)
    if raw.ndim == 1:  # single-rep rounds
        raw, wrapped = raw[:, None], wrapped[:, None]
    if raw.shape != wrapped.shape or raw.size == 0:
        raise ValueError(f"mismatched timing grids: {raw.shape} vs {wrapped.shape}")
    ratios = np.median(wrapped, axis=1) / np.median(raw, axis=1)
    return float(ratios.min() - 1.0)


def bench_wrapper_overhead(
    n_steps: int = 100_000, n_envs: int = 1024, rounds: int = 8, reps: int = 3,
) -> tuple[list[list[float]], list[list[float]], bool]:
    """(raw_times, wrapped_times, hlo_identical) for the same rollout.

    VmapWrapper is trace-time sugar, so raw and wrapped MUST lower to the
    same program — this benchmark asserts it by comparing the compiled HLO
    text of both paths byte-for-byte (``hlo_identical``).  With identity
    proven, the wrapper's true overhead is 0 by construction and any timing
    delta is host noise; the (rounds, reps) grids are collected interleaved
    raw/wrapped and fed to :func:`estimate_overhead` to bound that residual.
    """
    env = ChargaxEnv(EnvConfig())
    params = env.default_params
    venv = VmapWrapper(env, n_envs)
    raw = _make_random_rollout(env, venv, n_steps, n_envs, wrapped=False)
    wrapped = _make_random_rollout(env, venv, n_steps, n_envs, wrapped=True)

    key = jax.random.key(0)
    _, state = venv.reset(key, params)
    # the ground truth: both paths are ONE program (compare compiled HLO,
    # i.e. post-optimisation — stronger than comparing the stableHLO input)
    hlo = [
        fn.lower(key, state, params).compile().as_text() for fn in (raw, wrapped)
    ]
    hlo_identical = hlo[0] == hlo[1]
    for fn in (raw, wrapped):  # compile both before any timing
        st, s = fn(key, state, params)
        jax.block_until_ready(s)

    raw_times: list[list[float]] = []
    wrapped_times: list[list[float]] = []
    for _ in range(max(rounds, 1)):
        rrow: list[float] = []
        wrow: list[float] = []
        for _ in range(max(reps, 1)):
            for row, fn in ((rrow, raw), (wrow, wrapped)):  # interleaved
                t0 = time.perf_counter()
                _, s = fn(key, state, params)
                jax.block_until_ready(s)
                row.append(time.perf_counter() - t0)
        raw_times.append(rrow)
        wrapped_times.append(wrow)
    return raw_times, wrapped_times, hlo_identical


def bench_fused_vs_staged(
    n_steps: int = 100_000, n_envs: int = 1024, rounds: int = 3,
) -> tuple[float, float, str]:
    """(seconds staged, seconds fused, impl) for the same random rollout.

    The fused path is ``VmapWrapper(...).with_fused_step(True)`` — the exact
    hot-path routing ``rl_train --fused`` uses — against the staged default.
    The resolved backend (``pallas`` on TPU/GPU, ``ref`` on CPU, or whatever
    ``CHARGAX_FUSED_IMPL`` forces) is returned so the persisted row says
    what was actually measured.  Interleaved timing, min per path.
    """
    from repro.kernels.chargax_step.ops import resolve_impl

    env_s = ChargaxEnv(EnvConfig())
    venv_s = VmapWrapper(env_s, n_envs)
    venv_f = venv_s.with_fused_step(True)
    env_f = venv_f.unwrapped
    p_s = env_s.default_params
    p_f = env_f.default_params  # carries the hoisted pole pack
    staged = _make_random_rollout(env_s, venv_s, n_steps, n_envs, wrapped=True)
    fused = _make_random_rollout(env_f, venv_f, n_steps, n_envs, wrapped=True)

    key = jax.random.key(0)
    _, state = venv_s.reset(key, p_s)
    for fn, p in ((staged, p_s), (fused, p_f)):  # compile both first
        _, s = fn(key, state, p)
        jax.block_until_ready(s)

    best = {"staged": float("inf"), "fused": float("inf")}
    for _ in range(max(rounds, 1)):
        for label, fn, p in (("staged", staged, p_s), ("fused", fused, p_f)):
            t0 = time.perf_counter()
            _, s = fn(key, state, p)
            jax.block_until_ready(s)
            best[label] = min(best[label], time.perf_counter() - t0)
    return best["staged"], best["fused"], resolve_impl()


def bench_real_vs_synthetic(
    n_steps: int = 100_000, n_envs: int = 1024, rounds: int = 3,
) -> tuple[float, float]:
    """(seconds synthetic, seconds real-data) for the same jitted rollout.

    Proves table provenance is perf-neutral: a real-data scenario
    (``REAL_PACK``: ENTSO-E prices + PVGIS solar from vendored extracts)
    swaps into the *same compiled program* as the synthetic baseline —
    enforced by the recompile sentinel (``repro.obs.compile_guard``, which
    names the offending function + avals if the swap ever recompiles) —
    and steps at the same rate.  Interleaved timing, min per table, as in
    ``bench_wrapper_overhead``.
    """
    from repro import scenarios

    env = ChargaxEnv(EnvConfig())
    venv = VmapWrapper(env, n_envs)
    p_synth = scenarios.make("shopping_pv_tou").make_params(env)
    p_real = scenarios.make("real_nl_2024_office").make_params(env)
    rollout = _make_random_rollout(env, venv, n_steps, n_envs, wrapped=True)

    key = jax.random.key(0)
    _, state = venv.reset(key, p_synth)
    _, s = rollout(key, state, p_synth)  # warm-up: the one allowed compile
    jax.block_until_ready(s)
    with compile_guard("real-data params swap"):
        _, s = rollout(key, state, p_real)
        jax.block_until_ready(s)
    assert cache_entries(rollout) == 1

    best = {"synth": float("inf"), "real": float("inf")}
    for _ in range(max(rounds, 1)):
        for label, p in (("synth", p_synth), ("real", p_real)):
            t0 = time.perf_counter()
            _, s = rollout(key, state, p)
            jax.block_until_ready(s)
            best[label] = min(best[label], time.perf_counter() - t0)
    return best["synth"], best["real"]


def bench_python_random(n_steps: int = 20_000) -> float:
    """Seconds per n_steps transitions of the python reference env (1 env)."""
    env = PythonChargax()
    env.reset()
    t0 = time.perf_counter()
    done_ctr = 0
    for _ in range(n_steps):
        _, _, done, _ = env.step(env.sample_action())
        if done:
            env.reset()
            done_ctr += 1
    return time.perf_counter() - t0


def bench_jax_ppo(n_steps: int = 100_000, n_envs: int = 16) -> float:
    env = ChargaxEnv(EnvConfig())
    cfg = PPOConfig(
        total_timesteps=n_steps, num_envs=n_envs,
        rollout_steps=300 if n_envs > 1 else 512, hidden=(64, 64),
    )
    train = jax.jit(make_train(cfg, env))
    out = train(jax.random.key(0))  # includes compile; time a second run
    jax.block_until_ready(out["metrics"]["loss"])
    t0 = time.perf_counter()
    out = train(jax.random.key(1))
    jax.block_until_ready(out["metrics"]["loss"])
    return time.perf_counter() - t0


def bench_python_ppo(n_steps: int = 10_000, n_envs: int = 16) -> float:
    """Host-loop PPO: python envs, jitted policy/update (SB3+CUDA analogue)."""
    from repro.rl import networks
    from repro.optim import AdamWConfig, adamw_init, adamw_update, apply_updates

    jenv = ChargaxEnv(EnvConfig())
    envs = [PythonChargax(seed=i) for i in range(n_envs)]
    obs = np.stack([e.reset() for e in envs])
    n_heads, n_act = jenv.num_action_heads, jenv.num_actions_per_head
    params = networks.init_actor_critic(jax.random.key(0), jenv.obs_dim, n_heads, n_act, (64, 64))
    opt = adamw_init(params)
    rollout = 128

    @jax.jit
    def act(params, key, obs):
        out = networks.apply_actor_critic(params, obs, n_heads, n_act)
        a = networks.sample_action(key, out.logits)
        return a, networks.log_prob(out.logits, a), out.value

    @jax.jit
    def update(params, opt, obs_b, act_b, logp_b, adv_b, tgt_b):
        def loss_fn(p):
            out = networks.apply_actor_critic(p, obs_b, n_heads, n_act)
            lp = networks.log_prob(out.logits, act_b)
            ratio = jnp.exp(lp - logp_b)
            adv = (adv_b - adv_b.mean()) / (adv_b.std() + 1e-8)
            pg = -jnp.minimum(ratio * adv, jnp.clip(ratio, 0.8, 1.2) * adv).mean()
            v = 0.5 * jnp.square(out.value - tgt_b).mean()
            ent = networks.entropy(out.logits).mean()
            return pg + 0.25 * v - 0.01 * ent

        loss, grads = jax.value_and_grad(loss_fn)(params)
        upd, opt, _ = adamw_update(grads, opt, params, 2.5e-4, AdamWConfig(max_grad_norm=100.0))
        return apply_updates(params, upd), opt, loss

    key = jax.random.key(0)
    t0 = time.perf_counter()
    steps_done = 0
    while steps_done < n_steps:
        obs_buf, act_buf, logp_buf, rew_buf, val_buf = [], [], [], [], []
        for _ in range(rollout):
            key, k = jax.random.split(key)
            a, lp, v = act(params, k, jnp.asarray(obs))
            a_np = np.asarray(a)
            obs_buf.append(obs.copy())
            nobs = np.empty_like(obs)
            rews = np.empty(n_envs)
            for i, e in enumerate(envs):
                o, r, d, _ = e.step(a_np[i])
                if d:
                    o = e.reset()
                nobs[i], rews[i] = o, r
            act_buf.append(a_np)
            logp_buf.append(np.asarray(lp))
            val_buf.append(np.asarray(v))
            rew_buf.append(rews * 0.1)
            obs = nobs
            steps_done += n_envs
        # GAE on host
        vals = np.stack(val_buf + [val_buf[-1]])
        rews = np.stack(rew_buf)
        adv = np.zeros_like(rews)
        g = 0.0
        for t in reversed(range(rollout)):
            delta = rews[t] + 0.99 * vals[t + 1] - vals[t]
            g = delta + 0.99 * 0.95 * g
            adv[t] = g
        tgt = adv + vals[:-1]
        flat = lambda x: jnp.asarray(np.concatenate(x if isinstance(x, list) else list(x)))
        params, opt, _ = update(
            params, opt,
            jnp.asarray(np.concatenate(obs_buf)), jnp.asarray(np.concatenate(act_buf)),
            jnp.asarray(np.concatenate(logp_buf)), jnp.asarray(adv.reshape(-1)),
            jnp.asarray(tgt.reshape(-1)),
        )
    return time.perf_counter() - t0


LAST_SUMMARY: dict | None = None  # set by run(); persisted by benchmarks.run


def run(quick: bool = True) -> list[tuple[str, float, str]]:
    """Returns rows: (name, us_per_env_step, derived)."""
    global LAST_SUMMARY
    rows = []
    n_jax = 100_000
    n_py = 10_000 if quick else 50_000
    raw_ts, wrapped_ts, hlo_same = bench_wrapper_overhead(
        n_jax, rounds=4 if quick else 8, reps=2 if quick else 3
    )
    t_py = bench_python_random(n_py)
    t_jax = min(min(r) for r in raw_ts)
    t_wrapped = min(min(r) for r in wrapped_ts)
    us_jax = t_jax / n_jax * 1e6
    us_py = t_py / n_py * 1e6
    residual = estimate_overhead(raw_ts, wrapped_ts)
    # HLO identity is the proof of zero wrapper cost; the estimator bounds
    # the measurement noise that remains after that proof
    overhead = 0.0 if hlo_same else residual
    rows.append(("random_chargax_jax", us_jax, f"{n_jax/t_jax:,.0f} steps/s"))
    rows.append(
        (
            "random_chargax_wrapped",
            t_wrapped / n_jax * 1e6,
            f"{n_jax/t_wrapped:,.0f} steps/s VmapWrapper "
            f"overhead={overhead:+.2%} (target <=2%) "
            f"hlo_identical={hlo_same} noise_residual={residual:+.2%}",
        )
    )
    rows.append(("random_python_ref", us_py, f"{n_py/t_py:,.0f} steps/s"))
    rows.append(("random_speedup", us_py / us_jax, "x faster (paper: 27x-1144x)"))

    # fused step kernel (EnvConfig.fused_step) vs the staged lax pipeline on
    # the identical wrapped rollout — the rl_train --fused hot path
    t_staged, t_fused, fused_impl = bench_fused_vs_staged(n_jax, rounds=3)
    fused_frac = t_fused / t_staged - 1.0
    rows.append(
        (
            "random_chargax_fused",
            t_fused / n_jax * 1e6,
            f"{n_jax/t_fused:,.0f} steps/s fused-vs-staged "
            f"{fused_frac:+.2%} (impl={fused_impl})",
        )
    )

    # real-data scenarios (ENTSO-E + PVGIS tables) vs synthetic: same jit
    # entry, same speed — provenance of the exogenous tables is perf-neutral
    t_synth, t_real = bench_real_vs_synthetic(n_jax, rounds=3)
    real_frac = t_real / t_synth - 1.0
    rows.append(
        (
            "random_chargax_real_data",
            t_real / n_jax * 1e6,
            f"{n_jax/t_real:,.0f} steps/s real-vs-synthetic "
            f"{real_frac:+.2%} (one jit entry)",
        )
    )

    n_ppo = 50_000 if quick else 100_000
    t_ppo16 = bench_jax_ppo(n_ppo, 16)
    t_ppo1 = bench_jax_ppo(25_000 if quick else 100_000, 1)
    rows.append(("ppo16_chargax_jax", t_ppo16 / n_ppo * 1e6, f"{n_ppo/t_ppo16:,.0f} steps/s"))
    rows.append(("ppo1_chargax_jax", t_ppo1 / (25_000 if quick else 100_000) * 1e6, ""))

    n_pyppo = 5_000 if quick else 20_000
    t_pyppo = bench_python_ppo(n_pyppo, 16)
    rows.append(("ppo16_python_ref", t_pyppo / n_pyppo * 1e6, f"{n_pyppo/t_pyppo:,.0f} steps/s"))
    rows.append(
        ("ppo16_speedup", (t_pyppo / n_pyppo) / (t_ppo16 / n_ppo), "x faster (paper: 134x-2820x)")
    )
    LAST_SUMMARY = {
        "num_envs": 16,
        "steps_per_sec": round(n_ppo / t_ppo16, 1),
        "random_env_steps_per_sec": round(n_jax / t_jax, 1),
        "wrapped_env_steps_per_sec": round(n_jax / t_wrapped, 1),
        "wrapper_overhead_frac": round(overhead, 4),
        "wrapper_overhead_noise_residual_frac": round(residual, 4),
        "wrapper_hlo_identical": hlo_same,
        "fused_env_steps_per_sec": round(n_jax / t_fused, 1),
        "fused_vs_staged_frac": round(fused_frac, 4),
        "fused_impl": fused_impl,
        "real_data_env_steps_per_sec": round(n_jax / t_real, 1),
        "real_vs_synthetic_frac": round(real_frac, 4),
        "python_ref_steps_per_sec": round(n_py / t_py, 1),
    }
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")
