"""Grid benchmark: feeder-envelope coupling cost + grid_aware vs max-charge.

Three claims, persisted to ``BENCH_grid.json`` by ``benchmarks.run``:

  1. **Throughput**: the allocate stage (table lookup + proportional
     curtailment) is essentially free — steps/sec for the jitted vmapped env
     on a grid-capped scenario vs the flat baseline scenario.
  2. **Coupled fleet**: the shared-feeder FleetEnv step (vmapped request ->
     fleet curtailment -> vmapped deliver) also holds its throughput.
  3. **Violation/profit**: on ``grid_tight_transformer``, the ``grid_aware``
     curtailment baseline holds ``grid/violation == 0`` while the paper's
     always-max baseline overshoots every busy step (and pays the penalty in
     reward).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import scenarios
from repro.core import ChargaxEnv, EnvConfig, FleetEnv
from repro.envs import VmapWrapper
from repro.rl.baselines import grid_aware_policy, max_charge_policy

LAST_SUMMARY: dict = {}

TIGHT_SCENARIO = "grid_tight_transformer"


def _env_steps_per_sec(scenario: str, num_envs: int, steps: int) -> float:
    env = ChargaxEnv(EnvConfig())
    params = scenarios.make(scenario).make_params(env)
    venv = VmapWrapper(env, num_envs)

    @jax.jit
    def rollout(key):
        obs, state = venv.reset(key, params)

        def body(carry, _):
            state, key = carry
            key, k_act, k_step = jax.random.split(key, 3)
            ts = venv.step(k_step, state, venv.sample_action(k_act), params)
            return (ts.state, key), ts.reward

        (state, _), rewards = jax.lax.scan(body, (state, key), None, steps)
        return rewards.sum()

    rollout(jax.random.key(0)).block_until_ready()  # compile
    t0 = time.perf_counter()
    rollout(jax.random.key(1)).block_until_ready()
    return num_envs * steps / (time.perf_counter() - t0)


def _fleet_steps_per_sec(couple_grid: bool, steps: int) -> float:
    sc = scenarios.make(TIGHT_SCENARIO)
    fleet = FleetEnv(
        ["paper_16", "deep_4x4", "paper_16", "mixed_8_8"],
        scenarios=[sc] * 4,
        couple_grid=couple_grid,
    )
    params = fleet.default_params

    @jax.jit
    def rollout(key):
        obs, state = fleet.reset(key, params)

        def body(carry, k):
            state = carry
            action = fleet.sample_action(jax.random.fold_in(k, 1))
            obs, state, reward, done, info = fleet.step(k, state, action, params)
            return state, reward

        keys = jax.random.split(key, steps)
        state, rewards = jax.lax.scan(body, state, keys)
        return rewards.sum()

    rollout(jax.random.key(0)).block_until_ready()
    t0 = time.perf_counter()
    rollout(jax.random.key(1)).block_until_ready()
    return fleet.n_stations * steps / (time.perf_counter() - t0)


def _episode_kpis(env, params, action) -> dict:
    """One constant-action episode; sum grid violations, mean profit/reward."""

    @jax.jit
    def run(key):
        obs, state = env.reset(key, params)

        def body(carry, k):
            obs, state = carry
            ts = env.step(k, state, action, params)
            return (ts.obs, ts.state), (
                ts.info["grid/violation"],
                ts.info["profit"],
                ts.reward,
            )

        keys = jax.random.split(jax.random.key(1), env.config.episode_steps)
        (_, state), (viol, profit, reward) = jax.lax.scan(body, (obs, state), keys)
        return viol, profit, reward

    viol, profit, reward = run(jax.random.key(0))
    return {
        "violation_kw_max": float(jnp.max(viol)),
        "violation_kw_sum": float(jnp.sum(viol)),
        "profit": float(jnp.sum(profit)),
        "reward": float(jnp.sum(reward)),
    }


def run(quick: bool = True) -> list[tuple[str, float, str]]:
    global LAST_SUMMARY
    rows = []

    # --- 1. allocate-stage throughput cost --------------------------------
    num_envs, steps = (64, 288) if quick else (512, 1024)
    sps_flat = _env_steps_per_sec("shopping_flat", num_envs, steps)
    sps_grid = _env_steps_per_sec(TIGHT_SCENARIO, num_envs, steps)
    rows.append(("grid_steps_flat", 1e6 / sps_flat, f"steps_per_sec={sps_flat:,.0f}"))
    rows.append(
        (
            "grid_steps_capped",
            1e6 / sps_grid,
            f"steps_per_sec={sps_grid:,.0f} ratio_vs_flat={sps_grid/sps_flat:.2f}",
        )
    )

    # --- 2. coupled-fleet step cost ---------------------------------------
    fsteps = 288 if quick else 1024
    sps_un = _fleet_steps_per_sec(False, fsteps)
    sps_cp = _fleet_steps_per_sec(True, fsteps)
    rows.append(
        (
            "grid_fleet_coupled",
            1e6 / sps_cp,
            f"steps_per_sec={sps_cp:,.0f} ratio_vs_uncoupled={sps_cp/sps_un:.2f}",
        )
    )

    # --- 3. grid_aware baseline vs always-max on the tight transformer ----
    env = ChargaxEnv(EnvConfig())
    params = scenarios.make(TIGHT_SCENARIO).make_params(env)
    obs0, _ = env.reset(jax.random.key(0), params)
    kpis = {}
    for name, make in {
        "grid_aware": lambda: grid_aware_policy(env, params),
        "max_charge": lambda: max_charge_policy(env),
    }.items():
        action = make()(None, jax.random.key(2), obs0)
        kpis[name] = _episode_kpis(env, params, action)
    ga, mx = kpis["grid_aware"], kpis["max_charge"]
    for name, k in kpis.items():
        rows.append(
            (
                f"grid_violation_{name}",
                k["violation_kw_max"],
                f"viol_sum_kw={k['violation_kw_sum']:.0f} "
                f"profit={k['profit']:.0f} reward={k['reward']:.0f}",
            )
        )

    LAST_SUMMARY = {
        "steps_per_sec_flat": round(sps_flat),
        "steps_per_sec_grid_capped": round(sps_grid),
        "fleet_steps_per_sec_uncoupled": round(sps_un),
        "fleet_steps_per_sec_coupled": round(sps_cp),
        "tight_scenario": TIGHT_SCENARIO,
        "violation_kw_max_grid_aware": ga["violation_kw_max"],
        "violation_kw_max_max_charge": mx["violation_kw_max"],
        "violation_zero_grid_aware": bool(ga["violation_kw_max"] == 0.0),
        "reward_grid_aware": round(ga["reward"], 2),
        "reward_max_charge": round(mx["reward"], 2),
        "grid_aware_beats_max_on_reward": bool(ga["reward"] > mx["reward"]),
    }
    return rows


if __name__ == "__main__":
    for name, v, d in run():
        print(f"{name},{v:.3f},{d}")
