"""§Perf hillclimb driver: compile a cell variant and report roofline terms.

    PYTHONPATH=src python -m benchmarks.perf_iter --arch tinyllama-1.1b \
        --shape train_4k --microbatches 2 [--no-remat] [--tag hypothesis-3]

Appends ``kind="perf_iter"`` records to results/perf_iters.jsonl through the
shared observability sink (``repro.obs.MetricsWriter``) — same
manifest-then-records schema as ``rl_train --metrics-out`` and
``benchmarks.run --metrics-out``, so one reader serves every artifact.
(Must run in a fresh process: the 512-device forcing happens at import.)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--strategy", default="2d", choices=["2d", "fsdp", "dp"])
    ap.add_argument("--router-group", type=int, default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/perf_iters.jsonl")
    args = ap.parse_args()

    from repro.analysis.roofline import analyze_cell

    overrides = {}
    if args.router_group is not None:
        overrides["router_group"] = args.router_group
    if args.capacity_factor is not None:
        overrides["capacity_factor"] = args.capacity_factor

    t0 = time.perf_counter()
    rec = analyze_cell(
        args.arch,
        args.shape,
        microbatches=args.microbatches,
        remat=not args.no_remat,
        cfg_overrides=overrides or None,
        strategy=args.strategy,
    )
    rec["tag"] = args.tag
    rec["remat"] = not args.no_remat
    rec["strategy"] = args.strategy
    rec["overrides"] = overrides
    rec["wall_s"] = round(time.perf_counter() - t0, 1)

    print(json.dumps({k: rec[k] for k in (
        "arch", "shape", "tag", "num_microbatches", "remat", "strategy",
        "t_compute_s", "t_memory_s", "t_collective_s", "bottleneck",
        "useful_compute_ratio", "roofline_fraction_compute", "useful_fraction",
    )}, indent=1))

    from repro.obs import MetricsWriter

    with MetricsWriter(args.out, run="perf_iter") as w:
        w.write(rec, kind="perf_iter")


if __name__ == "__main__":
    main()
