"""Append the generated result sections to EXPERIMENTS.md from results/*.json.

    PYTHONPATH=src python -m benchmarks.gen_experiments
"""
from __future__ import annotations

import json
import os

MARK = "<!-- GENERATED RESULTS BELOW — regenerate with benchmarks.gen_experiments -->"


def dryrun_summary() -> str:
    rows = json.load(open("results/dryrun.json"))
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = [
        "### §Dry-run-results\n",
        "| arch | shape | mesh | compile s | GiB/dev | fits 16G |",
        "|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('compile_s','-')} "
            f"| {r.get('bytes_per_device',0)/2**30:.2f} | {'Y' if r.get('fits_16g_hbm') else 'tight'} |"
        )
    n_ok = sum(1 for r in rows if r.get("ok"))
    out.append(f"\n**{n_ok}/{len(rows)} cells compile** (both meshes, every applicable shape).")
    before = "results/dryrun_before_perf.json"
    if os.path.exists(before):
        b = {(r["arch"], r["shape"], r["mesh"]): r for r in json.load(open(before))}
        worst = []
        for r in rows:
            key = (r["arch"], r["shape"], r["mesh"])
            if key in b and b[key].get("bytes_per_device"):
                worst.append(
                    (b[key]["bytes_per_device"] / max(r.get("bytes_per_device", 1), 1), key,
                     b[key]["bytes_per_device"], r.get("bytes_per_device", 0))
                )
        worst.sort(reverse=True)
        out.append("\nLargest §Perf memory wins (paper-faithful baseline -> optimized):\n")
        out.append("| cell | before GiB/dev | after GiB/dev | x |")
        out.append("|---|---|---|---|")
        for ratio, key, bb, aa in worst[:10]:
            out.append(
                f"| {key[0]} {key[1]} {key[2]} | {bb/2**30:.1f} | {aa/2**30:.2f} | {ratio:,.0f}x |"
            )
    return "\n".join(out)


def roofline_summary() -> str:
    if not os.path.exists("results/roofline.json"):
        return "### §Roofline-results\n\n(pending)"
    rows = [r for r in json.load(open("results/roofline.json")) if "bottleneck" in r]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "### §Roofline-results\n",
        "Single-pod 16x16 (256 chips); terms in seconds per step.  memory =",
        "TPU-fusion materialisation model (raw XLA:CPU bytes-accessed term in",
        "parentheses as the hard upper bound); useful-frac = (6·N_active·D /",
        "chips / peak) / bound — the honest roofline fraction.\n",
        "| arch | shape | compute | memory (raw) | collective | bound | useful ratio | useful frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        raw = r.get("t_memory_raw_s", float("nan"))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} "
            f"| {r['t_memory_s']:.3f} ({raw:.1f}) "
            f"| {r['t_collective_s']:.3f} | {r['bottleneck']} "
            f"| {r['useful_compute_ratio']:.2f} | {r.get('useful_fraction', 0):.3f} |"
        )
    bounds = {}
    for r in rows:
        bounds[r["bottleneck"]] = bounds.get(r["bottleneck"], 0) + 1
    out.append(f"\nBottleneck census: {bounds}.")
    fails = [r for r in json.load(open("results/roofline.json")) if "error" in r]
    if fails:
        out.append(f"Failed probes: {[(r['arch'], r['shape']) for r in fails]}")
    return "\n".join(out)


def perf_iters_summary() -> str:
    if os.path.exists("results/perf_iters.jsonl"):
        from repro.obs import read_jsonl

        rows = [r for r in read_jsonl("results/perf_iters.jsonl") if r.get("kind") == "perf_iter"]
    elif os.path.exists("results/perf_iters.json"):  # legacy pre-sink format
        rows = json.load(open("results/perf_iters.json"))
    else:
        return "### §Perf-hillclimb\n\n(pending)"
    out = [
        "### §Perf-hillclimb\n",
        "| cell | tag | mb | remat | compute | memory | collective | bound | frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} {r['shape']} | {r.get('tag','')} | {r.get('num_microbatches','-')} "
            f"| {'Y' if r.get('remat', True) else 'N'} "
            f"| {r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} "
            f"| {r['bottleneck']} | {r.get('useful_fraction', r['roofline_fraction_compute']):.3f} |"
        )
    return "\n".join(out)


def ppo_dryrun_summary() -> str:
    if not os.path.exists("results/ppo_dryrun.json"):
        return ""
    rows = json.load(open("results/ppo_dryrun.json"))
    out = ["### §Dry-run: chargax-ppo-update (paper-representative cell)\n",
           "| mesh | envs | compile s | GiB/dev | collective GiB |", "|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['mesh']} | {r['num_envs']:,} | {r['compile_s']} "
            f"| {r['bytes_per_device']/2**30:.2f} "
            f"| {r['collectives']['total_bytes']/2**30:.3f} |"
        )
    return "\n".join(out)


def main():
    doc = open("EXPERIMENTS.md").read()
    if MARK in doc:
        doc = doc.split(MARK)[0]
    parts = [
        doc.rstrip(),
        "\n\n" + MARK + "\n",
        dryrun_summary(),
        "",
        ppo_dryrun_summary(),
        "",
        roofline_summary(),
        "",
        perf_iters_summary(),
        "",
    ]
    open("EXPERIMENTS.md", "w").write("\n".join(parts))
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
