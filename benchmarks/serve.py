"""Serving-shaped inference throughput: O(10^5) station observations per step.

A production control plane for a national charging network doesn't run
episodes — it runs *request batches*: every station ships its current
observation, one device step maps the whole batch to actions.  This
benchmark times exactly that path (:func:`repro.rl.eval.make_serve`: a
jitted, donated-buffer batched-policy step) at increasing batch sizes and
reports obs/sec plus p50/p99 per-batch latency.

Persisted as ``BENCH_serve.json`` through the shared observability sink
(schema_version, git sha, backend, device count) by ``benchmarks.run``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import ChargaxEnv, EnvConfig
from repro.obs import emit_json_line
from repro.rl import make_ppo_policy, networks
from repro.rl.eval import make_serve

# quick mode still proves the acceptance bar: >= 1e5 concurrent station
# observations in one serve step (131072 = 2^17)
BATCHES_QUICK = (32_768, 131_072)
BATCHES_FULL = (32_768, 131_072, 524_288)

LAST_SUMMARY: dict | None = None  # set by run(); persisted by benchmarks.run


def _percentile(sorted_vals: list[float], q: float) -> float:
    i = min(int(round(q * (len(sorted_vals) - 1))), len(sorted_vals) - 1)
    return sorted_vals[i]


def bench_serve(policy, params, batch: int, obs_dim: int, iters: int) -> dict:
    """Latency stats for ``iters`` serve steps over a ``(batch, obs_dim)`` load."""
    serve_step = make_serve(policy)
    key = jax.random.key(0)
    obs = jax.random.normal(jax.random.key(1), (batch, obs_dim), jnp.float32)
    jax.block_until_ready(serve_step(params, key, obs))  # compile
    lat = []
    for i in range(iters):
        # fresh buffer each step (the serving access pattern donation assumes)
        o = obs + jnp.float32(i)
        jax.block_until_ready(o)
        t0 = time.perf_counter()
        jax.block_until_ready(serve_step(params, key, o))
        lat.append(time.perf_counter() - t0)
    lat.sort()
    p50 = _percentile(lat, 0.50)
    return {
        "batch_size": batch,
        "obs_per_sec": round(batch / p50, 1),
        "latency_p50_ms": round(p50 * 1e3, 3),
        "latency_p99_ms": round(_percentile(lat, 0.99) * 1e3, 3),
        "iters": iters,
    }


def run(quick: bool = True):
    """Benchmark-harness entry point: list of (name, us_per_call, derived)."""
    global LAST_SUMMARY
    env = ChargaxEnv(EnvConfig())
    n_heads = env.action_space.shape[-1]
    n_actions = env.action_space.num_categories
    params = networks.init_actor_critic(
        jax.random.key(7), env.obs_dim, n_heads, n_actions
    )
    policy = make_ppo_policy(env, greedy=True)

    batches = BATCHES_QUICK if quick else BATCHES_FULL
    iters = 6 if quick else 20
    rows, per_batch = [], []
    for batch in batches:
        stats = bench_serve(policy, params, batch, env.obs_dim, iters)
        per_batch.append(stats)
        rows.append(
            (
                f"serve_{batch}",
                stats["latency_p50_ms"] * 1e3,  # us per serve step
                f"{stats['obs_per_sec']:.0f} obs/s "
                f"p99={stats['latency_p99_ms']:.1f}ms",
            )
        )
    top = per_batch[-1]
    LAST_SUMMARY = {
        "obs_dim": env.obs_dim,
        "policy": "ppo_mlp_greedy",
        "donated": jax.default_backend() != "cpu",
        "batch_size": top["batch_size"],
        "obs_per_sec": top["obs_per_sec"],
        "latency_p50_ms": top["latency_p50_ms"],
        "latency_p99_ms": top["latency_p99_ms"],
        "serve": per_batch,
    }
    emit_json_line("SERVE_JSON", {"serve": per_batch})
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(",".join(str(x) for x in row))
