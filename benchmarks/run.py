"""Benchmark harness — one module per paper table/figure (deliverable (d)).

  python -m benchmarks.run [--full] [--only speed,ppo,satisfaction,shift,roofline]

Prints ``name,us_per_call,derived`` CSV rows (assignment format).  --full uses
paper-scale training budgets; the default quick mode validates the same
claims with reduced budgets suited to this single-CPU container.

Every benchmark's results are also PERSISTED through the shared
observability sink (``repro.obs.write_benchmark_json``): ``BENCH_<name>.json``
is written to the repo root (schema_version, git sha, backend/device
provenance, CSV rows, plus whatever summary dict the module left in its
``LAST_SUMMARY`` global) so the perf trajectory survives the run — CI
uploads them as artifacts.  ``--metrics-out PATH`` additionally appends one
JSONL record per benchmark (same schema as ``rl_train --metrics-out``).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MODULES = {
    "speed": ("benchmarks.speed_table", "Table 2 / Fig 1: env + PPO throughput"),
    "ppo": ("benchmarks.ppo_shopping", "Fig 4a: PPO vs max-charge baseline"),
    "satisfaction": ("benchmarks.satisfaction_sweep", "Fig 4b/c: alpha sweep"),
    "shift": ("benchmarks.price_shift", "Fig 5: price-year distribution shift"),
    "fleet": ("benchmarks.fleet_throughput", "Fleet: heterogeneous stations, one vmap"),
    "fleet_sharded": (
        "benchmarks.fleet_sharded",
        "Fleet: station axis sharded over the device mesh",
    ),
    "v2g": (
        "benchmarks.v2g",
        "V2G: allow_v2g throughput + mixed-scenario PPO profit vs baselines",
    ),
    "grid": (
        "benchmarks.grid",
        "Grid: feeder-envelope allocate cost + grid_aware vs max-charge violations",
    ),
    "serve": (
        "benchmarks.serve",
        "Serve: batched-policy inference step, obs/sec + p50/p99 latency",
    ),
    "roofline": ("benchmarks.roofline_report", "dry-run + roofline tables"),
}


def persist(name: str, rows, summary: dict | None, quick: bool) -> str:
    """Write ``BENCH_<name>.json`` via the shared obs sink; return its path."""
    from repro.obs import write_benchmark_json

    return write_benchmark_json(name, rows, summary=summary, quick=quick)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--no-persist", action="store_true", help="skip writing BENCH_<name>.json"
    )
    ap.add_argument(
        "--metrics-out",
        default=None,
        metavar="PATH",
        help="append one JSONL record per benchmark (manifest + summary + "
        "rows) — the CI artifact sink",
    )
    ap.add_argument(
        "--list",
        action="store_true",
        help="print every registered benchmark (name: description) and exit",
    )
    args = ap.parse_args()

    if args.list:
        for name, (_, desc) in MODULES.items():
            print(f"{name}: {desc}")
        return

    names = list(MODULES) if args.only is None else args.only.split(",")
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; choose from {list(MODULES)}")
    writer = None
    if args.metrics_out:
        from repro.obs import MetricsWriter

        writer = MetricsWriter(args.metrics_out, run="benchmarks", quick=not args.full)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod_name, desc = MODULES[name]
        print(f"# --- {name}: {desc}", flush=True)
        t0 = time.perf_counter()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            rows = mod.run(quick=not args.full)
            for rname, val, derived in rows:
                print(f"{rname},{val:.3f},{derived}", flush=True)
            summary = getattr(mod, "LAST_SUMMARY", None)
            if not args.no_persist:
                path = persist(name, rows, summary, not args.full)
                print(f"# wrote {os.path.relpath(path, REPO_ROOT)}", flush=True)
            if writer is not None:
                writer.write(
                    {
                        "benchmark": name,
                        "wall_s": round(time.perf_counter() - t0, 1),
                        **(summary or {}),
                        "rows": [
                            {"name": r, "us_per_call": round(float(v), 3), "derived": d}
                            for r, v, d in rows
                        ],
                    },
                    kind="benchmark",
                )
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,FAILED: {type(e).__name__}: {e}", flush=True)
            if writer is not None:
                writer.write(
                    {"benchmark": name, "error": f"{type(e).__name__}: {e}"},
                    kind="benchmark_failure",
                )
        print(f"# {name} took {time.perf_counter()-t0:.0f}s", flush=True)
    if writer is not None:
        writer.close()
        print(f"# metrics JSONL: {writer.path}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
