"""Benchmark harness — one module per paper table/figure (deliverable (d)).

  python -m benchmarks.run [--full] [--only speed,ppo,satisfaction,shift,roofline]

Prints ``name,us_per_call,derived`` CSV rows (assignment format).  --full uses
paper-scale training budgets; the default quick mode validates the same
claims with reduced budgets suited to this single-CPU container.

Every benchmark's results are also PERSISTED: ``BENCH_<name>.json`` is
written to the repo root (git sha, device count, CSV rows, plus whatever
summary dict the module left in its ``LAST_SUMMARY`` global) so the perf
trajectory survives the run — CI uploads them as artifacts.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MODULES = {
    "speed": ("benchmarks.speed_table", "Table 2 / Fig 1: env + PPO throughput"),
    "ppo": ("benchmarks.ppo_shopping", "Fig 4a: PPO vs max-charge baseline"),
    "satisfaction": ("benchmarks.satisfaction_sweep", "Fig 4b/c: alpha sweep"),
    "shift": ("benchmarks.price_shift", "Fig 5: price-year distribution shift"),
    "fleet": ("benchmarks.fleet_throughput", "Fleet: heterogeneous stations, one vmap"),
    "fleet_sharded": (
        "benchmarks.fleet_sharded",
        "Fleet: station axis sharded over the device mesh",
    ),
    "v2g": (
        "benchmarks.v2g",
        "V2G: allow_v2g throughput + mixed-scenario PPO profit vs baselines",
    ),
    "roofline": ("benchmarks.roofline_report", "dry-run + roofline tables"),
}


def _git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT, text=True
        ).strip()
    except Exception:  # noqa: BLE001
        return "unknown"


def persist(name: str, rows, summary: dict | None, quick: bool) -> str:
    """Write ``BENCH_<name>.json`` to the repo root; return its path."""
    import jax

    # summary first so modules can surface headline fields (steps_per_sec,
    # num_envs) at the top level, but provenance keys always win
    rec = dict(summary or {})
    rec.update(
        benchmark=name,
        git_sha=_git_sha(),
        device_count=jax.device_count(),
        backend=jax.default_backend(),
        quick=quick,
        unix_time=int(time.time()),
        rows=[
            {"name": r, "us_per_call": round(float(v), 3), "derived": d}
            for r, v, d in rows
        ],
    )
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--no-persist", action="store_true", help="skip writing BENCH_<name>.json"
    )
    args = ap.parse_args()

    names = list(MODULES) if args.only is None else args.only.split(",")
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; choose from {list(MODULES)}")
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod_name, desc = MODULES[name]
        print(f"# --- {name}: {desc}", flush=True)
        t0 = time.perf_counter()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            rows = mod.run(quick=not args.full)
            for rname, val, derived in rows:
                print(f"{rname},{val:.3f},{derived}", flush=True)
            if not args.no_persist:
                path = persist(
                    name, rows, getattr(mod, "LAST_SUMMARY", None), not args.full
                )
                print(f"# wrote {os.path.relpath(path, REPO_ROOT)}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,FAILED: {type(e).__name__}: {e}", flush=True)
        print(f"# {name} took {time.perf_counter()-t0:.0f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
