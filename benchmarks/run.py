"""Benchmark harness — one module per paper table/figure (deliverable (d)).

  python -m benchmarks.run [--full] [--only speed,ppo,satisfaction,shift,roofline]

Prints ``name,us_per_call,derived`` CSV rows (assignment format).  --full uses
paper-scale training budgets; the default quick mode validates the same
claims with reduced budgets suited to this single-CPU container.
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = {
    "speed": ("benchmarks.speed_table", "Table 2 / Fig 1: env + PPO throughput"),
    "ppo": ("benchmarks.ppo_shopping", "Fig 4a: PPO vs max-charge baseline"),
    "satisfaction": ("benchmarks.satisfaction_sweep", "Fig 4b/c: alpha sweep"),
    "shift": ("benchmarks.price_shift", "Fig 5: price-year distribution shift"),
    "fleet": ("benchmarks.fleet_throughput", "Fleet: heterogeneous stations, one vmap"),
    "roofline": ("benchmarks.roofline_report", "dry-run + roofline tables"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    names = list(MODULES) if args.only is None else args.only.split(",")
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; choose from {list(MODULES)}")
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod_name, desc = MODULES[name]
        print(f"# --- {name}: {desc}", flush=True)
        t0 = time.perf_counter()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            rows = mod.run(quick=not args.full)
            for rname, val, derived in rows:
                print(f"{rname},{val:.3f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,FAILED: {type(e).__name__}: {e}", flush=True)
        print(f"# {name} took {time.perf_counter()-t0:.0f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
