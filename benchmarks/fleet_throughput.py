"""Fleet throughput: heterogeneous multi-station rollouts under one vmap.

Measures env-steps/sec of a ``FleetEnv`` mixing three heterogeneous bundled
architectures (``paper_16``, ``deep_4x4``, ``single_dc_8``), each paired
with a different catalog scenario, replicated to fleets of increasing size —
the "millions of users" scaling axis of the ROADMAP.  A jitted 24h
``lax.scan`` rollout is timed per fleet size and a machine-readable JSON
summary line (``FLEET_JSON {...}``) is emitted for dashboards/CI.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import EnvConfig, FleetEnv
from repro.envs import FleetAdapter
from repro.obs import emit_json_line

ARCHS = ("paper_16", "deep_4x4", "single_dc_8")
SCENARIOS = ("shopping_pv_tou", "work_solar_summer", "highway_demand_charge")

LAST_SUMMARY: dict | None = None  # set by run(); persisted by benchmarks.run


def bench_fleet(n_replicas: int, n_days: int = 1, mesh=None) -> tuple[float, FleetEnv]:
    """Seconds for a jitted ``n_days``-day rollout of the replicated fleet.

    With ``mesh``, the stacked params/state are placed over its data axes and
    the rollout runs under the ambient mesh (``benchmarks.fleet_sharded``);
    without, this is the plain single-device harness.
    """
    import contextlib

    from repro.distributed import env_sharding, sharding

    fleet = FleetEnv(
        ARCHS * n_replicas,
        EnvConfig(),
        scenarios=SCENARIOS * n_replicas,
    )
    # the rollout drives the fleet through the Environment protocol: typed
    # action space, TimeStep returns
    env = FleetAdapter(fleet)
    steps = fleet.config.episode_steps * n_days

    with sharding.set_mesh(mesh) if mesh is not None else contextlib.nullcontext():
        params = env.default_params
        if mesh is not None:
            params = env_sharding.place_env_batch(params, mesh)

        @jax.jit
        def rollout(key, state):
            def body(carry, _):
                key, state = carry
                key, ka, ks = jax.random.split(key, 3)
                ts = env.step(ks, state, env.sample_action(ka), params)
                return (key, ts.state), jnp.sum(ts.reward)

            (_, state), rs = jax.lax.scan(body, (key, state), None, steps)
            return state, rs.sum()

        key = jax.random.key(0)
        _, state = env.reset(key, params)
        if mesh is not None:
            state = env_sharding.place_env_batch(state, mesh)
        state2, _ = rollout(key, state)  # compile
        jax.block_until_ready(state2.t)
        t0 = time.perf_counter()
        _, total = rollout(key, state)
        jax.block_until_ready(total)
    return time.perf_counter() - t0, fleet


def run(quick: bool = True):
    """Benchmark-harness entry point: list of (name, us_per_call, derived)."""
    global LAST_SUMMARY
    sizes = (1, 4) if quick else (1, 4, 16, 64)
    rows = []
    summary = []
    base_per_station = None  # smallest fleet's per-station throughput
    for n in sizes:
        secs, fleet = bench_fleet(n)
        steps = fleet.config.episode_steps * fleet.n_stations
        sps = steps / secs
        per_station = sps / fleet.n_stations
        if base_per_station is None:
            base_per_station = per_station
        # per-station throughput relative to the smallest fleet: 1.0 is
        # perfect linear scaling, < 1.0 makes the sub-linear falloff of
        # bigger vmapped fleets visible at a glance in BENCH_fleet.json
        eff = per_station / base_per_station
        rows.append(
            (
                f"fleet_{fleet.n_stations}_stations",
                secs * 1e6 / fleet.config.episode_steps,
                f"{sps:.0f} station-steps/s ({fleet.max_evse}-lane padded, "
                f"eff={eff:.2f})",
            )
        )
        summary.append(
            {
                "n_stations": fleet.n_stations,
                "architectures": list(fleet.architectures),
                "padded_evse": fleet.max_evse,
                "steps_per_sec": round(sps, 1),
                "seconds_per_24h_rollout": round(secs, 4),
                "scaling_efficiency": round(eff, 3),
            }
        )
    LAST_SUMMARY = {
        "num_envs": summary[-1]["n_stations"],
        "steps_per_sec": summary[-1]["steps_per_sec"],
        "scaling_efficiency": summary[-1]["scaling_efficiency"],
        "fleet_throughput": summary,
    }
    emit_json_line("FLEET_JSON", {"fleet_throughput": summary})
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(",".join(str(x) for x in row))
