"""V2G benchmark: throughput with `allow_v2g` on/off + profit vs baselines.

Three claims, persisted to ``BENCH_v2g.json`` by ``benchmarks.run``:

  1. **Throughput**: enabling V2G (per-port bidirectional masks, the split
     p_sell/p_v2g_comp revenue) costs ~nothing — steps/sec for the jitted
     vmapped env is reported for both settings.
  2. **Training**: PPO with ``allow_v2g=True`` trains across a *mixed*
     v2g/non-v2g scenario distribution under the nested vmap — a single
     compiled training graph serves the whole mix (the catalog-wide
     no-recompile guarantee is asserted in
     ``tests/scenarios/test_scenarios.py``).
  3. **Profit**: on a ToU V2G scenario, a V2G-aware agent (PPO and the
     rule-based price-arbitrage baseline) beats the paper's always-max
     baseline on daily profit.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import scenarios
from repro.core import ChargaxEnv, EnvConfig
from repro.envs import VmapWrapper
from repro.rl import PPOConfig, evaluate, make_ppo_policy, make_train
from repro.rl.baselines import max_charge_policy, v2g_arbitrage_policy

LAST_SUMMARY: dict = {}

TOU_SCENARIO = "v2g_shopping_tou"


def _env_steps_per_sec(allow_v2g: bool, num_envs: int, steps: int) -> float:
    env = ChargaxEnv(EnvConfig(allow_v2g=allow_v2g))
    params = scenarios.make(TOU_SCENARIO).make_params(env)
    venv = VmapWrapper(env, num_envs)  # protocol-path batching

    @jax.jit
    def rollout(key):
        obs, state = venv.reset(key, params)

        def body(carry, _):
            state, key = carry
            key, k_act, k_step = jax.random.split(key, 3)
            ts = venv.step(k_step, state, venv.sample_action(k_act), params)
            state, reward = ts.state, ts.reward
            return (state, key), reward

        (state, _), rewards = jax.lax.scan(body, (state, key), None, steps)
        return rewards.sum()

    rollout(jax.random.key(0)).block_until_ready()  # compile
    t0 = time.perf_counter()
    rollout(jax.random.key(1)).block_until_ready()
    wall = time.perf_counter() - t0
    return num_envs * steps / wall


def run(quick: bool = True) -> list[tuple[str, float, str]]:
    global LAST_SUMMARY
    rows = []

    # --- 1. throughput: v2g on vs off ------------------------------------
    num_envs, steps = (64, 288) if quick else (512, 1024)
    sps_off = _env_steps_per_sec(False, num_envs, steps)
    sps_on = _env_steps_per_sec(True, num_envs, steps)
    rows.append(
        ("v2g_steps_off", 1e6 / sps_off, f"steps_per_sec={sps_off:,.0f}")
    )
    rows.append(
        (
            "v2g_steps_on",
            1e6 / sps_on,
            f"steps_per_sec={sps_on:,.0f} ratio_on_off={sps_on/sps_off:.2f}",
        )
    )

    # --- 2+3. mixed-distribution PPO + profit vs baselines ----------------
    env = ChargaxEnv(EnvConfig(allow_v2g=True))
    mix = list(scenarios.V2G_MIXED_PACK)
    stacked = scenarios.stack_params([scenarios.make(n).make_params(env) for n in mix])
    cfg = PPOConfig(
        total_timesteps=90_000 if quick else 2_000_000,
        num_envs=12,
        rollout_steps=150 if quick else 300,
        hidden=(64, 64) if quick else (128, 128),
    )
    train = jax.jit(make_train(cfg, env, scenario_params=stacked))
    # compile first, time the run (matches speed_table's post-compile timing)
    compiled = train.lower(jax.random.key(0)).compile()
    t0 = time.perf_counter()
    out = compiled(jax.random.key(0))
    jax.block_until_ready(out["metrics"]["rollout_reward"])
    train_wall = time.perf_counter() - t0
    train_sps = cfg.total_timesteps / train_wall
    rows.append(
        (
            "v2g_ppo_mixed_train",
            1e6 / train_sps,
            f"env_steps_per_sec={train_sps:,.0f} scenarios={len(mix)}",
        )
    )

    # profit on the ToU scenario: PPO + arbitrage vs always-max.  The
    # us_per_call column stays a time (eval µs per env-step, compile
    # included); profits live in the derived string and LAST_SUMMARY
    tou_params = scenarios.make(TOU_SCENARIO).make_params(env)
    key = jax.random.key(42)
    n_eval = 32
    res, eval_us = {}, {}
    for name, (pol, pol_params) in {
        "ppo": (make_ppo_policy(env), out["runner_state"].params),
        "max_charge": (max_charge_policy(env), None),
        "v2g_arbitrage": (v2g_arbitrage_policy(env, tou_params), None),
    }.items():
        t0 = time.perf_counter()
        res[name] = evaluate(
            env, pol, pol_params, key, n_eval, env_params=tou_params
        )
        eval_us[name] = (
            (time.perf_counter() - t0) * 1e6 / (n_eval * env.config.episode_steps)
        )
    base = res["max_charge"]["daily_profit"]
    for name in ("ppo", "v2g_arbitrage"):
        r = res[name]
        rows.append(
            (
                f"v2g_profit_{name}",
                eval_us[name],
                f"profit={r['daily_profit']:.0f} baseline={base:.0f} "
                f"ratio={r['daily_profit']/max(abs(base),1e-9):.2f} "
                f"discharged_kwh={r['energy_discharged_kwh']:.0f}",
            )
        )

    best_v2g = max(res["ppo"]["daily_profit"], res["v2g_arbitrage"]["daily_profit"])
    LAST_SUMMARY = {
        "steps_per_sec_v2g_off": round(sps_off),
        "steps_per_sec_v2g_on": round(sps_on),
        "ppo_mixed_env_steps_per_sec": round(train_sps),
        "mixed_scenarios": mix,
        "tou_scenario": TOU_SCENARIO,
        "profit_max_charge_baseline": round(base, 2),
        "profit_ppo": round(res["ppo"]["daily_profit"], 2),
        "profit_v2g_arbitrage": round(res["v2g_arbitrage"]["daily_profit"], 2),
        "discharged_kwh_ppo": round(res["ppo"]["energy_discharged_kwh"], 2),
        "discharged_kwh_arbitrage": round(
            res["v2g_arbitrage"]["energy_discharged_kwh"], 2
        ),
        "v2g_beats_max_baseline": bool(best_v2g > base),
    }
    return rows


if __name__ == "__main__":
    for name, v, d in run():
        print(f"{name},{v:.3f},{d}")
