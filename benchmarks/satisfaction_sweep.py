"""Paper Figure 4b/c: user-satisfaction alpha sweep.

Trains PPO with increasing satisfaction-penalty weight alpha (Eq. 3) and
reports missing-kWh-at-departure and daily profit.  Validation claim: higher
alpha reduces missing charge while profit stays near-flat (Fig. 4b)."""
from __future__ import annotations

import jax

from repro.core import ChargaxEnv, EnvConfig, RewardWeights
from repro.rl import PPOConfig, evaluate, make_ppo_policy, make_train


def run(quick: bool = True) -> list[tuple[str, float, str]]:
    rows = []
    alphas = [0.0, 1.0, 4.0]
    timesteps = 300_000 if quick else 1_500_000
    env = ChargaxEnv(EnvConfig(scenario="shopping", traffic="high"))
    for alpha in alphas:
        weights = RewardWeights(satisfaction_time=alpha)
        params = env.make_params(weights=weights)
        cfg = PPOConfig(total_timesteps=timesteps, num_envs=12, rollout_steps=300)
        train = jax.jit(make_train(cfg, env, env_params=params))
        out = train(jax.random.key(0))
        pol = make_ppo_policy(env)
        # evaluate on the UNPENALISED env so profit numbers are comparable
        res = evaluate(env, pol, out["runner_state"].params, jax.random.key(1), 32)
        rows.append(
            (
                f"fig4b_alpha_{alpha:g}",
                res["missing_kwh"],
                f"missing_kwh={res['missing_kwh']:.1f} profit={res['daily_profit']:.0f} "
                f"overtime={res['overtime_steps']:.1f}",
            )
        )
    return rows


if __name__ == "__main__":
    for name, v, d in run():
        print(f"{name},{v:.2f},{d}")
