"""Render §Dry-run / §Roofline tables from results/*.json into markdown."""
from __future__ import annotations

import json
import os


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(path="results/dryrun.json") -> str:
    if not os.path.exists(path):
        return "(dry-run results missing — run repro.launch.dryrun)"
    rows = json.load(open(path))
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = [
        "| arch | shape | mesh | ok | compile s | GiB/dev | fits 16G | collective GiB (once-counted) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        coll = r.get("collectives", {}).get("total_bytes", 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {'Y' if r.get('ok') else 'FAIL'} "
            f"| {r.get('compile_s','-')} | {fmt_bytes(r.get('bytes_per_device',0))} "
            f"| {'Y' if r.get('fits_16g_hbm') else 'tight'} | {fmt_bytes(coll)} |"
        )
    n_ok = sum(1 for r in rows if r.get("ok"))
    out.append(f"\n{n_ok}/{len(rows)} cells compile.")
    return "\n".join(out)


def roofline_table(path="results/roofline.json") -> str:
    if not os.path.exists(path):
        return "(roofline results missing — run repro.analysis.roofline)"
    rows = json.load(open(path))
    rows = [r for r in rows if "bottleneck" in r]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | t_compute s | t_memory s | t_collective s | bound | "
        "model TFLOPs | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['bottleneck']}** "
            f"| {r['model_flops']/1e12:.1f} | {r['useful_compute_ratio']:.2f} "
            f"| {r['roofline_fraction_compute']:.2f} |"
        )
    return "\n".join(out)


def run(quick: bool = True):
    dr = dryrun_table()
    rf = roofline_table()
    n = dr.count("| Y |")
    return [("dryrun_cells_ok", float(n), "see results/dryrun.json")]


if __name__ == "__main__":
    print("## Dry-run\n")
    print(dryrun_table())
    print("\n## Roofline\n")
    print(roofline_table())
