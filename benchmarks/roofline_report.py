"""Render §Dry-run / §Roofline tables from results/*.json into markdown,
plus the kernel-vs-reference speed table (ISSUE 10).

The kernel table times the SAME vmapped transition chain (request →
allocate → deliver, state threaded through a scan so XLA cannot hoist the
work) three ways:

  staged            — the lax pipeline ``env.step`` uses by default,
  fused_ref         — ``fused_transition`` on the jnp reference impl (the
                      CPU hot-path routing of ``EnvConfig.fused_step``),
  pallas_interpret  — the Pallas slab kernel in interpret mode (the only
                      way to exercise the kernel's lowering on CPU; its
                      absolute time is an emulation cost, not a perf claim
                      — on TPU/GPU the same kernel runs compiled).

Persisted by ``benchmarks.run`` as ``BENCH_roofline.json`` with
``fused_ref_vs_staged_frac`` in the summary; CI's bench-smoke job runs it.
"""
from __future__ import annotations

import json
import os
import time


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(path="results/dryrun.json") -> str:
    if not os.path.exists(path):
        return "(dry-run results missing — run repro.launch.dryrun)"
    rows = json.load(open(path))
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = [
        "| arch | shape | mesh | ok | compile s | GiB/dev | fits 16G | collective GiB (once-counted) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        coll = r.get("collectives", {}).get("total_bytes", 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {'Y' if r.get('ok') else 'FAIL'} "
            f"| {r.get('compile_s','-')} | {fmt_bytes(r.get('bytes_per_device',0))} "
            f"| {'Y' if r.get('fits_16g_hbm') else 'tight'} | {fmt_bytes(coll)} |"
        )
    n_ok = sum(1 for r in rows if r.get("ok"))
    out.append(f"\n{n_ok}/{len(rows)} cells compile.")
    return "\n".join(out)


def roofline_table(path="results/roofline.json") -> str:
    if not os.path.exists(path):
        return "(roofline results missing — run repro.analysis.roofline)"
    rows = json.load(open(path))
    rows = [r for r in rows if "bottleneck" in r]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | t_compute s | t_memory s | t_collective s | bound | "
        "model TFLOPs | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['bottleneck']}** "
            f"| {r['model_flops']/1e12:.1f} | {r['useful_compute_ratio']:.2f} "
            f"| {r['roofline_fraction_compute']:.2f} |"
        )
    return "\n".join(out)


def bench_kernel_vs_reference(
    n_envs: int = 128, n_iters: int = 20, rounds: int = 3
) -> dict[str, float]:
    """Seconds per variant for ``n_iters`` chained transitions × ``n_envs``.

    States thread through the scan (each step consumes the previous step's
    delivered state), so the three programs do real sequential work; targets
    are fixed.  Interleaved rounds, min per variant.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import ChargaxEnv, EnvConfig, transition
    from repro.kernels.chargax_step import ops
    from repro.utils import replace

    env = ChargaxEnv(EnvConfig())
    params = env.default_params
    fp = replace(params, pole=ops.build_pole_params(params))
    dt = env.config.dt_hours
    n = env.n_evse

    keys = jax.random.split(jax.random.key(0), n_envs)
    _, state = jax.vmap(env.reset)(keys)
    k1, k2 = jax.random.split(jax.random.key(1))
    te = jax.random.uniform(k1, (n_envs, n), minval=-1.0, maxval=1.0) * params.evse_max_current
    tb = jax.random.uniform(k2, (n_envs,), minval=-1.0, maxval=1.0) * params.batt_max_current

    def staged_one(s, e, b):
        applied = transition.request(params, s, e, b, dt)
        alloc = transition.allocate(params, s, applied)
        return alloc, transition.deliver(params, s, alloc.applied, dt)

    def fused_one(impl):
        return lambda s, e, b: ops.fused_transition(fp, s, e, b, dt, impl=impl)

    def chained(one):
        v = jax.vmap(one)

        @jax.jit
        def run_chain(state, te, tb):
            def body(s, _):
                alloc, charged = v(s, te, tb)
                return charged.state, alloc.power_kw.sum()
            s, p = jax.lax.scan(body, state, None, n_iters)
            return s, p.sum()

        return run_chain

    fns = {
        "staged": chained(staged_one),
        "fused_ref": chained(fused_one("ref")),
        "pallas_interpret": chained(fused_one("interpret")),
    }
    for fn in fns.values():  # compile everything before timing
        _, p = fn(state, te, tb)
        jax.block_until_ready(p)

    best = {k: float("inf") for k in fns}
    for _ in range(max(rounds, 1)):
        for k, fn in fns.items():  # interleaved
            t0 = time.perf_counter()
            _, p = fn(state, te, tb)
            jax.block_until_ready(p)
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


LAST_SUMMARY: dict | None = None  # set by run(); persisted by benchmarks.run


def run(quick: bool = True):
    global LAST_SUMMARY
    import jax

    dr = dryrun_table()
    rf = roofline_table()
    n = dr.count("| Y |")
    rows = [("dryrun_cells_ok", float(n), "see results/dryrun.json")]

    n_envs, n_iters = (128, 20) if quick else (512, 50)
    t = bench_kernel_vs_reference(n_envs, n_iters, rounds=3)
    per_step = {k: v / (n_iters * n_envs) * 1e6 for k, v in t.items()}
    frac = t["fused_ref"] / t["staged"] - 1.0
    rows.append(("kernel_staged", per_step["staged"], f"{n_envs} envs x {n_iters} chained"))
    rows.append(
        ("kernel_fused_ref", per_step["fused_ref"], f"fused-ref-vs-staged {frac:+.2%}")
    )
    rows.append(
        (
            "kernel_pallas_interpret",
            per_step["pallas_interpret"],
            "interpret-mode emulation cost (compiled kernel needs TPU/GPU)",
        )
    )
    LAST_SUMMARY = {
        "kernel_n_envs": n_envs,
        "kernel_n_iters": n_iters,
        "staged_us_per_env_step": round(per_step["staged"], 3),
        "fused_ref_us_per_env_step": round(per_step["fused_ref"], 3),
        "pallas_interpret_us_per_env_step": round(per_step["pallas_interpret"], 3),
        "fused_ref_vs_staged_frac": round(frac, 4),
        "backend": jax.default_backend(),
    }
    return rows


if __name__ == "__main__":
    print("## Dry-run\n")
    print(dryrun_table())
    print("\n## Roofline\n")
    print(roofline_table())
    print("\n## Kernel vs reference\n")
    for name, us, derived in run()[1:]:
        print(f"{name},{us:.2f},{derived}")
