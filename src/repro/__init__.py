"""Chargax at pod scale — see DESIGN.md."""
__version__ = "1.0.0"
