"""Composable wrappers over the functional :class:`~repro.envs.base.Environment`.

The stack replaces the per-consumer vmap/autoreset glue that used to live in
``rl/ppo.py`` (``nest``/``flat``/``v_reset``/``v_step``), ``rl/eval.py`` and
the benchmarks.  Each wrapper is proven bit-identical to the hand-rolled
pattern it absorbs (``tests/envs/test_wrappers.py``):

======================  =====================================================
``AutoReset``           restarts finished episodes inside ``step`` (the
                        PureJaxRL where(done) pattern)
``LogWrapper``          episode return/length accounting surfaced in ``info``
``VmapWrapper``         batches an env over a leading axis; supports the
                        nested scenario×env layout (S-axis tables, one copy
                        per scenario) and per-env stacked params
``FleetAdapter``        presents :class:`~repro.core.fleet.FleetEnv` through
                        the protocol (TimeStep returns + batched spaces)
``GymnasiumBridge``     non-JAX consumers — see :mod:`repro.envs.gym_bridge`
======================  =====================================================

Canonical single-env composition (what PPO builds internally)::

    env   = ChargaxEnv(EnvConfig())
    wenv  = AutoReset(VmapWrapper(env, num_envs))       # batched, autoreset
    obs, state = wenv.reset(key, params)
    ts = wenv.step(key, state, action, params)          # ts.done marks ends
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.envs import spaces
from repro.envs.base import Environment, TimeStep
from repro.obs.metrics import MetricsAccumulator
from repro.obs.trace import annotate


class Wrapper(Environment):
    """Delegating base wrapper: behaves exactly like the wrapped env."""

    def __init__(self, env: Environment):
        self._env = env

    def __getattr__(self, name: str) -> Any:
        if name.startswith("_"):
            raise AttributeError(name)
        return getattr(self._env, name)

    # -- protocol delegation -------------------------------------------
    def reset(self, key: jax.Array, params: Any | None = None):
        return self._env.reset(key, params)

    def step(self, key: jax.Array, state: Any, action: Any, params: Any | None = None):
        return self._env.step(key, state, action, params)

    @property
    def default_params(self) -> Any:
        return self._env.default_params

    @property
    def observation_space(self) -> spaces.Space:
        return self._env.observation_space

    @property
    def action_space(self) -> spaces.Space:
        return self._env.action_space

    @property
    def unwrapped(self) -> Environment:
        return self._env.unwrapped

    def with_fused_step(self, fused: bool) -> "Wrapper":
        """This stack with the inner env's fused hot path toggled.

        Rebuilds the wrapper chain around ``inner.with_fused_step`` (wrappers
        close over vmapped step functions at construction, so toggling after
        the fact must reconstruct).  Returns self when nothing changes.
        """
        inner = self._env.with_fused_step(fused)
        if inner is self._env:
            return self
        return type(self)(inner)


def _where_done(done: jnp.ndarray, on_done: Any, otherwise: Any) -> Any:
    """``where(done, a, b)`` with ``done`` broadcast along trailing axes —
    the exact select PPO's hand-rolled auto-reset used."""

    def sel(r, n):
        d = done.reshape(done.shape + (1,) * (n.ndim - done.ndim))
        return jnp.where(d, r, n)

    return jax.tree_util.tree_map(sel, on_done, otherwise)


class AutoReset(Wrapper):
    """Restart finished episodes inside ``step``.

    ``step`` consumes one key, split into a step key and a reset key; where
    ``done`` the returned obs/state are a fresh ``reset`` (reward, done and
    info still describe the *finishing* transition, so returns/GAE see the
    terminal step).  Composes above :class:`VmapWrapper` — the inner env
    splits each key per environment — which reproduces PPO's historical
    vmapped step + vmapped reset + ``where(done)`` path bit-for-bit.
    """

    def step(
        self, key: jax.Array, state: Any, action: Any, params: Any | None = None
    ) -> TimeStep:
        with annotate("wrap/AutoReset"):
            k_step, k_reset = jax.random.split(key)
            ts = self._env.step(k_step, state, action, params)
            r_obs, r_state = self._env.reset(k_reset, params)
            obs = _where_done(ts.done, r_obs, ts.obs)
            new_state = _where_done(ts.done, r_state, ts.state)
            return TimeStep(obs, new_state, ts.reward, ts.done, ts.info)


class LogState(NamedTuple):
    """Episode accounting carried alongside the wrapped env state."""

    env_state: Any
    episode_return: jnp.ndarray
    episode_length: jnp.ndarray
    returned_episode_return: jnp.ndarray
    returned_episode_length: jnp.ndarray
    # in-jit KPI accumulator (None unless the wrapper was given metrics=…)
    metrics: MetricsAccumulator | None = None


class LogWrapper(Wrapper):
    """Track episode return/length; surface the *last finished* episode's
    totals in ``info`` (PureJaxRL's LogWrapper semantics).

    Adds ``info["episode_return"]`` / ``info["episode_length"]`` (values of
    the most recently completed episode, frozen between episode ends) and
    ``info["returned_episode"]`` (this step finished an episode).  Wrap it
    *outside* :class:`AutoReset` so the running totals survive the restart.

    ``metrics=`` names per-step ``info`` scalars (``"profit"``,
    ``"energy_delivered"``, ...; ``"reward"`` is always available) to fold
    into a :class:`repro.obs.MetricsAccumulator` carried in
    :class:`LogState` — KPIs accumulate on device through the rollout scan
    and flush to the host once, after it (``state.metrics.flush()``).  This
    is how PPO and eval report domain KPIs per scenario without extra
    device syncs.  Works over any inner env whose ``info`` carries the
    named scalars, including :class:`FleetAdapter` fleets (per-station
    lanes accumulate independently).
    """

    def __init__(self, env: Environment, metrics: tuple[str, ...] = ()):
        super().__init__(env)
        self.metric_names = tuple(metrics)

    def with_fused_step(self, fused: bool) -> "LogWrapper":
        inner = self._env.with_fused_step(fused)
        if inner is self._env:
            return self
        return type(self)(inner, self.metric_names)

    def _make_acc(self, batch: tuple[int, ...]) -> MetricsAccumulator | None:
        if not self.metric_names:
            return None
        return MetricsAccumulator.create(self.metric_names, batch_shape=batch)

    def reset(self, key: jax.Array, params: Any | None = None):
        obs, env_state = self._env.reset(key, params)
        batch = jnp.shape(obs)[:-1]
        zf = jnp.zeros(batch, jnp.float32)
        zi = jnp.zeros(batch, jnp.int32)
        return obs, LogState(env_state, zf, zi, zf, zi, self._make_acc(batch))

    def step(
        self, key: jax.Array, state: LogState, action: Any, params: Any | None = None
    ) -> TimeStep:
        with annotate("wrap/LogWrapper"):
            ts = self._env.step(key, state.env_state, action, params)
            ep_ret = state.episode_return + ts.reward
            ep_len = state.episode_length + 1
            done = ts.done
            acc = state.metrics
            if acc is not None:
                acc = acc.update({"reward": ts.reward, **ts.info})
            new_state = LogState(
                env_state=ts.state,
                episode_return=jnp.where(done, 0.0, ep_ret),
                episode_length=jnp.where(done, 0, ep_len),
                returned_episode_return=jnp.where(
                    done, ep_ret, state.returned_episode_return
                ),
                returned_episode_length=jnp.where(
                    done, ep_len, state.returned_episode_length
                ),
                metrics=acc,
            )
            info = dict(ts.info)
            info["episode_return"] = new_state.returned_episode_return
            info["episode_length"] = new_state.returned_episode_length
            info["returned_episode"] = done
            return TimeStep(ts.obs, new_state, ts.reward, done, info)


class VmapWrapper(Wrapper):
    """Batch an environment over a leading axis of ``num_envs``.

    ``reset``/``step`` take ONE key and split it into ``num_envs`` per-env
    keys — exactly the ``jax.random.split(k, num_envs)`` discipline the
    hand-rolled consumers used, so same keys give bit-identical rollouts.

    Three parameter layouts:

    * default — one params pytree broadcast to every env
      (``in_axes=(0, None)``);
    * ``params_axis=0`` — a stacked ``(num_envs, ...)`` pytree mapped
      per-env (the ``rl.eval`` per-episode scenario/fleet layout);
    * ``num_scenarios=S`` — the nested scenario×env layout from PR 2: the
      batch is viewed as ``(S, num_envs // S)``, the *outer* vmap maps the
      stacked scenario tables (leading axis S — one copy per scenario,
      never per env) and the *inner* vmap broadcasts each scenario's params
      to its block of envs.  Inputs/outputs stay flat ``(num_envs, ...)``;
      the (S, E) nesting is internal.
    """

    def __init__(
        self,
        env: Environment,
        num_envs: int,
        params_axis: int | None = None,
        num_scenarios: int | None = None,
        fused_step: bool | None = None,
    ):
        if fused_step is not None:
            env = env.with_fused_step(fused_step)
        super().__init__(env)
        if num_envs < 1:
            raise ValueError(f"num_envs must be >= 1, got {num_envs}")
        if num_scenarios is not None:
            if params_axis is not None:
                raise ValueError("pass either params_axis or num_scenarios, not both")
            if num_envs % num_scenarios != 0:
                raise ValueError(
                    f"num_envs={num_envs} is not a multiple of "
                    f"{num_scenarios} scenarios: the nested vmap assigns "
                    "num_envs // S envs per scenario"
                )
        self.num_envs = int(num_envs)
        self.params_axis = params_axis
        self.num_scenarios = num_scenarios
        if num_scenarios is not None:
            self._n_per = num_envs // num_scenarios
            self._v_reset = jax.vmap(
                jax.vmap(env.reset, in_axes=(0, None)), in_axes=(0, 0)
            )
            self._v_step = jax.vmap(
                jax.vmap(env.step, in_axes=(0, 0, 0, None)), in_axes=(0, 0, 0, 0)
            )
        else:
            self._v_reset = jax.vmap(env.reset, in_axes=(0, params_axis))
            self._v_step = jax.vmap(env.step, in_axes=(0, 0, 0, params_axis))

    def with_fused_step(self, fused: bool) -> "VmapWrapper":
        inner = self._env.with_fused_step(fused)
        if inner is self._env:
            return self
        return type(self)(inner, self.num_envs, self.params_axis, self.num_scenarios)

    # -- (num_envs, ...) <-> (S, E, ...) views --------------------------
    def _nest(self, tree: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda x: x.reshape(
                (self.num_scenarios, self._n_per) + x.shape[1:]
            ),
            tree,
        )

    def _flat(self, tree: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda x: x.reshape((self.num_envs,) + x.shape[2:]), tree
        )

    def _resolve(self, params: Any | None) -> Any:
        if params is not None:
            return params
        if self.params_axis is not None or self.num_scenarios is not None:
            raise ValueError(
                "stacked-params VmapWrapper needs explicit params: the inner "
                "env's default_params has no leading stack axis"
            )
        return self._env.default_params

    @property
    def default_params(self) -> Any:
        # route through _resolve so the stacked-params modes raise their
        # informative error instead of handing back an unstacked pytree
        return self._resolve(None)

    # -- protocol ------------------------------------------------------
    def reset(self, key: jax.Array, params: Any | None = None):
        params = self._resolve(params)
        keys = jax.random.split(key, self.num_envs)
        if self.num_scenarios is None:
            return self._v_reset(keys, params)
        obs, state = self._v_reset(self._nest(keys), params)
        return self._flat(obs), self._flat(state)

    def step(
        self, key: jax.Array, state: Any, action: Any, params: Any | None = None
    ) -> TimeStep:
        with annotate("wrap/VmapWrapper"):
            params = self._resolve(params)
            keys = jax.random.split(key, self.num_envs)
            if self.num_scenarios is None:
                return self._v_step(keys, state, action, params)
            ts = self._v_step(
                self._nest(keys), self._nest(state), self._nest(action), params
            )
            return TimeStep(
                self._flat(ts.obs),
                self._flat(ts.state),
                self._flat(ts.reward),
                self._flat(ts.done),
                self._flat(ts.info),
            )

    @property
    def observation_space(self) -> spaces.Space:
        return spaces.batch(self._env.observation_space, self.num_envs)

    @property
    def action_space(self) -> spaces.Space:
        return spaces.batch(self._env.action_space, self.num_envs)


class FleetAdapter(Wrapper):
    """Present a :class:`~repro.core.fleet.FleetEnv` through the protocol.

    ``FleetEnv`` stays a thin vmapped implementation with its historical
    tuple-returning ``step``; the adapter adds :class:`TimeStep` returns and
    the ``(n_stations, ...)``-batched spaces so fleets compose with the rest
    of the wrapper stack (e.g. ``AutoReset(FleetAdapter(fleet))`` — the
    fleet's per-station ``done`` broadcasts through the auto-reset select).
    """

    def __init__(self, env: Any, fused_step: bool | None = None):
        if fused_step is not None:
            env = env.with_fused_step(fused_step)
        super().__init__(env)

    def step(
        self, key: jax.Array, state: Any, action: Any, params: Any | None = None
    ) -> TimeStep:
        with annotate("wrap/FleetAdapter"):
            obs, state, reward, done, info = self._env.step(key, state, action, params)
            return TimeStep(obs, state, reward, done, info)

    @property
    def observation_space(self) -> spaces.Space:
        return spaces.batch(
            self._env.template.observation_space, self._env.n_stations
        )

    @property
    def action_space(self) -> spaces.Space:
        return spaces.batch(self._env.template.action_space, self._env.n_stations)

    @property
    def unwrapped(self) -> Any:
        return self._env
