"""The functional ``Environment`` protocol (Jumanji/gymnax-style).

Every Chargax environment — the single station, fleets, and anything a
wrapper produces — speaks one typed interface:

    obs, state = env.reset(key, params)
    ts = env.step(key, state, action, params)      # ts: TimeStep

``reset``/``step`` are pure and jit/vmap/scan-compatible; ``params`` is a
numeric pytree (``None`` selects ``env.default_params``) so sweeps and
scenario swaps never recompile.  :class:`TimeStep` is a NamedTuple and
therefore *unpacks exactly like the historical 5-tuple*::

    obs, state, reward, done, info = env.step(key, state, action, params)

so protocol adoption is non-breaking for tuple-style consumers while typed
consumers can write ``ts.obs`` / ``ts.reward``.

Shapes and bounds live in typed :mod:`repro.envs.spaces` objects
(``observation_space`` / ``action_space``), replacing the scattered
``obs_dim`` / ``num_action_heads`` / ``num_actions_per_head`` integers —
those remain available as thin aliases derived *from* the spaces.
"""
from __future__ import annotations

import abc
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.spaces import Space


class TimeStep(NamedTuple):
    """One environment transition.  Unpacks as ``(obs, state, reward, done,
    info)`` — field access (``ts.reward``) and tuple unpacking both work, and
    the NamedTuple is a pytree so it threads through jit/vmap/scan."""

    obs: Any
    state: Any
    reward: jnp.ndarray
    done: jnp.ndarray
    info: dict


class Environment(abc.ABC):
    """Functional environment protocol.

    Implementations must be *pure*: all randomness comes from the ``key``
    argument, all mutable quantities live in ``state``, and every number that
    may change between runs lives in the ``params`` pytree (shape-affecting
    configuration belongs in static env construction).
    """

    # ------------------------------------------------------------------
    # Core interface
    # ------------------------------------------------------------------
    @abc.abstractmethod
    def reset(self, key: jax.Array, params: Any | None = None) -> tuple[Any, Any]:
        """Start an episode: ``(obs, state)``."""

    @abc.abstractmethod
    def step(
        self, key: jax.Array, state: Any, action: Any, params: Any | None = None
    ) -> TimeStep:
        """Advance one transition and return a :class:`TimeStep`."""

    @property
    def default_params(self) -> Any:
        """Parameter pytree used when ``params=None``."""
        raise NotImplementedError(f"{type(self).__name__} has no default_params")

    # ------------------------------------------------------------------
    # Spaces
    # ------------------------------------------------------------------
    @property
    @abc.abstractmethod
    def observation_space(self) -> Space:
        """Typed observation space."""

    @property
    @abc.abstractmethod
    def action_space(self) -> Space:
        """Typed action space."""

    def sample_action(self, key: jax.Array) -> jnp.ndarray:
        """One uniform action from ``action_space`` (jit-compatible)."""
        return self.action_space.sample(key)

    # ------------------------------------------------------------------
    # Wrapper plumbing
    # ------------------------------------------------------------------
    @property
    def unwrapped(self) -> "Environment":
        """The innermost environment (wrappers override)."""
        return self
