"""``GymnasiumBridge`` — the protocol for non-JAX consumers (SB3, CleanRL...).

EV2Gym (Orfanoudakis et al., 2024) shows a Gym-compatible surface is what
makes an EV-charging simulator adoptable outside its home stack; this bridge
wraps any functional :class:`~repro.envs.base.Environment` into a stateful
``gymnasium.Env``: numpy in/out, an internally-carried PRNG key, jitted
``reset``/``step`` under the hood (so the Python-loop overhead is the only
cost vs the pure-JAX path).

gymnasium is an *optional* dependency: importing this module never requires
it; constructing the bridge without it raises a helpful ``ImportError``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.envs import spaces as repro_spaces
from repro.envs.base import Environment

try:  # optional dependency — the bridge only exists for non-JAX consumers
    import gymnasium as _gym

    _GymEnvBase: type = _gym.Env
except ImportError:  # pragma: no cover - exercised on gymnasium-less installs
    _gym = None
    _GymEnvBase = object


def _to_gym_space(space: repro_spaces.Space):
    if isinstance(space, repro_spaces.Box):
        return _gym.spaces.Box(
            low=space.low.astype(np.float32),
            high=space.high.astype(np.float32),
            shape=space.shape,
            dtype=np.float32,
        )
    if isinstance(space, repro_spaces.MultiDiscrete):
        if space.nvec.ndim != 1:
            raise ValueError(
                f"gymnasium MultiDiscrete needs a 1-D nvec, got {space.shape}"
            )
        return _gym.spaces.MultiDiscrete(space.nvec.astype(np.int64))
    if isinstance(space, repro_spaces.Discrete):
        return _gym.spaces.Discrete(space.n)
    raise TypeError(f"cannot convert {type(space).__name__} to a gymnasium space")


class GymnasiumBridge(_GymEnvBase):
    """A stateful ``gymnasium.Env`` view of a functional environment.

    Wraps a *single-instance* env (scalar reward/done): batched envs
    (``VmapWrapper``, ``FleetAdapter``) have multi-axis action spaces and are
    rejected at construction — gymnasium's vector API is a different
    contract.  Chargax episodes end at a fixed horizon, so ``done`` maps to
    gymnasium's *truncated* flag (``terminated`` stays False).  ``info``
    leaves are converted to numpy scalars/arrays.
    """

    metadata = {"render_modes": []}

    def __init__(self, env: Environment, params: Any | None = None, seed: int = 0):
        if _gym is None:
            raise ImportError(
                "GymnasiumBridge requires the optional 'gymnasium' package "
                "(pip install gymnasium); the pure-JAX protocol has no such "
                "dependency"
            )
        self._env = env
        self._params = params if params is not None else env.default_params
        self._key = jax.random.key(seed)
        self._state: Any = None
        self._jit_reset = jax.jit(env.reset)
        self._jit_step = jax.jit(env.step)
        self.observation_space = _to_gym_space(env.observation_space)
        self.action_space = _to_gym_space(env.action_space)

    # ------------------------------------------------------------------
    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def reset(self, *, seed: int | None = None, options: dict | None = None):
        if seed is not None:
            self._key = jax.random.key(seed)
        obs, self._state = self._jit_reset(self._next_key(), self._params)
        return np.asarray(obs), {}

    def step(self, action):
        ts = self._jit_step(
            self._next_key(),
            self._state,
            jnp.asarray(action, jnp.int32),
            self._params,
        )
        self._state = ts.state
        info = {k: np.asarray(v) for k, v in ts.info.items()}
        # fixed-horizon episode end -> truncation, not termination
        return np.asarray(ts.obs), float(ts.reward), False, bool(ts.done), info

    def render(self):  # pragma: no cover - nothing to draw
        return None

    def close(self):
        return None
