"""Typed observation/action spaces for the functional ``Environment`` protocol.

Jumanji/gymnax-style: a :class:`Space` describes the shape, dtype and bounds
of one side of the env interface, replacing the scattered
``obs_dim``/``num_action_heads``/``num_actions_per_head`` integers that every
consumer used to re-derive.  Spaces are plain Python objects (never traced);
``sample`` is jit/vmap-compatible and ``contains`` is a host-side check used
by tests and the Gymnasium bridge.

``batch(space, n)`` prepends a batch axis — how :class:`~repro.envs.wrappers.
VmapWrapper` and :class:`~repro.envs.wrappers.FleetAdapter` derive their
batched spaces from the single-env ones.
"""
from __future__ import annotations

import abc
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


class Space(abc.ABC):
    """Base space: shape + dtype + sampling + membership."""

    shape: tuple[int, ...]
    dtype: Any

    @abc.abstractmethod
    def sample(self, key: jax.Array) -> jnp.ndarray:
        """Draw one element of the space (jit/vmap-compatible)."""

    @abc.abstractmethod
    def contains(self, x: Any) -> bool:
        """Host-side membership check (shape, dtype kind, bounds)."""


class Box(Space):
    """Continuous n-dimensional box ``[low, high]`` (possibly unbounded).

    ``low``/``high`` may be scalars (broadcast) or arrays of ``shape``.
    """

    def __init__(
        self,
        low: float | np.ndarray,
        high: float | np.ndarray,
        shape: tuple[int, ...],
        dtype: Any = jnp.float32,
    ):
        self.shape = tuple(int(s) for s in shape)
        self.dtype = dtype
        self.low = np.broadcast_to(np.asarray(low, np.float64), self.shape)
        self.high = np.broadcast_to(np.asarray(high, np.float64), self.shape)

    def sample(self, key: jax.Array) -> jnp.ndarray:
        # uniform inside finite bounds; standard normal along unbounded axes
        finite = np.isfinite(self.low) & np.isfinite(self.high)
        lo = jnp.asarray(np.where(finite, self.low, 0.0), self.dtype)
        hi = jnp.asarray(np.where(finite, self.high, 1.0), self.dtype)
        ku, kn = jax.random.split(key)
        u = jax.random.uniform(ku, self.shape, self.dtype, lo, hi)
        n = jax.random.normal(kn, self.shape, self.dtype)
        return jnp.where(jnp.asarray(finite), u, n)

    def contains(self, x: Any) -> bool:
        x = np.asarray(x)
        return bool(
            x.shape == self.shape
            and np.all(x >= self.low - 1e-6)
            and np.all(x <= self.high + 1e-6)
        )

    def __repr__(self) -> str:
        lo = float(self.low.min()) if self.low.size else -np.inf
        hi = float(self.high.max()) if self.high.size else np.inf
        return f"Box({lo:g}, {hi:g}, shape={self.shape})"

    def __eq__(self, other: Any) -> bool:
        return (
            isinstance(other, Box)
            and self.shape == other.shape
            and np.array_equal(self.low, other.low)
            and np.array_equal(self.high, other.high)
        )


class Discrete(Space):
    """A single categorical choice in ``{0, ..., n-1}``."""

    def __init__(self, n: int, dtype: Any = jnp.int32):
        self.n = int(n)
        self.shape = ()
        self.dtype = dtype

    def sample(self, key: jax.Array) -> jnp.ndarray:
        return jax.random.randint(key, self.shape, 0, self.n, self.dtype)

    def contains(self, x: Any) -> bool:
        x = np.asarray(x)
        return bool(
            x.shape == ()
            and np.issubdtype(x.dtype, np.integer)
            and 0 <= int(x) < self.n
        )

    def __repr__(self) -> str:
        return f"Discrete({self.n})"

    def __eq__(self, other: Any) -> bool:
        return isinstance(other, Discrete) and self.n == other.n


class MultiDiscrete(Space):
    """A grid of categorical choices: ``nvec[i...]`` options per element.

    Chargax's action space is the uniform case — ``(n_evse + 1)`` heads with
    ``2 * discretization + 1`` levels each (paper Table 3; the battery is the
    last head).  ``num_categories`` exposes the per-head count when uniform.
    """

    def __init__(self, nvec: Any, dtype: Any = jnp.int32):
        self.nvec = np.asarray(nvec, np.int64)
        if self.nvec.ndim == 0:
            self.nvec = self.nvec[None]
        self.shape = self.nvec.shape
        self.dtype = dtype

    @property
    def num_categories(self) -> int:
        """Per-element category count — defined only for uniform grids."""
        n = np.unique(self.nvec)
        if n.size != 1:
            raise ValueError(f"non-uniform MultiDiscrete: nvec spans {n}")
        return int(n[0])

    def sample(self, key: jax.Array) -> jnp.ndarray:
        n = np.unique(self.nvec)
        if n.size == 1:  # uniform grid: one randint, same draws as the
            # historical env.sample_action implementations
            return jax.random.randint(key, self.shape, 0, int(n[0]), self.dtype)
        u = jax.random.uniform(key, self.shape)
        return jnp.floor(u * jnp.asarray(self.nvec)).astype(self.dtype)

    def contains(self, x: Any) -> bool:
        x = np.asarray(x)
        return bool(
            x.shape == self.shape
            and np.issubdtype(x.dtype, np.integer)
            and np.all(x >= 0)
            and np.all(x < self.nvec)
        )

    def __repr__(self) -> str:
        try:
            return f"MultiDiscrete({self.num_categories} x {self.shape})"
        except ValueError:
            return f"MultiDiscrete(nvec={self.nvec.tolist()})"

    def __eq__(self, other: Any) -> bool:
        return isinstance(other, MultiDiscrete) and np.array_equal(
            self.nvec, other.nvec
        )


def batch(space: Space, n: int) -> Space:
    """Prepend a batch axis of size ``n`` to ``space``."""
    if isinstance(space, Box):
        return Box(
            np.broadcast_to(space.low, (n,) + space.shape),
            np.broadcast_to(space.high, (n,) + space.shape),
            (n,) + space.shape,
            space.dtype,
        )
    if isinstance(space, MultiDiscrete):
        return MultiDiscrete(
            np.broadcast_to(space.nvec, (n,) + space.shape), space.dtype
        )
    if isinstance(space, Discrete):
        return MultiDiscrete(np.full((n,), space.n), space.dtype)
    raise TypeError(f"cannot batch {type(space).__name__}")
