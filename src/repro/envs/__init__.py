"""Unified functional environment protocol + composable wrapper stack.

    from repro.envs import AutoReset, VmapWrapper
    env  = ChargaxEnv(EnvConfig())                   # implements Environment
    wenv = AutoReset(VmapWrapper(env, num_envs=16))  # batched + autoreset
    obs, state = wenv.reset(key, params)
    obs, state, reward, done, info = wenv.step(key, state, action, params)

See :mod:`repro.envs.base` for the protocol, :mod:`repro.envs.spaces` for
typed spaces, :mod:`repro.envs.wrappers` for the stack and
:mod:`repro.envs.gym_bridge` for the optional non-JAX surface.
"""
from repro.envs import spaces
from repro.envs.base import Environment, TimeStep
from repro.envs.gym_bridge import GymnasiumBridge
from repro.envs.wrappers import (
    AutoReset,
    FleetAdapter,
    LogState,
    LogWrapper,
    VmapWrapper,
    Wrapper,
)

__all__ = [
    "AutoReset",
    "Environment",
    "FleetAdapter",
    "GymnasiumBridge",
    "LogState",
    "LogWrapper",
    "TimeStep",
    "VmapWrapper",
    "Wrapper",
    "spaces",
]
