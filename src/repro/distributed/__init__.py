"""Distribution substrate: sharding rules, env-batch placement, train/serve
steps, checkpointing, gradient compression (DESIGN.md §5)."""
from repro.distributed.env_sharding import (
    constrain_env_batch,
    env_shardings,
    make_shard_envs,
    place_env_batch,
)
from repro.distributed.sharding import (
    DP,
    batch_spec,
    cache_shardings,
    constrain,
    data_axes,
    param_shardings,
    param_spec,
)
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.train_step import (
    TrainState,
    TrainStepConfig,
    init_train_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

__all__ = [
    "DP",
    "batch_spec",
    "constrain_env_batch",
    "env_shardings",
    "make_shard_envs",
    "place_env_batch",
    "cache_shardings",
    "constrain",
    "data_axes",
    "param_shardings",
    "param_spec",
    "CheckpointManager",
    "TrainState",
    "TrainStepConfig",
    "init_train_state",
    "make_prefill_step",
    "make_serve_step",
    "make_train_step",
]
