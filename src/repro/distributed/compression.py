"""Gradient compression (int8 error-feedback) for the cross-pod hop.

1-bit/8-bit SGD-style codecs with error feedback: the quantisation residual
is carried in the train state and added back before the next compression, so
the scheme is unbiased in the long run (Seide et al., 2014; Karimireddy et
al., 2019).  Inside a single jit the compress->decompress pair round-trips
through int8, which is exactly what a real cross-pod all-reduce would move —
XLA's collective then transfers 1/4 of the bf16 bytes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantisation. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress_with_feedback(grads, error_feedback):
    """Apply EF-int8 to every gradient leaf; returns (grads', new_feedback)."""

    def one(g, ef):
        corrected = g.astype(jnp.float32) + ef
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), corrected - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_feedback)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_g, new_e
