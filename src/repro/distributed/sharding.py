"""Path-based sharding rules: parameter/activation/cache PartitionSpecs.

The model zoo names its leaves canonically (``q_proj``, ``expert_w_gate``,
``ssm_in_proj``, ...), so a small rule table assigns the tensor-parallel
('model') dim per leaf kind, and a generic FSDP pass shards the largest
remaining divisible dim over the data axes.  Anything non-divisible falls
back gracefully (fewer axes -> replicated), so every mesh shape compiles.

Mesh axes: ('data', 'model') single pod, ('pod', 'data', 'model') multi-pod
(DESIGN.md §5).  ``data_axes(mesh)`` returns ('pod','data') or ('data',).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# JAX version compat: the ambient-mesh API moved across releases.
#   jax >= 0.5: jax.sharding.set_mesh / jax.sharding.get_abstract_mesh
#   jax  < 0.5: `with mesh:` sets a thread-local physical mesh readable via
#               jax.interpreters.pxla.thread_resources
# ``set_mesh``/``get_abstract_mesh`` below present the new-style interface on
# both; all repo code goes through them instead of jax.sharding directly.
# ---------------------------------------------------------------------------
def set_mesh(mesh: Mesh):
    """Return a context manager making ``mesh`` the ambient mesh."""
    new = getattr(jax.sharding, "set_mesh", None)
    if new is not None:
        return new(mesh)
    return mesh  # jax<0.5: Mesh is itself a context manager


def get_abstract_mesh():
    """The ambient mesh (empty mesh when none is active), any JAX version."""
    new = getattr(jax.sharding, "get_abstract_mesh", None)
    if new is not None:
        return new()
    from jax.interpreters import pxla

    return pxla.thread_resources.env.physical_mesh


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# ---------------------------------------------------------------------------
# Sharding strategy (hillclimb lever, EXPERIMENTS.md §Perf):
#   '2d'   — FSDP over data axes x tensor-parallel over 'model' (default)
#   'fsdp' — params fully sharded over ALL axes, batch over ALL axes, no TP
#            (collective-optimal for models whose activations >> params)
#   'dp'   — replicated params, batch over all axes (tiny models)
# ---------------------------------------------------------------------------
_STRATEGY = "2d"


def set_strategy(s: str):
    global _STRATEGY
    assert s in ("2d", "fsdp", "dp"), s
    _STRATEGY = s


def get_strategy() -> str:
    return _STRATEGY


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


# leaf-name -> index of the dim to shard over 'model' (negative ok).
# Stacked layer params carry a leading layer axis handled separately.
_TP_DIM = {
    "q_proj": -1,
    "k_proj": -1,
    "v_proj": -1,
    "g_proj": -1,
    "o_proj": -2,
    "gate_proj": -1,
    "up_proj": -1,
    "down_proj": -2,
    "cm_k_proj": -1,
    "cm_v_proj": -2,
    "cm_r_proj": -1,
    "w_lora_a": -1,
    "w_lora_b": -1,
    "r_proj": -1,
    "expert_w_gate": 0,  # expert-parallel
    "expert_w_up": 0,
    "expert_w_down": 0,
    "ssm_in_proj": -1,
    "ssm_out_proj": -2,
    "embed": 0,  # vocab
    "unembed": -1,  # vocab
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _is_stacked(path) -> bool:
    return any(
        isinstance(e, jax.tree_util.DictKey) and str(e.key) in ("layers", "enc_layers", "dec_layers")
        for e in path
    )


def param_spec(path, shape: tuple[int, ...], mesh: Mesh, strategy: str | None = None) -> P:
    """PartitionSpec for one parameter leaf."""
    strategy = strategy or get_strategy()
    name = _leaf_name(path)
    stacked = _is_stacked(path)
    ndim = len(shape)
    spec: list[Any] = [None] * ndim
    lead = 1 if stacked else 0  # skip the layer-stack axis

    if strategy == "dp":
        return P(*spec)

    if strategy == "fsdp":
        # experts stay expert-parallel over 'model' (gathering every expert
        # per device would be infeasible); everything else fully sharded
        if name.startswith("expert_w") and shape[lead] % mesh.shape["model"] == 0:
            spec[lead] = "model"
            da = data_axes(mesh)
            dsize = _axis_size(mesh, da)
            cand = [
                i for i in range(lead + 1, ndim)
                if shape[i] % dsize == 0 and shape[i] >= dsize
            ]
            if cand and dsize > 1:
                best = max(cand, key=lambda i: shape[i])
                spec[best] = da if len(da) > 1 else da[0]
            return P(*spec)
        # fully shard the largest divisible dim over as many axes as divide it
        for axes in (all_axes(mesh), data_axes(mesh) + ("model",), ("model",), data_axes(mesh)):
            axes = tuple(a for a in axes if a in mesh.axis_names)
            size = _axis_size(mesh, axes)
            if size <= 1:
                continue
            cand = [i for i in range(lead, ndim) if shape[i] % size == 0 and shape[i] >= size]
            if cand:
                best = max(cand, key=lambda i: shape[i])
                spec[best] = axes if len(axes) > 1 else axes[0]
                return P(*spec)
        return P(*spec)

    # --- '2d' (default): TP + FSDP -----------------------------------------
    # 1) tensor-parallel dim (negative = from the end; positive = after the
    #    layer-stack axis, e.g. the expert dim of stacked MoE weights)
    tp = _TP_DIM.get(name)
    if tp is not None and ndim - lead >= 2:
        idx = (ndim + tp) if tp < 0 else (tp + lead)
        if lead <= idx < ndim and shape[idx] % mesh.shape["model"] == 0:
            spec[idx] = "model"

    # 2) FSDP: largest remaining divisible dim over the data axes
    da = data_axes(mesh)
    dsize = _axis_size(mesh, da)
    if dsize > 1 and ndim - lead >= 1:
        candidates = [
            i for i in range(lead, ndim) if spec[i] is None and shape[i] % dsize == 0
        ]
        if candidates:
            best = max(candidates, key=lambda i: shape[i])
            if shape[best] >= dsize:  # don't shard tiny dims
                spec[best] = da if len(da) > 1 else da[0]
    return P(*spec)


def param_shardings(params, mesh: Mesh):
    """Pytree of NamedShardings matching ``params`` (works on ShapeDtypeStructs)."""

    def one(path, leaf):
        return NamedSharding(mesh, param_spec(path, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# activations / batch / cache
# ---------------------------------------------------------------------------
def batch_spec(mesh: Mesh, batch: int, strategy: str | None = None) -> P:
    """Shard the batch dim over the data axes ('2d') or all axes ('fsdp'/'dp')."""
    strategy = strategy or get_strategy()
    axes = data_axes(mesh) if strategy == "2d" else all_axes(mesh)
    use = []
    rem = batch
    for a in axes:
        if rem % mesh.shape[a] == 0:
            use.append(a)
            rem //= mesh.shape[a]
    if not use:
        return P(None)
    return P(tuple(use) if len(use) > 1 else use[0])


def token_sharding(mesh: Mesh, batch: int) -> NamedSharding:
    return NamedSharding(mesh, P(*batch_spec(mesh, batch), None))


def cache_spec(path, shape: tuple[int, ...], mesh: Mesh, batch: int) -> P:
    """KV/SSM cache sharding.

    KV caches (L, B, Hkv, S, hd): batch over data axes; the cache *sequence*
    over 'model' (flash-decoding style sequence parallelism — kv-head counts
    are below the TP width for every assigned arch).  For global_batch=1
    (long_500k) the sequence is sharded over data axes too.
    SSM/conv/wkv states: batch over data; feature dims over 'model' when
    divisible.
    """
    name = _leaf_name(path)
    da = data_axes(mesh)
    spec: list[Any] = [None] * len(shape)

    # locate the batch dim: caches are stacked (layer axis 0), batch axis 1;
    # whisper cross-cache 'ck'/'cv' share the same layout.
    bdim = 1 if len(shape) >= 2 else 0
    bspec = batch_spec(mesh, batch)[0]
    if shape[bdim] == batch and bspec is not None:
        spec[bdim] = bspec

    if name in ("k", "v", "ck", "cv") and len(shape) == 5:
        # (L, B, Hkv, S, hd): shard S over 'model' (+ data axes if batch=1)
        s_axes = ("model",) + (da if spec[bdim] is None else ())
        use: list[str] = []
        for a in s_axes:
            if shape[3] % _axis_size(mesh, tuple(use) + (a,)) == 0:
                use.append(a)
        if use:
            spec[3] = tuple(use) if len(use) > 1 else use[0]
    else:
        # states: shard the largest trailing dim over 'model' when divisible
        for i in range(len(shape) - 1, bdim, -1):
            if spec[i] is None and shape[i] % mesh.shape["model"] == 0 and shape[i] >= mesh.shape["model"]:
                spec[i] = "model"
                break
    return P(*spec)


def cache_shardings(cache, mesh: Mesh, batch: int):
    def one(path, leaf):
        return NamedSharding(mesh, cache_spec(path, leaf.shape, mesh, batch))

    return jax.tree_util.tree_map_with_path(one, cache)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Ambient-mesh activation constraints (model-code-side annotations)
# ---------------------------------------------------------------------------
DP = "__data_axes__"  # sentinel: expands to whichever of (pod, data) exist


def constrain(x, *entries):
    """``with_sharding_constraint`` against the ambient mesh (``set_mesh``).

    No-op when no mesh is active (single-device tests) or when an entry does
    not divide its dim.  Entries: axis name, tuple of names, the DP sentinel
    (the batch axes of the current strategy), or None.  Axes already consumed
    by an earlier entry are dropped (keeps 'fsdp' pins valid).  Model code
    can therefore annotate unconditionally.
    """
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    names = set(mesh.axis_names)
    strategy = get_strategy()
    dp_axes = tuple(
        a
        for a in (("pod", "data") if strategy == "2d" else ("pod", "data", "model"))
        if a in names
    )
    used: set[str] = set()
    spec: list = []
    for dim, e in zip(x.shape, entries):
        if e == DP:
            e = dp_axes if len(dp_axes) != 1 else dp_axes[0]
        if e is None:
            spec.append(None)
            continue
        axes = tuple(a for a in (e if isinstance(e, tuple) else (e,)) if a in names and a not in used)
        if not axes:
            spec.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % size == 0:
            used.update(axes)
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    spec += [None] * (len(x.shape) - len(spec))
    return jax.lax.with_sharding_constraint(x, P(*spec))


def opt_state_shardings(opt_state, params_shardings):
    """AdamW moments mirror the parameter shardings; step is replicated."""
    import dataclasses

    from repro.optim import AdamWState

    assert isinstance(opt_state, AdamWState) or hasattr(opt_state, "mu")
    mesh = jax.tree_util.tree_leaves(params_shardings)[0].mesh
    return AdamWState(
        step=NamedSharding(mesh, P()),
        mu=params_shardings,
        nu=params_shardings,
    )
