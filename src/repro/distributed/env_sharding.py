"""Environment-batch sharding: place the env/station axis over the mesh.

``FleetEnv`` rollouts and the PPO environment batch carry a leading
environment (or station) axis.  At pod scale that axis shards over the
mesh's data axes (``('pod', 'data')`` when present) so rollouts parallelise
across chips without host transfers — the paper's on-device-rollout claim
generalised to meshes (DESIGN.md §3).  On a single device every helper here
degrades to the identity, so the same env/PPO code compiles unchanged in
CPU tests.

Two flavours:

* **ambient** — :func:`constrain_env_batch` annotates the leading axis of
  every leaf against the mesh installed by ``sharding.set_mesh`` and is a
  no-op when none is active.  Env code (``FleetEnv``, ``make_train``) calls
  it unconditionally.
* **explicit** — :func:`make_shard_envs` / :func:`place_env_batch` build
  ``NamedSharding``s for a concrete mesh (launch scripts, benchmarks), with
  per-leaf divisibility fallback to replication so every mesh shape
  compiles.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding


def constrain_env_batch(tree: Any) -> Any:
    """Constrain the leading (env/station) axis of every leaf to the data axes.

    Ambient-mesh flavour of :func:`sharding.constrain`: a no-op without an
    active mesh or when the leading dim does not divide the data-axis size,
    so callers annotate unconditionally (single-device fallback).
    """
    return jax.tree_util.tree_map(lambda x: sharding.constrain(x, sharding.DP), tree)


def env_shardings(tree: Any, mesh: Mesh) -> Any:
    """Pytree of ``NamedSharding``s sharding each leaf's leading axis.

    Leaves whose leading dim does not divide the data-axis size (or scalars)
    are replicated — every fleet composition places on every mesh.
    """
    axes = sharding.data_axes(mesh)
    size = 1
    for a in axes:
        size *= mesh.shape[a]

    def one(x):
        shape = getattr(x, "shape", ())
        if not axes or size <= 1 or not shape or shape[0] % size:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0]))

    return jax.tree_util.tree_map(one, tree)


def place_env_batch(tree: Any, mesh: Mesh) -> Any:
    """``device_put`` a stacked env/fleet pytree onto the mesh's data axes."""
    return jax.tree_util.tree_map(
        jax.device_put, tree, env_shardings(tree, mesh)
    )


def make_shard_envs(mesh: Mesh):
    """Explicit-mesh constraint callable for ``make_train(shard_envs=...)``.

    Returns a function mapping an array (or pytree) to the same values with
    the leading env axis constrained onto ``mesh``'s data axes.
    """
    def shard(tree):
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, tree, env_shardings(tree, mesh)
        )

    return shard
