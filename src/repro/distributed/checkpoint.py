"""Fault-tolerant checkpointing (orbax is unavailable offline; DESIGN.md §5).

Guarantees:
  * step-atomic: writes land in ``step_XXXX.tmp`` and are renamed only after
    every leaf + metadata is fsynced — a crash mid-save never corrupts the
    latest checkpoint;
  * keep-k rotation;
  * async saves (background thread) off the training critical path;
  * **elastic restore**: leaves are stored as full logical arrays with their
    tree paths, so a checkpoint taken on one mesh restores onto any other
    mesh/topology — ``restore`` takes target shardings and ``device_put``s
    each leaf straight to its new layout;
  * restart-safe RNG/data-pipeline state: arbitrary small pytrees ride along
    in metadata ("extras").

On a real multi-host pod each host writes its addressable shards (the layout
is the same modulo a per-host shard index); this container has one host so
leaves are materialised fully.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extras: dict | None = None, blocking: bool = True):
        """Snapshot ``tree`` at ``step``.  Non-blocking saves copy to host
        first (cheap) and write in a background thread."""
        leaves = [(k, np.asarray(jax.device_get(v))) for k, v in _flatten(tree)]
        if blocking:
            self._write(step, leaves, extras or {})
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, leaves, extras or {})
            )
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, leaves, extras: dict):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extras": extras, "leaves": {}}
        for i, (key, arr) in enumerate(leaves):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._rotate()

    def _rotate(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None, shardings=None) -> tuple[Any, dict]:
        """Restore into the structure of ``template``.

        ``shardings``: optional matching pytree of NamedShardings — the
        elastic-resharding path: leaves are device_put straight onto the new
        mesh regardless of the mesh they were saved from.
        Returns (tree, extras).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)

        flat_t = jax.tree_util.tree_flatten_with_path(template)
        paths, treedef = [p for p, _ in flat_t[0]], flat_t[1]
        shard_leaves = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(paths)
        )
        leaves = []
        for path_entry, shard in zip(paths, shard_leaves):
            key = jax.tree_util.keystr(path_entry)
            info = manifest["leaves"][key]
            arr = np.load(os.path.join(path, info["file"]))
            leaves.append(jax.device_put(arr, shard) if shard is not None else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extras"]
