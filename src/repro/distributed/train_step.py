"""Distributed LM train/serve steps.

``make_train_step`` builds the jittable step used by both the real trainer
and the AOT dry-run: forward+backward (with per-layer remat via the model's
scan body), microbatched gradient accumulation (a scan — VMEM-bounding knob
for the big cells), AdamW with fp32 moments, optional int8 error-feedback
gradient compression on the cross-pod hop, and donated state.

``make_serve_step`` builds the one-token decode step against a sharded cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed import compression
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, AdamWState, adamw_init, adamw_update, apply_updates
from repro.utils import pytree_dataclass


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    num_microbatches: int = 1
    compress_grads: bool = False  # int8 error-feedback on gradients
    unroll_layers: bool = False  # unroll layer scans (FLOP-probe compiles)
    remat: bool = True  # per-layer activation checkpointing


@pytree_dataclass
class TrainState:
    params: Any
    opt: AdamWState
    error_feedback: Any  # compression residuals (empty dict if disabled)


def init_train_state(model, key: jax.Array, ts_cfg: TrainStepConfig) -> TrainState:
    params = model.init(key)
    ef = (
        jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if ts_cfg.compress_grads
        else {}
    )
    return TrainState(params=params, opt=adamw_init(params), error_feedback=ef)


def make_train_step(model, ts_cfg: TrainStepConfig) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch``: dict with tokens (B, L), labels (B, L) [, frames (B, F, d)].
    """
    from repro.optim import cosine_warmup_schedule

    cfg: ModelConfig = model.cfg
    lr_fn = cosine_warmup_schedule(ts_cfg.lr, ts_cfg.warmup_steps, ts_cfg.total_steps)
    opt_cfg = AdamWConfig(
        weight_decay=ts_cfg.weight_decay, max_grad_norm=ts_cfg.max_grad_norm
    )

    def loss_fn(params, batch):
        if cfg.family == "encdec":
            return model.loss(
                params, batch["tokens"], batch["labels"], batch["frames"],
                remat=ts_cfg.remat, unroll=ts_cfg.unroll_layers,
            )
        return model.loss(
            params, batch["tokens"], batch["labels"],
            remat=ts_cfg.remat, unroll=ts_cfg.unroll_layers,
        )

    def compute_grads(params, batch):
        n = ts_cfg.num_microbatches
        if n == 1:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            return loss, aux, grads

        micro = jax.tree_util.tree_map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch
        )

        def acc_fn(carry, mb):
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            g_acc, l_acc = carry
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) / n, g_acc, grads
            )
            return (g_acc, l_acc + loss / n), aux

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (grads, loss), auxs = jax.lax.scan(acc_fn, (zeros, jnp.float32(0.0)), micro)
        aux = jax.tree_util.tree_map(lambda a: a[-1], auxs)
        grads = jax.tree_util.tree_map(lambda g, p: g.astype(p.dtype), grads, params)
        return loss, aux, grads

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, aux, grads = compute_grads(state.params, batch)

        ef = state.error_feedback
        if ts_cfg.compress_grads:
            grads, ef = compression.compress_decompress_with_feedback(grads, ef)

        updates, opt, gnorm = adamw_update(grads, state.opt, state.params, lr_fn, opt_cfg)
        params = apply_updates(state.params, updates)
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "lr": lr_fn(opt.step),
            **{k: v for k, v in aux.items()},
        }
        return TrainState(params=params, opt=opt, error_feedback=ef), metrics

    return train_step


def make_serve_step(model, unroll: bool = False) -> Callable:
    """Returns serve_step(params, cache, tokens (B,1), pos) ->
    (next_tokens (B,1), cache) — greedy decode of ONE new token against the
    existing KV/state cache (the decode_* / long_* dry-run target)."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos, unroll=unroll)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, cache

    return serve_step


def make_prefill_step(model, unroll: bool = False) -> Callable:
    """Full-sequence forward (no bwd) — the prefill_32k dry-run target.

    Returns *last-position* logits (what a serving prefill emits before
    decode takes over); materialising (B, 32k, V) fp32 logits was the
    dominant memory term of every prefill cell (§Perf iteration 1)."""

    def prefill(params, batch):
        if model.cfg.family == "encdec":
            enc = model.encode(params, batch["frames"], unroll)
            x = model.decode_hidden(params, batch["tokens"], enc, unroll)
            w = params["embed"].T
        else:
            x, _ = model.apply_hidden(
                params, batch["tokens"], remat=False, unroll=unroll
            )
            w = (
                params["embed"].T
                if model.cfg.tied_embeddings
                else params["unembed"]
            )
        last = x[:, -1, :]
        return (last @ w.astype(last.dtype)).astype(jnp.float32)

    return prefill
