"""City-scale demand allocation: the level above station control.

One population-scale arrival stream (inhomogeneous Poisson over the same
day-profile/seasonality processes stations use) splits across a fleet of
stations through a gravity/queue choice model — pure array ops, riding
inside the fleet's compiled step::

    from repro import city
    from repro.core import FleetEnv

    cp = city.make_city("city_ring_evening", n_stations=6)
    fleet = FleetEnv(["paper_16"] * 6, city=cp)      # arrivals now per-station
    scores = city.sweep_layouts(fleet, [cp, ...], policy)   # placement loop

See README "City-scale serving" and docs/scenario_authoring.md (city axis).
"""
from repro.city.demand import (
    DemandAllocation,
    StationFeatures,
    allocate_demand,
    choice_logits,
    city_rates,
    station_features,
    stream_rate,
)
from repro.city.params import CityParams, demand_zones, layout_xy, make_city
from repro.city.sweep import sweep_layouts

__all__ = [
    "CityParams",
    "DemandAllocation",
    "StationFeatures",
    "allocate_demand",
    "choice_logits",
    "city_rates",
    "demand_zones",
    "layout_xy",
    "make_city",
    "station_features",
    "stream_rate",
    "sweep_layouts",
]
