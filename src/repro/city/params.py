"""City parameters: everything the demand-allocation layer reads, as one pytree.

A :class:`CityParams` describes the level *above* station control — a city of
drivers choosing among stations: where the stations sit (``station_xy``),
where demand originates (gravity zones), how big the driving population is,
how its arrivals distribute over the day/year, and how strongly drivers trade
off distance, price and queues when picking a station.

Everything is a jnp array, so a stack of ``CityParams`` (leading layout axis,
``repro.utils.stack_pytrees``) vmaps cleanly — the station-placement outer
loop (:func:`repro.city.sweep_layouts`) scores candidate layouts as one
compiled sweep.  Static structure (number of stations / zones) lives in the
array shapes.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.utils import pytree_dataclass, steps_per_day


@pytree_dataclass
class CityParams:
    """Population-scale demand routed across a fleet of stations.

    Shapes: ``S`` stations, ``Z`` demand zones, ``spd`` steps per day.
    """

    station_xy: jnp.ndarray  # (S, 2) station coordinates [km]
    zone_xy: jnp.ndarray  # (Z, 2) demand-centroid coordinates [km]
    zone_pop_frac: jnp.ndarray  # (Z,) share of the population per zone (sums to 1)
    population: jnp.ndarray  # () expected charging sessions per day, city-wide
    arrival_profile: jnp.ndarray  # (spd,) fraction of daily arrivals per step
    #     (sums to 1 — the inhomogeneous-Poisson intensity shape)
    day_scale: jnp.ndarray  # (365,) seasonal/weekend modulation (mean ~1)
    # --- choice-model (gravity/queue) logit weights ---
    w_dist: jnp.ndarray  # () per km of zone->station distance
    w_price: jnp.ndarray  # () per EUR/kWh of the station's current buy price
    w_queue: jnp.ndarray  # () per unit of station occupancy fraction

    @property
    def n_stations(self) -> int:
        return self.station_xy.shape[-2]

    @property
    def n_zones(self) -> int:
        return self.zone_xy.shape[-2]


# ---------------------------------------------------------------------------
# Station-layout generators (numpy, seeded — deterministic in their inputs)
# ---------------------------------------------------------------------------
def layout_xy(
    kind: str, n_stations: int, radius_km: float = 5.0, seed: int = 11
) -> np.ndarray:
    """Candidate station placements, shape ``(n_stations, 2)`` in km.

    ``ring``: evenly spaced on a circle of ``radius_km``; ``grid``: the
    tightest square grid covering ``n_stations``, spanning the diameter;
    ``clustered``: seeded Gaussian scatter pulled toward the centre (dense
    urban core, sparse edge).
    """
    if n_stations < 1:
        raise ValueError(f"need at least one station, got {n_stations}")
    if kind == "ring":
        ang = 2.0 * np.pi * np.arange(n_stations) / n_stations
        xy = radius_km * np.stack([np.cos(ang), np.sin(ang)], axis=1)
    elif kind == "grid":
        side = int(np.ceil(np.sqrt(n_stations)))
        ticks = (
            np.linspace(-radius_km, radius_km, side)
            if side > 1
            else np.zeros(1)
        )
        gx, gy = np.meshgrid(ticks, ticks)
        xy = np.stack([gx.ravel(), gy.ravel()], axis=1)[:n_stations]
    elif kind == "clustered":
        rng = np.random.default_rng(seed)
        xy = rng.normal(0.0, radius_km / 2.5, (n_stations, 2))
        xy *= 0.5 + 0.5 * np.linspace(0.2, 1.0, n_stations)[:, None]
    else:
        raise ValueError(f"unknown city layout {kind!r}")
    return xy.astype(np.float32)


def demand_zones(
    n_zones: int, radius_km: float = 5.0, seed: int = 11
) -> tuple[np.ndarray, np.ndarray]:
    """Gravity-model demand centroids ``(Z, 2)`` + population shares ``(Z,)``.

    Zone 0 is the city core (heaviest); the rest ring it at 60% of the
    radius with seeded angular jitter, sharing the remaining population with
    a mild decay.
    """
    if n_zones < 1:
        raise ValueError(f"need at least one zone, got {n_zones}")
    rng = np.random.default_rng(seed)
    xy = np.zeros((n_zones, 2), dtype=np.float32)
    if n_zones > 1:
        ang = 2.0 * np.pi * (
            np.arange(n_zones - 1) / (n_zones - 1)
            + 0.1 * rng.standard_normal(n_zones - 1)
        )
        xy[1:] = 0.6 * radius_km * np.stack([np.cos(ang), np.sin(ang)], axis=1)
    frac = 0.7 ** np.arange(n_zones)
    frac = frac / frac.sum()
    return xy, frac.astype(np.float32)


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------
def make_city(
    scenario=None,
    n_stations: int = 4,
    dt_minutes: float = 5.0,
    *,
    population: float | None = None,
    layout: str | np.ndarray | None = None,
    radius_km: float | None = None,
    n_zones: int | None = None,
    w_dist: float | None = None,
    w_price: float | None = None,
    w_queue: float | None = None,
    seed: int | None = None,
) -> CityParams:
    """Build :class:`CityParams` from a scenario's ``city_*`` axis (or kwargs).

    ``scenario`` is a :class:`repro.scenarios.Scenario` (or registry name)
    whose city axis supplies the defaults; every keyword overrides its field.
    The arrival-profile *shape* reuses the scenario's bundled day curve
    (:func:`repro.core.datasets.arrival_rate_curve`, normalised to a per-step
    fraction) and the seasonal/weekend ``day_scale`` process — the same
    inhomogeneous-Poisson machinery stations use, lifted to the population.

    ``layout`` may also be an explicit ``(n_stations, 2)`` coordinate array
    (candidate placements for :func:`repro.city.sweep_layouts`).
    """
    from repro.core import datasets
    from repro.scenarios import processes

    if isinstance(scenario, str):
        from repro import scenarios as _scen

        scenario = _scen.make(scenario)

    def field(override, name, default):
        if override is not None:
            return override
        if scenario is not None:
            return getattr(scenario, name)
        return default

    population = field(population, "city_population", 1000.0)
    layout = field(layout, "city_layout", "ring")
    radius_km = field(radius_km, "city_radius_km", 5.0)
    n_zones = field(n_zones, "city_zones", 3)
    w_dist = field(w_dist, "city_w_dist", 0.35)
    w_price = field(w_price, "city_w_price", 4.0)
    w_queue = field(w_queue, "city_w_queue", 2.0)
    seed = field(seed, "city_seed", 11)

    profile = scenario.profile if scenario is not None else "shopping"
    traffic = scenario.traffic if scenario is not None else "medium"
    curve = np.asarray(
        datasets.arrival_rate_curve(profile, traffic, dt_minutes), np.float64
    )
    arrival_profile = (curve / curve.sum()).astype(np.float32)
    if scenario is not None:
        day_scale = processes.seasonal_arrival_scale(
            scenario.season, scenario.season_amplitude, scenario.weekend_factor
        )
    else:
        day_scale = processes.seasonal_arrival_scale()

    if isinstance(layout, str):
        xy = layout_xy(layout, n_stations, radius_km, seed)
    else:
        xy = np.asarray(layout, np.float32)
        if xy.shape != (n_stations, 2):
            raise ValueError(
                f"explicit layout must have shape ({n_stations}, 2), "
                f"got {xy.shape}"
            )
    zone_xy, zone_frac = demand_zones(n_zones, radius_km, seed)

    spd = steps_per_day(dt_minutes)
    assert arrival_profile.shape == (spd,)
    return CityParams(
        station_xy=jnp.asarray(xy),
        zone_xy=jnp.asarray(zone_xy),
        zone_pop_frac=jnp.asarray(zone_frac),
        population=jnp.float32(population),
        arrival_profile=jnp.asarray(arrival_profile),
        day_scale=jnp.asarray(day_scale),
        w_dist=jnp.float32(w_dist),
        w_price=jnp.float32(w_price),
        w_queue=jnp.float32(w_queue),
    )
