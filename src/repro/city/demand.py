"""Demand allocation: split one population-scale arrival stream across stations.

The city generates one inhomogeneous-Poisson arrival intensity
(:func:`stream_rate`, built from the same day-profile/seasonality processes
stations use) and :func:`allocate_demand` routes it across the fleet with a
gravity/queue choice model — pure array ops (distance/price/occupancy logits
-> per-zone softmax routing, with a capacity-aware rejection/overflow term),
so the split is jit/vmap/grad-friendly and rides inside the fleet's compiled
step.

Conservation holds by construction::

    sum(rates) + overflow == stream_rate        (to float tolerance)

and a zero population yields *exactly* zero extra rates, which keeps a
city-coupled :class:`repro.core.FleetEnv` bit-identical to an uncoupled one
(tested in ``tests/city/``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.city.params import CityParams
from repro.core.state import EnvParams, EnvState


class StationFeatures(NamedTuple):
    """Per-station choice-model inputs, each shaped ``(S,)``."""

    price: jnp.ndarray  # current buy price [EUR/kWh]
    occupancy: jnp.ndarray  # occupied fraction of real ports, in [0, 1]
    free_ports: jnp.ndarray  # free real ports — per-step acceptance capacity


class DemandAllocation(NamedTuple):
    rates: jnp.ndarray  # (S,) expected extra arrivals per station this step
    overflow: jnp.ndarray  # () expected drivers balking city-wide (no capacity)
    shares: jnp.ndarray  # (S,) pre-capacity choice probabilities (sum to 1)


def stream_rate(city: CityParams, day: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Expected city-wide arrivals this step (inhomogeneous Poisson intensity).

    ``population`` [sessions/day] x the day-profile fraction for step ``t``
    x the seasonal/weekend scale for ``day`` — the station-level arrival
    machinery lifted to the population.
    """
    spd = city.arrival_profile.shape[-1]
    n_days = city.day_scale.shape[-1]
    return (
        city.population
        * city.arrival_profile[..., jnp.mod(t, spd)]
        * city.day_scale[..., jnp.mod(day, n_days)]
    )


def choice_logits(city: CityParams, features: StationFeatures) -> jnp.ndarray:
    """Gravity/queue logits, shape ``(Z, S)``: zone-to-station attractiveness.

    Drivers dislike distance (per km, zone-specific), price (per EUR/kWh) and
    queues (per unit occupancy fraction); the negated weighted sum is the
    softmax logit.
    """
    d = jnp.linalg.norm(
        city.station_xy[None, :, :] - city.zone_xy[:, None, :], axis=-1
    )  # (Z, S) km
    return (
        -city.w_dist * d
        - city.w_price * features.price[None, :]
        - city.w_queue * features.occupancy[None, :]
    )


def allocate_demand(
    stream: jnp.ndarray,
    city: CityParams,
    features: StationFeatures,
) -> DemandAllocation:
    """Split ``stream`` (expected arrivals this step) across the stations.

    Routing: per-zone softmax over :func:`choice_logits`, population-weighted
    over zones.  Capacity awareness: a station can absorb at most its free
    real ports per step; the first spill is re-routed once to stations with
    remaining headroom (drivers trying their second choice), the residue is
    ``overflow`` — drivers balking city-wide.  Everything is a smooth-ish
    array op (softmax + clamps), so the split differentiates through to the
    choice weights and station coordinates.
    """
    shares_z = jax.nn.softmax(choice_logits(city, features), axis=-1)  # (Z, S)
    shares = jnp.sum(city.zone_pop_frac[:, None] * shares_z, axis=0)  # (S,)
    raw = stream * shares

    cap = jnp.maximum(features.free_ports, 0.0)
    served = jnp.minimum(raw, cap)
    headroom = cap - served
    spill = jnp.sum(raw - served)
    # second-choice round: spilled drivers spread over remaining headroom
    take = jnp.minimum(spill, jnp.sum(headroom))
    extra = take * headroom / jnp.maximum(jnp.sum(headroom), 1e-9)
    rates = served + extra
    overflow = stream - jnp.sum(rates)
    return DemandAllocation(rates, jnp.maximum(overflow, 0.0), shares)


# ---------------------------------------------------------------------------
# Fleet-state adapters (stacked (S, ...) pytrees -> StationFeatures -> rates)
# ---------------------------------------------------------------------------
def station_features(params: EnvParams, state: EnvState) -> StationFeatures:
    """Read the choice-model features out of a stacked fleet state.

    ``params``/``state`` carry a leading station axis ``S`` (the
    :class:`repro.core.FleetEnv` layout); padded lanes are masked out of both
    occupancy and capacity.
    """
    mask = params.evse_mask  # (S, N)
    n_real = jnp.maximum(jnp.sum(mask, axis=-1), 1.0)
    occupied = jnp.sum(state.occupied * mask, axis=-1)
    spd = state.price_buy.shape[-1]
    price = jax.vmap(lambda row, t: row[jnp.mod(t, spd)])(
        state.price_buy, state.t
    )
    return StationFeatures(
        price=price,
        occupancy=occupied / n_real,
        free_ports=jnp.sum((1.0 - state.occupied) * mask, axis=-1),
    )


def city_rates(
    city: CityParams, params: EnvParams, state: EnvState
) -> tuple[DemandAllocation, jnp.ndarray]:
    """Per-station extra arrival rates for one fleet step.

    Returns ``(allocation, stream)`` — the allocation's ``rates`` feed the
    per-station ``arrival_rate_extra`` seam of
    :meth:`repro.core.ChargaxEnv.finish_step`.  The episode clock is shared
    fleet-wide (station 0's ``day``/``t``, the grid-coupling convention).
    """
    stream = stream_rate(city, state.day[0], state.t[0])
    alloc = allocate_demand(stream, city, station_features(params, state))
    return alloc, stream
