"""Station-placement outer loop: score candidate city layouts as one vmap.

The ROADMAP's placement direction (station placement via RL + agent-based
simulation) falls out of the city demand-allocation layer: a layout is just a
:class:`~repro.city.params.CityParams` pytree, so a *stack* of candidate
layouts (leading axis ``K``, :func:`repro.utils.stack_pytrees`) rolls the
same city-coupled fleet out under ``jax.vmap`` — one compiled program scores
every candidate under the same trained (or baseline) policy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import stack_pytrees


def sweep_layouts(
    fleet,
    cities,
    policy,
    policy_params=None,
    key: jax.Array | None = None,
    steps: int | None = None,
) -> dict:
    """Roll each candidate city out against ``fleet`` and score it.

    Args:
        fleet: a city-coupled :class:`repro.core.FleetEnv` (its own ``city``
            is ignored — each candidate is passed through the
            ``step_with_city`` seam as a traced argument).
        cities: stacked ``CityParams`` with a leading layout axis ``K``
            (``stack_pytrees([make_city(...), ...])``), or a list/tuple of
            ``CityParams`` which is stacked here.
        policy: ``(params, key, obs) -> action`` — trained PPO policy or a
            baseline.
        steps: rollout length (default: one episode).

    Returns a dict of ``(K,)`` arrays: ``profit`` (fleet-total EUR, the
    placement score), ``cars_served``, ``overflow`` (expected balked
    drivers), plus the winning index ``best``.
    """
    if isinstance(cities, (list, tuple)):
        cities = stack_pytrees(cities)
    key = key if key is not None else jax.random.key(0)
    steps = steps if steps is not None else fleet.config.episode_steps
    params = fleet.default_params

    def rollout(city, key):
        obs, state = fleet.reset(key, params)

        def body(carry, _):
            key, state, obs, overflow = carry
            key, k_act, k_step = jax.random.split(key, 3)
            action = policy(policy_params, k_act, obs)
            obs, state, _, _, info = fleet.step_with_city(
                k_step, state, action, params, city
            )
            return (key, state, obs, overflow + info["city/overflow"][0]), None

        (_, state, _, overflow), _ = jax.lax.scan(
            body, (key, state, obs, jnp.float32(0.0)), None, steps
        )
        return {
            "profit": jnp.sum(state.profit_cum),
            "cars_served": jnp.sum(state.cars_served),
            "overflow": overflow,
        }

    out = jax.jit(jax.vmap(rollout, in_axes=(0, None)))(cities, key)
    out["best"] = jnp.argmax(out["profit"])
    return out
