"""Compiled-artifact analysis: HLO collective parsing + roofline model."""
from repro.analysis.hlo import collective_stats
from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, analyze_cell

__all__ = ["collective_stats", "analyze_cell", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]
