"""Three-term roofline analysis from AOT-compiled artifacts (assignment §Roofline).

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

XLA counts ``while`` bodies ONCE in ``cost_analysis()`` (verified in
tests/launch), so scanned-layer cells under-report.  We therefore derive the
roofline terms from **unrolled probe compiles on the production mesh**:
reduced-layer-count configs with full layer dimensions, ``unroll=True`` (no
while loops -> exact per-device FLOPs/bytes/collective counts), solved
linearly for (fixed, per-layer[, per-shared-block]) marginals and
extrapolated to the full depth.  num_microbatches=1 in probes; train totals
scale by the cell's microbatch count (identical per-microbatch work).

Outputs per (arch × shape × mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS = 6·N_active·D, and the useful-compute ratio.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_stats, cost_analysis_dict, materialized_bytes
from repro.configs.registry import build_model, get_config
from repro.models.config import SHAPES, ModelConfig, ShapeConfig

# ---------------------------------------------------------------------------
# hardware constants (TPU v5e)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link


# ---------------------------------------------------------------------------
# probe configs per family: (cfg_variant, coefficient row); unknowns x solve
# A x = b per metric, full total = c . x
# ---------------------------------------------------------------------------
def probe_plan(cfg: ModelConfig) -> tuple[list[tuple[ModelConfig, list[float]]], list[float]]:
    r = dataclasses.replace
    if cfg.family == "encdec":
        probes = [
            (r(cfg, n_layers=1, n_enc_layers=1), [1, 1]),
            (r(cfg, n_layers=2, n_enc_layers=2), [1, 2]),
        ]
        full = [1, cfg.n_layers]
    elif cfg.alt_local_global:
        probes = [(r(cfg, n_layers=2), [1, 1]), (r(cfg, n_layers=4), [1, 2])]
        full = [1, cfg.n_layers // 2]
    elif cfg.family == "hybrid":
        probes = [
            (r(cfg, n_layers=1, shared_attn_every=1), [1, 1, 1]),
            (r(cfg, n_layers=2, shared_attn_every=1), [1, 2, 2]),
            (r(cfg, n_layers=2, shared_attn_every=2), [1, 2, 1]),
        ]
        k = cfg.shared_attn_every
        n_groups = (cfg.n_layers + k - 1) // k
        full = [1, cfg.n_layers, n_groups]
    else:
        probes = [(r(cfg, n_layers=1), [1, 1]), (r(cfg, n_layers=2), [1, 2])]
        full = [1, cfg.n_layers]
    return probes, full


def _compile_probe(cfg: ModelConfig, shape: ShapeConfig, mesh, microbatches: int, remat: bool = True) -> dict:
    """Compile one probe (unrolled, mb=1, microbatch-sized batch) -> metrics."""
    from repro.distributed import sharding as shd
    from repro.distributed.train_step import (
        TrainState,
        TrainStepConfig,
        make_prefill_step,
        make_serve_step,
        make_train_step,
    )
    from repro.launch import dryrun
    from repro.optim import AdamWState

    model = build_model(cfg)
    rep = NamedSharding(mesh, P())
    ctx = shd.set_mesh(mesh)
    ctx.__enter__()
    key = jax.random.key(0)
    params_abs = jax.eval_shape(model.init, key)
    params_sh = shd.param_shardings(params_abs, mesh)

    if shape.kind == "train":
        micro_shape = dataclasses.replace(
            shape, global_batch=max(shape.global_batch // microbatches, 1)
        )
        batch = dryrun.model_inputs(cfg, micro_shape, mesh)
        ts_cfg = TrainStepConfig(num_microbatches=1, unroll_layers=True, remat=remat)
        step = make_train_step(model, ts_cfg)
        opt_abs = jax.eval_shape(
            lambda p: AdamWState(
                step=jnp.int32(0),
                mu=jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
                nu=jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
            ),
            params_abs,
        )
        state_abs = TrainState(params=params_abs, opt=opt_abs, error_feedback={})
        state_sh = TrainState(
            params=params_sh,
            opt=AdamWState(step=rep, mu=params_sh, nu=params_sh),
            error_feedback={},
        )
        batch_sh = jax.tree_util.tree_map(lambda s: s.sharding, batch)
        compiled = (
            jax.jit(step, in_shardings=(state_sh, batch_sh), donate_argnums=(0,))
            .lower(state_abs, batch)
            .compile()
        )
    elif shape.kind == "prefill":
        batch = dryrun.model_inputs(cfg, shape, mesh)
        step = make_prefill_step(model, unroll=True)
        compiled = (
            jax.jit(
                step,
                in_shardings=(params_sh, jax.tree_util.tree_map(lambda s: s.sharding, batch)),
            )
            .lower(params_abs, batch)
            .compile()
        )
    else:
        from repro.distributed.sharding import batch_spec, cache_shardings

        b, l = shape.global_batch, shape.seq_len
        step = make_serve_step(model, unroll=True)
        if cfg.family == "encdec":
            enc_abs = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), jnp.float32)
            cache_abs = jax.eval_shape(
                lambda p, e: model.init_cache(p, b, l, e), params_abs, enc_abs
            )
        else:
            cache_abs = jax.eval_shape(lambda: model.init_cache(b, l))
        cache_sh = cache_shardings(cache_abs, mesh, b)
        tok_sh = NamedSharding(mesh, P(*batch_spec(mesh, b), None))
        compiled = (
            jax.jit(
                step,
                in_shardings=(params_sh, cache_sh, tok_sh, rep),
                donate_argnums=(1,),
            )
            .lower(
                params_abs,
                cache_abs,
                jax.ShapeDtypeStruct((b, 1), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32),
            )
            .compile()
        )

    ctx.__exit__(None, None, None)
    cost = cost_analysis_dict(compiled)
    text = compiled.as_text()
    coll = collective_stats(text)
    mem = compiled.memory_analysis()
    args_bytes = float(getattr(mem, "argument_size_in_bytes", 0) or 0)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        # fusion model: materialization points + one read of the program args
        "bytes_fused": float(materialized_bytes(text)) + args_bytes,
        "collective_bytes": float(coll["total_bytes"]),
        "collective_count": int(coll["total_count"]),
    }


def model_params_active(cfg: ModelConfig) -> tuple[float, float]:
    """(total_params, active_params) from abstract shapes; MoE active =
    non-expert + expert * top_k / E."""
    model = build_model(cfg)
    params_abs = jax.eval_shape(model.init, jax.random.key(0))
    total = active = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_abs)[0]:
        n = float(np.prod(leaf.shape))
        key = jax.tree_util.keystr(path)
        total += n
        if "expert_w" in key:
            active += n * cfg.top_k / max(cfg.n_experts, 1)
        elif "embed" in key:
            pass  # 6ND convention excludes embedding lookup
        else:
            active += n
    return total, active


def analyze_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    microbatches: int | None = None,
    remat: bool = True,
    cfg_overrides: dict | None = None,
    strategy: str = "2d",
) -> dict:
    """Full §Roofline record for one cell (probe compiles + extrapolation)."""
    from repro.launch.dryrun import default_microbatches
    from repro.launch.mesh import make_production_mesh

    from repro.distributed import sharding as shd

    shd.set_strategy(strategy)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mb = microbatches or default_microbatches(cfg, shape, n_dev)

    probes, full_coeff = probe_plan(cfg)
    rows, results = [], []
    for pcfg, coeff in probes:
        rows.append(coeff)
        results.append(_compile_probe(pcfg, shape, mesh, mb, remat=remat))

    a = np.array(rows, dtype=np.float64)
    record: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_microbatches": mb,
        "strategy": strategy,
    }
    scale = mb if shape.kind == "train" else 1
    totals = {}
    for metric in ("flops", "bytes", "bytes_fused", "collective_bytes", "collective_count"):
        b_vec = np.array([r[metric] for r in results])
        x, *_ = np.linalg.lstsq(a, b_vec, rcond=None)
        est = float(np.dot(full_coeff, x))
        if est <= 0 or (x < -1e-6 * max(abs(b_vec).max(), 1)).any():
            # degenerate marginals (decode cells where per-layer deltas are
            # below compile noise): proportional fallback from the largest probe
            i = int(np.argmax(a.sum(axis=1)))
            est = float(b_vec[i]) * (sum(full_coeff) / a[i].sum())
        totals[metric] = est * scale
    record.update({f"per_device_{k}": v for k, v in totals.items()})

    # --- the three roofline terms (seconds, per step) -----------------------
    # memory term uses the TPU-fusion materialisation model; the raw XLA:CPU
    # "bytes accessed" (no fusion — every elementwise operand) is reported
    # alongside as the hard upper bound (EXPERIMENTS.md §Roofline caveat)
    t_compute = totals["flops"] / PEAK_FLOPS
    t_memory = totals["bytes_fused"] / HBM_BW
    t_collective = totals["collective_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    record["t_compute_s"] = t_compute
    record["t_memory_s"] = t_memory
    record["t_memory_raw_s"] = totals["bytes"] / HBM_BW
    record["t_collective_s"] = t_collective
    record["bottleneck"] = max(terms, key=terms.get)
    bound = max(terms.values())
    record["roofline_step_s"] = bound
    record["roofline_fraction_compute"] = t_compute / bound if bound > 0 else 0.0

    # --- model flops & useful-compute ratio ---------------------------------
    total_p, active_p = model_params_active(cfg)
    record["params_total"] = total_p
    record["params_active"] = active_p
    if shape.kind == "train":
        model_flops = 6.0 * active_p * shape.tokens
    elif shape.kind == "prefill":
        model_flops = 2.0 * active_p * shape.tokens
    else:
        model_flops = 2.0 * active_p * shape.global_batch  # one token / seq
    record["model_flops"] = model_flops
    hlo_global = totals["flops"] * n_dev
    record["hlo_flops_global"] = hlo_global
    record["useful_compute_ratio"] = model_flops / hlo_global if hlo_global else 0.0
    # fraction of the roofline spent on USEFUL model flops — the honest score
    # (immune to replicated/wasted compute inflating t_compute)
    t_useful = model_flops / n_dev / PEAK_FLOPS
    record["t_useful_compute_s"] = t_useful
    record["useful_fraction"] = t_useful / bound if bound > 0 else 0.0
    shd.set_strategy("2d")
    return record


def main():
    import argparse
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args()

    from repro.configs.registry import ARCH_IDS, applicable_shapes

    cells = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    for arch in archs:
        shapes = (
            [s.name for s in applicable_shapes(arch)]
            if (args.all or args.shape is None)
            else [args.shape]
        )
        cells.extend((arch, s) for s in shapes)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"]) for r in results if "bottleneck" in r}

    for arch, shape in cells:
        if (arch, shape) in done:
            print(f"[skip] {arch} {shape}")
            continue
        print(f"[roofline] {arch} {shape} ...", flush=True)
        try:
            rec = analyze_cell(arch, shape)
            print(
                f"   {rec['bottleneck']}-bound: compute {rec['t_compute_s']:.3f}s "
                f"memory {rec['t_memory_s']:.3f}s collective {rec['t_collective_s']:.3f}s "
                f"useful {rec['useful_compute_ratio']:.2f}",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001
            import traceback

            rec = {
                "arch": arch,
                "shape": shape,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-1500:],
            }
            print(f"   FAIL {rec['error'][:150]}", flush=True)
        results = [r for r in results if not (r["arch"] == arch and r["shape"] == shape)]
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
