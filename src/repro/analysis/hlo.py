"""Optimized-HLO parsing: collective op inventory and byte counts.

``cost_analysis()`` does not report collective traffic, so §Roofline's third
term comes from summing operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute in ``compiled.as_text()``.
"""
from __future__ import annotations

import re


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every JAX version.

    Newer JAX returns a dict; 0.4.x returns a one-element list of dicts (one
    per partitioned program); either may be empty/None.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %foo = bf16[16,128,4096]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\s("
    + "|".join(COLLECTIVE_OPS)
    + r")(?:-start|-done)?\("
)
# tuple-shaped outputs: = (bf16[...], bf16[...]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*("
    + "|".join(COLLECTIVE_OPS)
    + r")(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Returns {op_kind: {count, bytes}} + total, parsed from optimized HLO.

    Bytes are the *output* operand sizes (the data a chip must move), summed
    over instructions; -start/-done pairs are deduplicated by only counting
    -start (or the plain op).
    """
    stats: dict = {k: {"count": 0, "bytes": 0} for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # counted at -start
        m = _TUPLE_RE.search(line)  # tuple outputs first (subsumes scalar re)
        if m:
            inner, kind = m.groups()
            total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(inner))
            stats[kind]["count"] += 1
            stats[kind]["bytes"] += total
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            stats[kind]["count"] += 1
            stats[kind]["bytes"] += _shape_bytes(dtype, dims)
    stats["total_bytes"] = int(sum(v["bytes"] for k, v in stats.items() if isinstance(v, dict)))
    stats["total_count"] = int(sum(v["count"] for k, v in stats.items() if isinstance(v, dict)))
    return stats


# ---------------------------------------------------------------------------
# TPU-fusion memory model: HBM traffic ≈ bytes of buffers that MUST
# materialise.  XLA:CPU's "bytes accessed" counts every elementwise operand
# (no fusion), wildly over-stating HBM traffic; on TPU, elementwise chains
# fuse into their producers/consumers.  We approximate materialisation points
# as the outputs of non-fusible ops (dots/convs/reduces/scatter-gather/
# collectives/sorts) plus parameter reads — a standard fusion model.
# ---------------------------------------------------------------------------
# NOTE: "parameter" is deliberately absent — HLO fusion computations re-list
# their operands as parameter lines, which double-counts massively; program
# argument bytes are added once by the caller from memory_analysis().
_MATERIALIZE_OPS = (
    "dot", "convolution", "reduce", "reduce-window", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "sort", "rng",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_MAT_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\s("
    + "|".join(_MATERIALIZE_OPS)
    + r")(?:-start|-done)?\("
)


def materialized_bytes(hlo_text: str) -> int:
    """Fusion-model HBM traffic estimate (see block comment)."""
    total = 0
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _MAT_RE.search(line)
        if m:
            dtype, dims, _ = m.groups()
            total += _shape_bytes(dtype, dims)
    return total
