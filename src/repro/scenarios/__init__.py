"""Scenario subsystem: declarative worlds for Chargax stations.

    from repro import scenarios
    sc = scenarios.make("shopping_pv_tou")      # by name, from the catalog
    params = sc.make_params(env)                # pure array swap, no recompile
    fleet = FleetEnv(["paper_16", "deep_4x4"], scenarios=["shopping_flat",
                                                          "work_solar_summer"])

Every scenario lowers to identically-shaped ``EnvParams`` arrays, so one
jitted ``env.step`` serves the whole catalog (and any user scenario).
"""
from repro.utils import stack_pytrees as stack_params
from repro.scenarios.registry import (
    CATALOG,
    CITY_PACK,
    GRID_PACK,
    REAL_PACK,
    V2G_MIXED_PACK,
    V2G_PACK,
    make,
    names,
    register,
)
from repro.scenarios.scenario import MAX_CAR_MODELS, Scenario
from repro.scenarios import processes

__all__ = [
    "CATALOG",
    "CITY_PACK",
    "GRID_PACK",
    "MAX_CAR_MODELS",
    "REAL_PACK",
    "Scenario",
    "V2G_MIXED_PACK",
    "V2G_PACK",
    "make",
    "names",
    "processes",
    "register",
    "stack_params",
]
