"""Exogenous process generators for the scenario subsystem.

Each function returns a plain numpy table shaped to slot into an existing
:class:`~repro.core.state.EnvParams` field, so composing a scenario is a pure
array swap — same shapes, same jit cache entry, no recompilation.  All series
are deterministic in their inputs (seeded generators), mirroring the bundled
datasets in :mod:`repro.core.datasets`.  The real-data loaders in
:mod:`repro.data.ingest` emit identically shaped tables, so every generator
here is swappable for a measured series.

Doctest-checked (CI runs ``--doctest-modules`` on this file):

    >>> pv_table(0.0, dt_minutes=60.0).shape       # dark plant, hourly grid
    (365, 24)
    >>> import numpy as np
    >>> flat = np.full((365, 24), 0.10, np.float32)
    >>> tou = tou_overlay(flat, dt_minutes=60.0)
    >>> float(tou[0, 19]) > 0.10 > float(tou[0, 3])  # evening peak, night dip
    True
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core.datasets import DAYS_PER_YEAR
from repro.utils import steps_per_day


# ---------------------------------------------------------------------------
# Solar PV generation, shape (365, steps_per_day), kW
# ---------------------------------------------------------------------------
def pv_table(
    peak_kw: float,
    dt_minutes: float = 5.0,
    cloud_noise: float = 0.15,
    seed: int = 23,
) -> np.ndarray:
    """On-site PV generation in kW for every (day, step) of a year.

    Physics-lite clear-sky model: day length follows the seasonal declination
    cycle (solstices at days 172/355 for a mid-European latitude), intra-day
    output is the half-sine of solar elevation between sunrise and sunset,
    and an AR(1) daily cloudiness factor adds weather persistence.

        >>> pv = pv_table(150.0, dt_minutes=60.0)
        >>> float(pv[:, 0].max())              # never any sun at midnight
        0.0
        >>> bool(pv[172, 12] > pv[355, 12])    # summer noon beats winter noon
        True

    Results are cached; arguments are normalised to builtin ``float``/``int``
    first so ``np.float32(150)`` and ``150.0`` callers share one entry.
    """
    return _pv_table_cached(
        float(peak_kw), float(dt_minutes), float(cloud_noise), int(seed)
    )


@functools.lru_cache(maxsize=None)
def _pv_table_cached(
    peak_kw: float, dt_minutes: float, cloud_noise: float, seed: int
) -> np.ndarray:
    spd = steps_per_day(dt_minutes)
    if peak_kw <= 0.0:
        return np.zeros((DAYS_PER_YEAR, spd), dtype=np.float32)

    day = np.arange(DAYS_PER_YEAR)
    season = np.cos(2.0 * np.pi * (day - 172) / DAYS_PER_YEAR)  # +1 mid-summer
    daylight = 12.0 + 4.0 * season  # hours of sun
    sunrise = 12.0 - daylight / 2.0
    # clear-sky peak output scales with solar elevation through the year
    peak_factor = 0.55 + 0.45 * (season + 1.0) / 2.0

    h = np.arange(spd) * (24.0 / spd)
    frac = (h[None, :] - sunrise[:, None]) / daylight[:, None]
    irr = np.sin(np.pi * np.clip(frac, 0.0, 1.0))

    # AR(1) cloudiness c_d = 0.7 c_{d-1} + 0.3 x_d, closed form via cumprod:
    # c_d = phi^d c_0 + 0.3 phi^d * sum_k x_k phi^-k (decay stays >= 0.7^365
    # ~ 1e-57, comfortably inside float64, and the rescaled sum is dominated
    # by its latest terms so precision survives the round trip)
    rng = np.random.default_rng(seed)
    x = 1.0 - cloud_noise * rng.gamma(1.2, 1.0, DAYS_PER_YEAR)
    decay = np.cumprod(np.full(DAYS_PER_YEAR, 0.7))
    cloud = np.clip(decay * (0.8 + 0.3 * np.cumsum(x / decay)), 0.15, 1.0)

    table = peak_kw * peak_factor[:, None] * cloud[:, None] * irr
    return np.maximum(table, 0.0).astype(np.float32)


# ---------------------------------------------------------------------------
# Time-of-use tariff overlay on a (365, steps_per_day) price table
# ---------------------------------------------------------------------------
def tou_overlay(
    prices: np.ndarray,
    dt_minutes: float = 5.0,
    peak_mult: float = 1.6,
    offpeak_mult: float = 0.8,
    peak_hours: tuple[float, float] = (17.0, 21.0),
    offpeak_hours: tuple[float, float] = (0.0, 6.0),
) -> np.ndarray:
    """Apply a time-of-use multiplier structure to a day-ahead price table.

    Retail ToU contracts scale the wholesale curve up inside the evening peak
    window and down in the overnight valley; the multipliers ramp linearly
    over 30 minutes at window edges so the tariff stays scheduler-friendly.
    """
    spd = prices.shape[1]
    h = np.arange(spd) * (24.0 / spd)
    mult = np.ones(spd)

    def window(lo: float, hi: float) -> np.ndarray:
        ramp = 0.5  # hours
        up = np.clip((h - lo) / ramp, 0.0, 1.0)
        down = np.clip((hi - h) / ramp, 0.0, 1.0)
        return np.minimum(up, down)

    mult += (peak_mult - 1.0) * window(*peak_hours)
    mult += (offpeak_mult - 1.0) * window(*offpeak_hours)
    return (prices * mult[None, :]).astype(np.float32)


# ---------------------------------------------------------------------------
# Seasonal / weekend arrival modulation, shape (365,)
# ---------------------------------------------------------------------------
def seasonal_arrival_scale(
    season: str = "none",
    amplitude: float = 0.25,
    weekend_factor: float = 1.0,
) -> np.ndarray:
    """Per-day multiplier on the arrival-rate curve (mean ~1 over the year).

    ``season``: 'none' (flat), 'summer_peak' (holiday traffic, max at the
    July solstice) or 'winter_peak' (commuter/heating season, max in January).
    ``weekend_factor`` multiplies Saturdays/Sundays on top (shopping sites
    surge on weekends, workplaces go quiet).
    """
    day = np.arange(DAYS_PER_YEAR)
    if season == "none":
        scale = np.ones(DAYS_PER_YEAR)
    elif season == "summer_peak":
        scale = 1.0 + amplitude * np.cos(2.0 * np.pi * (day - 182) / DAYS_PER_YEAR)
    elif season == "winter_peak":
        scale = 1.0 + amplitude * np.cos(2.0 * np.pi * (day - 15) / DAYS_PER_YEAR)
    else:
        raise ValueError(f"unknown season kind {season!r}")
    weekend = np.isin(day % 7, [5, 6])
    scale = scale * np.where(weekend, weekend_factor, 1.0)
    return np.maximum(scale, 0.0).astype(np.float32)


# ---------------------------------------------------------------------------
# Grid feeder power envelope, shape (365, steps_per_day), kW
# ---------------------------------------------------------------------------
def grid_cap_table(
    cap_kw: float,
    dt_minutes: float = 5.0,
    profile: str = "flat",
    dr_events_per_day: float = 0.0,
    dr_depth: float = 0.5,
    dr_hours: float = 2.0,
    seed: int = 7,
) -> np.ndarray:
    """Feeder/transformer power cap in kW for every (day, step) of a year.

    ``profile``: 'flat' (constant ``cap_kw``) or 'evening_droop' (the cap
    drops ~40% during the 17-21h residential peak, with the same 0.5h ramps
    as the ToU overlay — the DSO reserves headroom for household load).

    Demand-response events: per day, ``Poisson(dr_events_per_day)`` events
    start at uniform steps and multiply the cap by ``dr_depth`` for
    ``dr_hours`` (wrapping past midnight within the day's row).  Seeded —
    the same arguments always yield the same table.

        >>> cap = grid_cap_table(400.0, dt_minutes=60.0)
        >>> cap.shape
        (365, 24)
        >>> float(cap.min()) == float(cap.max()) == 400.0   # flat, no events
        True
        >>> dr = grid_cap_table(400.0, 60.0, dr_events_per_day=2.0, dr_depth=0.5)
        >>> bool((dr < 400.0).any()) and bool(dr.min() > 0.0)  # events tighten
        True
        >>> droop = grid_cap_table(400.0, 60.0, profile="evening_droop")
        >>> bool(droop[0, 19] < droop[0, 3])   # evening cap below night cap
        True
    """
    spd = steps_per_day(dt_minutes)
    if cap_kw <= 0.0:
        raise ValueError(f"cap_kw must be > 0, got {cap_kw}")
    h = np.arange(spd) * (24.0 / spd)
    mult = np.ones(spd)
    if profile == "evening_droop":
        ramp = 0.5  # hours
        up = np.clip((h - 17.0) / ramp, 0.0, 1.0)
        down = np.clip((21.0 - h) / ramp, 0.0, 1.0)
        mult -= 0.4 * np.minimum(up, down)
    elif profile != "flat":
        raise ValueError(f"unknown grid cap profile {profile!r}")
    table = np.broadcast_to(cap_kw * mult[None, :], (DAYS_PER_YEAR, spd)).copy()

    if dr_events_per_day > 0.0:
        rng = np.random.default_rng(seed)
        dur = max(int(round(dr_hours * spd / 24.0)), 1)
        for day in range(DAYS_PER_YEAR):
            for _ in range(rng.poisson(dr_events_per_day)):
                start = int(rng.integers(0, spd))
                idx = (start + np.arange(dur)) % spd
                table[day, idx] *= dr_depth
    return table.astype(np.float32)


def grid_setpoint_table(
    peak_kw: float,
    dt_minutes: float = 5.0,
    window_hours: tuple[float, float] = (10.0, 16.0),
) -> np.ndarray:
    """DSO power-setpoint tracking target in kW, shape (365, steps_per_day).

    A half-sine bump peaking mid-window (default 10-16h: soak up midday
    solar), zero outside — the 'please draw this much' signal whose absolute
    tracking error the ``grid_setpoint`` reward weight penalises.

        >>> sp = grid_setpoint_table(400.0, dt_minutes=60.0)
        >>> sp.shape
        (365, 24)
        >>> float(sp[0, 13]) > 350.0 and float(sp[0, 3]) == 0.0
        True
    """
    spd = steps_per_day(dt_minutes)
    h = np.arange(spd) * (24.0 / spd)
    lo, hi = window_hours
    frac = np.clip((h - lo) / max(hi - lo, 1e-9), 0.0, 1.0)
    inside = (h >= lo) & (h < hi)
    bump = peak_kw * np.sin(np.pi * frac) * inside
    return np.broadcast_to(bump[None, :], (DAYS_PER_YEAR, spd)).astype(np.float32)


# ---------------------------------------------------------------------------
# Fleet-mix drift, shape (365, n_models)
# ---------------------------------------------------------------------------
def fleet_drift_table(
    probs_start: np.ndarray, probs_end: np.ndarray
) -> np.ndarray:
    """Linear drift between two model distributions over the year.

    Each row is re-normalised, so any start/end weighting is valid.
    """
    t = np.linspace(0.0, 1.0, DAYS_PER_YEAR)[:, None]
    table = (1.0 - t) * probs_start[None, :] + t * probs_end[None, :]
    table = table / table.sum(axis=1, keepdims=True)
    return table.astype(np.float32)


def big_battery_shift(probs: np.ndarray, capacity: np.ndarray, strength: float = 1.0) -> np.ndarray:
    """End-of-year distribution reweighted toward larger-capacity models.

    Models the observed market drift to bigger packs: weights are tilted by
    ``(capacity / mean_capacity) ** strength``.
    """
    mean_cap = float(np.sum(probs * capacity) / max(np.sum(probs), 1e-9))
    tilt = (np.maximum(capacity, 1e-6) / max(mean_cap, 1e-6)) ** strength
    end = probs * tilt
    s = end.sum()
    return (end / s if s > 0 else probs).astype(np.float32)
