"""Scenario registry + bundled catalog.

``make("name")`` resolves a scenario by string; ``register`` adds user
scenarios (e.g. from config files via ``Scenario.from_dict``).  The bundled
catalog spans the paper's dataset axes (profiles, regions, years, traffic)
crossed with the new exogenous processes (PV, ToU/demand tariffs, seasonal
modulation, fleet drift) — every entry lowers to the same parameter shapes,
so a jitted ``env.step`` runs the whole catalog with one compilation.
"""
from __future__ import annotations

from repro.scenarios.scenario import Scenario

_REGISTRY: dict[str, Scenario] = {}


def register(scenario: Scenario, overwrite: bool = False) -> Scenario:
    """Add a scenario to the registry (returned for chaining)."""
    if not overwrite and scenario.name in _REGISTRY:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def make(name: str) -> Scenario:
    """Look a scenario up by name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Bundled catalog
# ---------------------------------------------------------------------------
CATALOG = tuple(
    register(s)
    for s in [
        Scenario(
            name="shopping_flat",
            description="Baseline: shopping-centre station, flat NL 2021 tariff",
        ),
        Scenario(
            name="shopping_pv_tou",
            description="Shopping centre with rooftop PV and an evening-peak ToU tariff",
            pv_peak_kw=150.0,
            tariff="tou",
        ),
        Scenario(
            name="work_solar_summer",
            description="Workplace carport PV; summer holiday lull empties it on weekends",
            profile="work",
            pv_peak_kw=250.0,
            season="summer_peak",
            season_amplitude=0.2,
            weekend_factor=0.35,
        ),
        Scenario(
            name="highway_demand_charge",
            description="High-traffic highway plaza billed a demand charge above 400 kW",
            profile="highway",
            traffic="high",
            demand_charge_rate=0.4,
            demand_contract_kw=400.0,
        ),
        Scenario(
            name="residential_winter_crisis",
            description="Residential street chargers, DE 2022 crisis prices, winter peak",
            profile="residential",
            price_region="DE",
            price_year=2022,
            season="winter_peak",
            season_amplitude=0.3,
            weekend_factor=1.15,
        ),
        Scenario(
            name="shopping_fleet_drift",
            description="Shopping baseline with the EU mix drifting to bigger batteries",
            fleet_drift="big_battery_growth",
            fleet_drift_strength=1.5,
        ),
        Scenario(
            name="us_workplace_tou",
            description="US workplace: US car mix, carport PV, ToU with deep overnight valley",
            profile="work",
            car_region="US",
            pv_peak_kw=100.0,
            tariff="tou",
            tou_offpeak_mult=0.6,
            weekend_factor=0.3,
        ),
        Scenario(
            name="world_highway_2023",
            description="Global-mix highway site on FR 2023 post-crisis prices, summer surge",
            profile="highway",
            car_region="World",
            price_region="FR",
            price_year=2023,
            traffic="high",
            season="summer_peak",
            weekend_factor=1.25,
        ),
        # ----- V2G-heavy pack (EnvConfig(allow_v2g=True) makes these act) -----
        Scenario(
            name="v2g_shopping_tou",
            description="Shopping ToU arbitrage: cheap owner compensation, "
            "near-par grid sellback, every port bidirectional",
            tariff="tou",
            v2g_comp_price=0.12,
            grid_sell_discount=0.95,
        ),
        Scenario(
            name="v2g_residential_crisis",
            description="Residential V2G through DE 2022 crisis ToU peaks — "
            "the deepest discharge spreads in the catalog",
            profile="residential",
            price_region="DE",
            price_year=2022,
            tariff="tou",
            tou_peak_mult=1.8,
            season="winter_peak",
            v2g_comp_price=0.15,
            grid_sell_discount=0.95,
        ),
        Scenario(
            name="v2g_work_solar_split",
            description="Workplace carport PV with half the ports "
            "bidirectional: solar-charged packs sold into the evening peak",
            profile="work",
            pv_peak_kw=200.0,
            tariff="tou",
            tou_offpeak_mult=0.6,
            weekend_factor=0.35,
            v2g_comp_price=0.10,
            v2g_port_fraction=0.5,
        ),
        Scenario(
            name="v2g_degradation_guard",
            description="Shopping ToU arbitrage with cycling wear priced in "
            "(degradation weight trims uneconomic discharge)",
            tariff="tou",
            v2g_comp_price=0.12,
            grid_sell_discount=0.95,
            degradation_weight=0.05,
        ),
        Scenario(
            name="v2g_highway_peak_shaver",
            description="Highway plaza shaving its demand charge with a "
            "quarter of the lanes discharging at the peak",
            profile="highway",
            traffic="high",
            demand_charge_rate=0.4,
            demand_contract_kw=400.0,
            v2g_comp_price=0.20,
            v2g_port_fraction=0.25,
        ),
        # ----- real-data pack (repro.data.ingest) -----
        # NOTE: runs offline from the vendored sample extracts, which are
        # format-faithful *synthetic stand-ins* for the real exports (see
        # docs/data_provenance.md); point price_source/pv_source at your
        # own ENTSO-E/PVGIS downloads for measured data.
        Scenario(
            name="real_nl_2024_office",
            description="Workplace on NL-2024 day-ahead prices (vendored "
            "ENTSO-E-format extract) with a PVGIS-format Delft carport; "
            "weekends go quiet",
            profile="work",
            price_source="nl_2024",
            pv_source="pvgis_nl_delft",
            pv_peak_kw=120.0,
            weekend_factor=0.3,
        ),
        Scenario(
            name="real_nl_2024_shopping_tou",
            description="Shopping centre: ingested NL-2024 prices under a "
            "retail ToU overlay (negative midday hours make the valley real)",
            price_source="nl_2024",
            tariff="tou",
        ),
        Scenario(
            name="real_es_solar_heavy",
            description="Solar-heavy southern site: PVGIS-format Seville "
            "shape at 300 kW on ingested NL-2024 prices, summer arrival surge",
            price_source="nl_2024",
            pv_source="pvgis_es_seville",
            pv_peak_kw=300.0,
            season="summer_peak",
            weekend_factor=1.2,
        ),
        Scenario(
            name="real_nl_2024_residential_drift",
            description="Residential street on ingested NL-2024 prices with "
            "the EU mix drifting to bigger batteries",
            profile="residential",
            price_source="nl_2024",
            season="winter_peak",
            fleet_drift="big_battery_growth",
            fleet_drift_strength=1.5,
        ),
        # ----- grid pack: feeder power envelopes (allocate-stage coupling) -----
        # paper_16's worst-case gross draw is ~1650 kW (10 DC x 150 kW + 6 AC
        # x 11 kW, grid-side), so these caps genuinely bind.
        Scenario(
            name="grid_tight_transformer",
            description="Shopping site behind an undersized 300 kW feeder: "
            "the allocate stage curtails hard, overshoot is penalised",
            grid_cap_kw=300.0,
            grid_violation_weight=5.0,
        ),
        Scenario(
            name="grid_dr_events",
            description="500 kW feeder hit by ~1.5 demand-response events/day "
            "that tighten the cap to 40% for two hours",
            grid_cap_kw=500.0,
            grid_dr_events_per_day=1.5,
            grid_dr_depth=0.4,
            grid_dr_hours=2.0,
            grid_violation_weight=2.0,
        ),
        Scenario(
            name="grid_setpoint_tracking",
            description="DSO setpoint tracking: follow a 400 kW midday "
            "half-sine (solar soak) under an 800 kW feeder",
            grid_cap_kw=800.0,
            grid_violation_weight=1.0,
            grid_setpoint_kw=400.0,
            grid_setpoint_weight=0.5,
        ),
        Scenario(
            name="grid_evening_droop",
            description="Residential ToU street where the DSO reserves 40% "
            "of a 450 kW feeder for household load in the 17-21h peak",
            profile="residential",
            tariff="tou",
            grid_cap_kw=450.0,
            grid_cap_profile="evening_droop",
            grid_violation_weight=2.0,
        ),
        # ----- city pack: population-scale demand routed across a fleet -----
        # the city axis acts at FleetEnv level (FleetEnv(city="name") /
        # repro.city.make_city); single-station lowering ignores it, so these
        # keep the one-jit-entry catalog invariant for free.
        Scenario(
            name="city_ring_evening",
            description="Ring of shopping-district stations serving an "
            "evening-peaked city of 1800 charging sessions/day under ToU",
            tariff="tou",
            city_population=1800.0,
            city_layout="ring",
        ),
        Scenario(
            name="city_grid_commuters",
            description="Commuter city on a grid of workplace stations: "
            "2400 sessions/day, quiet weekends, queue-averse drivers",
            profile="work",
            weekend_factor=0.3,
            city_population=2400.0,
            city_layout="grid",
            city_w_queue=4.0,
        ),
        Scenario(
            name="city_clustered_core",
            description="Dense urban core in winter: clustered stations, "
            "3200 sessions/day, congestion spills demand outward",
            profile="residential",
            season="winter_peak",
            city_population=3200.0,
            city_layout="clustered",
            city_radius_km=4.0,
            city_w_dist=0.5,
        ),
        Scenario(
            name="city_price_shoppers",
            description="Price-sensitive drivers arbitraging ToU stations "
            "across town: routing follows the tariff valley",
            tariff="tou",
            tou_peak_mult=1.8,
            city_population=1500.0,
            city_layout="ring",
            city_w_price=10.0,
            city_w_dist=0.15,
        ),
    ]
)

# V2G-heavy scenarios plus their charge-only counterparts: the default mixed
# distribution for `rl_train --v2g` (nested-vmap scenario training, one table
# copy per scenario, zero recompilation across the mix)
V2G_PACK = (
    "v2g_shopping_tou",
    "v2g_residential_crisis",
    "v2g_work_solar_split",
    "v2g_degradation_guard",
    "v2g_highway_peak_shaver",
)
V2G_MIXED_PACK = (
    "v2g_shopping_tou",
    "v2g_residential_crisis",
    "v2g_work_solar_split",
    "shopping_pv_tou",
    "residential_winter_crisis",
    "shopping_flat",
)

# Scenarios exercising the real-data ingest path (ENTSO-E day-ahead price
# and PVGIS hourly solar formats; the vendored extracts are synthetic
# stand-ins with real-export schemas — docs/data_provenance.md documents
# this and how to swap in measured downloads).  Same shapes as the
# synthetic worlds: mixing real-data and synthetic scenarios in one
# training distribution costs zero recompilation.
REAL_PACK = (
    "real_nl_2024_office",
    "real_nl_2024_shopping_tou",
    "real_es_solar_heavy",
    "real_nl_2024_residential_drift",
)

# City-coupled scenarios: one population-scale arrival stream split across a
# fleet by the gravity/queue choice model (repro.city).  The city axis never
# touches EnvParams shapes — it lowers at fleet level via make_city — so the
# pack rides the one-jit-entry invariant untouched (catalog 21 -> 25).
CITY_PACK = (
    "city_ring_evening",
    "city_grid_commuters",
    "city_clustered_core",
    "city_price_shoppers",
)

# Grid-coupled scenarios: time-varying feeder power envelopes, demand-response
# events and setpoint tracking, all acting through the allocate stage of the
# staged transition pipeline.  Same parameter shapes as every other scenario
# (the cap/setpoint tables are always present, unlimited/zero by default), so
# adding the pack to a training distribution costs zero recompilation.
GRID_PACK = (
    "grid_tight_transformer",
    "grid_dr_events",
    "grid_setpoint_tracking",
    "grid_evening_droop",
)
