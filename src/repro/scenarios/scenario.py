"""Declarative scenarios: one dataclass composes every exogenous process.

A :class:`Scenario` names *what the world looks like* — user profile,
traffic, price region/year, car mix, PV plant, tariff structure, seasonal
modulation, fleet drift — while the environment keeps owning *how the world
evolves*.  ``Scenario.make_params(env)`` lowers the description into an
:class:`~repro.core.state.EnvParams` pytree whose arrays all have
scenario-independent shapes:

  * car tables are padded to :data:`MAX_CAR_MODELS` rows (probability 0) so
    EU/US/World mixes share one shape,
  * ``car_probs`` is always emitted as a (365, MAX_CAR_MODELS) drift table
    (constant rows when there is no drift),
  * PV/tariff/season arrays are always present (zeros/ones when inactive).

Consequently *every* scenario produces the same pytree structure and shapes:
swapping scenarios at runtime is a pure array swap and never recompiles a
jitted ``env.step`` (asserted in ``tests/scenarios/test_scenarios.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.env import ChargaxEnv
from repro.core.state import EnvParams, RewardWeights
from repro.scenarios import processes
from repro.utils import replace

# every bundled car table fits in 8 rows; padding rows get probability 0
MAX_CAR_MODELS = 8


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Declarative description of one charging-station world."""

    name: str
    description: str = ""
    # --- bundled dataset selection (paper Table 1) ---
    profile: str = "shopping"  # highway|residential|work|shopping
    traffic: str | float = "medium"  # low|medium|high or cars/day
    price_region: str = "NL"  # NL|FR|DE
    price_year: int = 2021
    car_region: str = "EU"  # EU|US|World
    # --- real-data axis (repro.data.ingest; overrides the synthetic tables
    # with identically shaped ones, so the catalog still compiles once) ---
    # ENTSO-E day-ahead prices: registry name ("nl_2024") or export path;
    # replaces the synthetic price_region/price_year curve (tariff overlays
    # still apply on top)
    price_source: str | None = None
    # PVGIS hourly solar: registry name ("pvgis_nl_delft") or seriescalc
    # path; replaces the clear-sky generator's *shape*, still scaled by
    # pv_peak_kw (set it > 0 or the plant stays dark)
    pv_source: str | None = None
    # --- solar PV plant ---
    pv_peak_kw: float = 0.0
    pv_cloud_noise: float = 0.15
    pv_seed: int = 23
    # --- tariff structure ---
    tariff: str = "flat"  # flat | tou
    tou_peak_mult: float = 1.6
    tou_offpeak_mult: float = 0.8
    demand_charge_rate: float = 0.0  # EUR per kW·step above contract
    demand_contract_kw: float = 0.0
    # --- arrival modulation ---
    season: str = "none"  # none | summer_peak | winter_peak
    season_amplitude: float = 0.25
    weekend_factor: float = 1.0
    # --- fleet-mix drift over the year ---
    fleet_drift: str = "none"  # none | big_battery_growth
    fleet_drift_strength: float = 1.0
    # --- V2G axis (needs EnvConfig.allow_v2g=True to act) ---
    # sell-price spread: owners are compensated v2g_comp_price EUR/kWh for
    # discharged energy (None = p_sell: no spread, V2G never pays off) while
    # the station sells to the grid at grid_sell_discount * p_buy
    v2g_comp_price: float | None = None
    grid_sell_discount: float = 0.9
    # fraction of real ports with bidirectional hardware (first k lanes)
    v2g_port_fraction: float = 1.0
    # battery/car wear weight lowered into RewardWeights.degradation
    degradation_weight: float = 0.0
    # --- grid axis: feeder power envelope + demand response + setpoint ---
    # feeder/transformer cap in kW (None = unlimited: the allocate stage is
    # an exact no-op); lowered into EnvParams.grid_cap_kw_table
    grid_cap_kw: float | None = None
    grid_cap_profile: str = "flat"  # flat | evening_droop
    # demand-response events: Poisson(events/day) windows multiplying the cap
    # by dr_depth for dr_hours (processes.grid_cap_table)
    grid_dr_events_per_day: float = 0.0
    grid_dr_depth: float = 0.5
    grid_dr_hours: float = 2.0
    grid_seed: int = 7
    # reward weight on kW of pre-curtailment cap overshoot
    # (RewardWeights.grid_violation; merges like degradation_weight)
    grid_violation_weight: float = 0.0
    # DSO setpoint-tracking objective: midday half-sine peaking at
    # grid_setpoint_kw, |drawn - setpoint| penalised at grid_setpoint_weight
    grid_setpoint_kw: float = 0.0
    grid_setpoint_weight: float = 0.0
    # --- city axis: a population of drivers choosing among stations ---
    # Acts at the FLEET level (``FleetEnv(city=...)`` via
    # ``repro.city.make_city(scenario, n_stations)``): the fields below
    # parameterise the population stream and the gravity/queue choice model.
    # Single-station lowering ignores them entirely, so ``make_params`` emits
    # the same EnvParams shapes as every other scenario and the one-jit-entry
    # catalog invariant is untouched.
    city_population: float = 0.0  # expected charging sessions/day city-wide
    #     (0 = no city coupling; the stream scales linearly with it)
    city_layout: str = "ring"  # ring | grid | clustered station placement
    city_radius_km: float = 5.0
    city_zones: int = 3  # gravity-model demand centroids
    city_w_dist: float = 0.35  # choice logit weight per km of distance
    city_w_price: float = 4.0  # per EUR/kWh of current buy price
    city_w_queue: float = 2.0  # per unit of station occupancy fraction
    city_seed: int = 11

    # ------------------------------------------------------------------
    # Serialisation (registry round-trips, config files)
    # ------------------------------------------------------------------
    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "Scenario":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown Scenario fields: {sorted(unknown)}")
        return cls(**d)

    def evolve(self, **changes: Any) -> "Scenario":
        """A modified copy (keeps scenario definitions declarative)."""
        return dataclasses.replace(self, **changes)

    # ------------------------------------------------------------------
    # Lowering to EnvParams
    # ------------------------------------------------------------------
    def make_params(
        self, env: ChargaxEnv, weights: RewardWeights | None = None
    ) -> EnvParams:
        """Lower this scenario onto ``env``'s station (pure array swaps)."""
        cfg = env.config
        base = env.make_params(
            weights=weights,
            price_year=self.price_year,
            traffic=self.traffic,
            profile=self.profile,
            price_region=self.price_region,
            car_region=self.car_region,
        )
        # the scenario's declared wear price merges into whatever weights are
        # in effect; an explicit nonzero caller degradation (an alpha sweep
        # over that axis) wins over the scenario's default
        if self.degradation_weight and float(base.weights.degradation) == 0.0:
            base = replace(
                base,
                weights=dataclasses.replace(
                    base.weights, degradation=float(self.degradation_weight)
                ),
            )
        if self.grid_violation_weight and float(base.weights.grid_violation) == 0.0:
            base = replace(
                base,
                weights=dataclasses.replace(
                    base.weights, grid_violation=float(self.grid_violation_weight)
                ),
            )
        if self.grid_setpoint_weight and float(base.weights.grid_setpoint) == 0.0:
            base = replace(
                base,
                weights=dataclasses.replace(
                    base.weights, grid_setpoint=float(self.grid_setpoint_weight)
                ),
            )

        # day-ahead curve: real ENTSO-E export or the synthetic region/year
        # profile already in base; tariff overlays apply to either
        if self.price_source is not None:
            from repro.data import ingest

            prices = ingest.load_price_table(self.price_source, cfg.dt_minutes)
        else:
            prices = np.asarray(base.price_buy_table)
        if self.tariff == "tou":
            prices = processes.tou_overlay(
                prices,
                cfg.dt_minutes,
                peak_mult=self.tou_peak_mult,
                offpeak_mult=self.tou_offpeak_mult,
            )
        elif self.tariff != "flat":
            raise ValueError(f"unknown tariff {self.tariff!r}")

        if self.pv_source is not None:
            from repro.data import ingest

            pv = (
                float(self.pv_peak_kw)
                * ingest.load_pv_table(self.pv_source, cfg.dt_minutes)
            ).astype(np.float32)
        else:
            pv = processes.pv_table(
                self.pv_peak_kw, cfg.dt_minutes, self.pv_cloud_noise, self.pv_seed
            )
        day_scale = processes.seasonal_arrival_scale(
            self.season, self.season_amplitude, self.weekend_factor
        )

        # car mix: pad to the common model count, then expand to a drift table
        probs = _pad(np.asarray(base.car_probs), 0.0)
        cap = _pad(np.asarray(base.car_capacity), 1.0)
        ac = _pad(np.asarray(base.car_ac_kw), 1.0)
        dc = _pad(np.asarray(base.car_dc_kw), 1.0)
        tau = _pad(np.asarray(base.car_tau), 0.5)
        if self.fleet_drift == "none":
            probs_end = probs
        elif self.fleet_drift == "big_battery_growth":
            probs_end = processes.big_battery_shift(
                probs, cap, self.fleet_drift_strength
            )
        else:
            raise ValueError(f"unknown fleet_drift {self.fleet_drift!r}")
        probs_table = processes.fleet_drift_table(probs, probs_end)

        # V2G port fraction: the first k real (unmasked) lanes get
        # bidirectional hardware — a pure (n_evse,) array swap, so mixed
        # v2g/non-v2g catalogs share one compiled step
        if not 0.0 <= self.v2g_port_fraction <= 1.0:
            raise ValueError(
                f"v2g_port_fraction must be in [0, 1], got {self.v2g_port_fraction}"
            )
        lane_mask = np.asarray(base.evse_mask)
        n_real = int(lane_mask.sum())
        n_v2g = int(round(self.v2g_port_fraction * n_real))
        v2g_mask = np.zeros_like(lane_mask)
        real_idx = np.flatnonzero(lane_mask > 0.5)
        v2g_mask[real_idx[:n_v2g]] = 1.0

        comp = self.v2g_comp_price
        p_v2g_comp = base.p_sell if comp is None else jnp.float32(comp)

        # grid axis: replace the unlimited-cap / zero-setpoint default tables
        # only when declared — same shapes either way, so the catalog (grid
        # and non-grid scenarios mixed) still shares one compiled step
        grid_tables = {}
        if self.grid_cap_kw is not None:
            grid_tables["grid_cap_kw_table"] = jnp.asarray(
                processes.grid_cap_table(
                    self.grid_cap_kw,
                    cfg.dt_minutes,
                    profile=self.grid_cap_profile,
                    dr_events_per_day=self.grid_dr_events_per_day,
                    dr_depth=self.grid_dr_depth,
                    dr_hours=self.grid_dr_hours,
                    seed=self.grid_seed,
                )
            )
        if self.grid_setpoint_kw:
            grid_tables["grid_setpoint_kw_table"] = jnp.asarray(
                processes.grid_setpoint_table(self.grid_setpoint_kw, cfg.dt_minutes)
            )

        return replace(
            base,
            **grid_tables,
            price_buy_table=jnp.asarray(prices),
            pv_kw_table=jnp.asarray(pv),
            arrival_day_scale=jnp.asarray(day_scale),
            car_probs=jnp.asarray(probs_table),
            car_capacity=jnp.asarray(cap),
            car_ac_kw=jnp.asarray(ac),
            car_dc_kw=jnp.asarray(dc),
            car_tau=jnp.asarray(tau),
            demand_charge_rate=jnp.float32(self.demand_charge_rate),
            demand_contract_kw=jnp.float32(self.demand_contract_kw),
            evse_v2g_mask=jnp.asarray(v2g_mask),
            p_v2g_comp=p_v2g_comp,
            grid_sell_discount=jnp.float32(self.grid_sell_discount),
        )


def _pad(x: np.ndarray, fill: float) -> np.ndarray:
    if x.shape[0] > MAX_CAR_MODELS:
        raise ValueError(f"car table has {x.shape[0]} > {MAX_CAR_MODELS} models")
    out = np.full(MAX_CAR_MODELS, fill, dtype=np.float32)
    out[: x.shape[0]] = x
    return out
