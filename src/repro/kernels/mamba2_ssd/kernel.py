"""Mamba2 SSD — Pallas TPU kernel (chunk-dual form, DESIGN.md §6).

    grid = (B * H, L / Q)          # chunk axis sequential on TPU

Per grid step one (Q)-token chunk of one (batch, head) pair is processed:
intra-chunk work is two MXU matmuls — (Q,N)x(N,Q) score matrix and a masked
(Q,Q)x(Q,P) weighted sum — and the running state (N, P) is carried in VMEM
scratch across the chunk axis (inter-chunk recurrence), avoiding any HBM
round-trip for the state.

Inputs are pre-arranged by ``ops.py`` as (B*H, L, ...) slabs with dt folded
into x (``xdt = x * dt``) and log-decays precomputed (``loga = dt * A_h``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    xdt_ref,  # (Q, P)
    loga_ref,  # (Q, 128) lane-replicated log decay
    b_ref,  # (Q, N)
    c_ref,  # (Q, N)
    y_ref,  # out (Q, P)
    s_out_ref,  # out (N, P) final state (written every chunk; last wins)
    s_ref,  # scratch (N, P) carried state
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    xdt = xdt_ref[...].astype(jnp.float32)
    loga = loga_ref[:, 0]  # (Q,)
    b = b_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)

    cum = jnp.cumsum(loga)  # (Q,) inclusive
    total = cum[chunk - 1]

    # intra-chunk: (C B^T) ⊙ tril(exp(cum_i - cum_j)) @ xdt
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    diff = cum[:, None] - cum[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(cols <= rows, diff, -1e30))
    y_intra = jax.lax.dot_general(
        cb * decay, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # inter-chunk: exp(cum_i) * C_i @ S_prev
    s_prev = s_ref[...]
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, s_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_ref[...] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S = exp(total) S_prev + sum_j exp(total - cum_j) B_j ⊗ xdt_j
    wb = b * jnp.exp(total - cum)[:, None]  # (Q, N)
    s_new = jnp.exp(total) * s_prev + jax.lax.dot_general(
        wb, xdt, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new

    @pl.when(ci == pl.num_programs(1) - 1)
    def _emit_state():
        s_out_ref[...] = s_new


def ssd_fwd(
    xdt: jnp.ndarray,  # (BH, L, P)
    loga: jnp.ndarray,  # (BH, L, 128) lane-replicated
    b_mat: jnp.ndarray,  # (BH, L, N)
    c_mat: jnp.ndarray,  # (BH, L, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    bh, l, p = xdt.shape
    n = b_mat.shape[-1]
    assert l % chunk == 0

    grid = (bh, l // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, p), lambda g, c_: (g, c_, 0)),
            pl.BlockSpec((None, chunk, 128), lambda g, c_: (g, c_, 0)),
            pl.BlockSpec((None, chunk, n), lambda g, c_: (g, c_, 0)),
            pl.BlockSpec((None, chunk, n), lambda g, c_: (g, c_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, p), lambda g, c_: (g, c_, 0)),
            pl.BlockSpec((None, n, p), lambda g, c_: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, l, p), xdt.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xdt, loga, b_mat, c_mat)
    return y, s_fin
