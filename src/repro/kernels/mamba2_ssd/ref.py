"""Oracles for the Mamba2 SSD (state-space dual) layer core.

Semantics (per batch b, head h; state S in R^{N x P}):

    a_t = exp(dt_t * A_h)                       # A_h < 0
    S_t = a_t * S_{t-1} + dt_t * B_t (outer) x_t
    y_t = C_t @ S_t  (+ D_h * x_t added by the caller)

Two references:
  * ``ssd_scan_ref``    — sequential lax.scan; the ground-truth oracle.
  * ``ssd_chunked_jnp`` — chunk-parallel dual form (matmul-rich); the
                          execution path models use off-TPU, and the exact
                          math the Pallas kernel implements.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(
    x: jnp.ndarray,  # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H)
    a: jnp.ndarray,  # (H,) negative
    b_mat: jnp.ndarray,  # (B, L, N)  (single B/C group broadcast over heads)
    c_mat: jnp.ndarray,  # (B, L, N)
    s0: jnp.ndarray | None = None,  # (B, H, N, P)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,L,H,P), final_state (B,H,N,P))."""
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32)
    cf = c_mat.astype(jnp.float32)

    def per_bh(xh, dth, ah, bb, cc, s_init):
        # xh (L, P), dth (L,), bb/cc (L, N)
        def step(s, inp):
            xt, dtt, bt, ct = inp
            decay = jnp.exp(dtt * ah)
            s = decay * s + dtt * (bt[:, None] * xt[None, :])  # (N, P)
            y = ct @ s  # (P,)
            return s, y

        s_fin, ys = jax.lax.scan(step, s_init, (xh, dth, bb, cc))
        return ys, s_fin

    if s0 is None:
        s0 = jnp.zeros((bsz, h, n, p), jnp.float32)

    # vmap over batch then heads (B/C shared across heads)
    f = jax.vmap(  # batch
        jax.vmap(per_bh, in_axes=(1, 1, 0, None, None, 0), out_axes=(1, 0)),
        in_axes=(0, 0, None, 0, 0, 0),
        out_axes=(0, 0),
    )
    y, s_fin = f(xf, dtf, a.astype(jnp.float32), bf, cf, s0)
    return y.astype(x.dtype), s_fin


def _segsum_chunk(loga: jnp.ndarray) -> jnp.ndarray:
    """(..., Q) log decays -> local inclusive cumsum (..., Q)."""
    return jnp.cumsum(loga, axis=-1)


def ssd_chunked_jnp(
    x: jnp.ndarray,  # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H)
    a: jnp.ndarray,  # (H,)
    b_mat: jnp.ndarray,  # (B, L, N)
    c_mat: jnp.ndarray,  # (B, L, N)
    chunk: int = 128,
    s0: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunk-dual SSD as a scan over chunks; semantics == ``ssd_scan_ref``.

    Scanning (instead of computing every chunk's (Q,Q,H) decay tensor at
    once) bounds the live intermediates to ONE chunk — this was the dominant
    memory term of the zamba2 train cells (§Perf iteration 1).  The body is
    checkpointed so the backward pass recomputes rather than stores them.
    """
    from repro.utils import unroll_scans_enabled

    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    af = a.astype(jnp.float32)

    cs = lambda t: jnp.moveaxis(
        t.reshape(bsz, nc, chunk, *t.shape[2:]), 1, 0
    )  # (NC, B, Q, ...)
    xf = cs(x.astype(jnp.float32))
    dtf = cs(dt.astype(jnp.float32))
    bf = cs(b_mat.astype(jnp.float32))
    cf = cs(c_mat.astype(jnp.float32))

    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    if s0 is None:
        s0 = jnp.zeros((bsz, h, n, p), jnp.float32)

    @jax.checkpoint
    def body(s, inp):
        xc, dtc, bc, cc = inp  # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        loga = dtc * af
        cum = jnp.cumsum(loga, axis=1)  # (B,Q,H) inclusive
        total = cum[:, -1]  # (B,H)
        cb = jnp.einsum("bin,bjn->bij", cc, bc)
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,Q,H)
        # clamp inside exp: masked (j>i) diffs are positive -> would overflow
        # and poison the vjp (NaN = 0 * inf)
        decay = jnp.exp(jnp.where(mask[None, :, :, None], diff, -1e30))
        xdt = xc * dtc[..., None]
        y = jnp.einsum("bij,bijh,bjhp->bihp", cb, decay, xdt)
        y += jnp.einsum("bin,bih,bhnp->bihp", cc, jnp.exp(cum), s)
        w = jnp.exp(total[:, None] - cum)  # (B,Q,H)
        s_new = s * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bjn,bjh,bjhp->bhnp", bc, w, xdt
        )
        return s_new, y

    s_fin, ys = jax.lax.scan(
        body, s0, (xf, dtf, bf, cf), unroll=unroll_scans_enabled()
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, l, h, p)
    return y.astype(x.dtype), s_fin


def ssd_decode_step(
    x: jnp.ndarray,  # (B, H, P) one token
    dt: jnp.ndarray,  # (B, H)
    a: jnp.ndarray,  # (H,)
    b_t: jnp.ndarray,  # (B, N)
    c_t: jnp.ndarray,  # (B, N)
    s: jnp.ndarray,  # (B, H, N, P) carried state
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent decode update (the long_500k serving path)."""
    decay = jnp.exp(dt.astype(jnp.float32) * a)  # (B, H)
    s_new = s * decay[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", b_t.astype(jnp.float32), dt.astype(jnp.float32), x.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhnp->bhp", c_t.astype(jnp.float32), s_new)
    return y.astype(x.dtype), s_new
