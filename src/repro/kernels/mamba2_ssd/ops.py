"""Jit'd wrapper for the Mamba2 SSD core.

Dispatch: Pallas kernel on TPU, chunked-jnp dual form elsewhere (both match
the sequential-scan oracle).  Gradients flow through a custom_vjp whose
backward recomputes via the chunked-jnp form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba2_ssd import ref
from repro.kernels.mamba2_ssd.kernel import ssd_fwd


def _pallas_path(x, dt, a, b_mat, c_mat, chunk, interpret):
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    xdt = (xf * dtf[..., None]).transpose(0, 2, 1, 3).reshape(bsz * h, l, p)
    loga = (dtf * a.astype(jnp.float32)).transpose(0, 2, 1)  # (B,H,L)
    loga = jnp.broadcast_to(loga.reshape(bsz * h, l, 1), (bsz * h, l, 128))
    bb = jnp.broadcast_to(
        b_mat.astype(jnp.float32)[:, None], (bsz, h, l, n)
    ).reshape(bsz * h, l, n)
    cc = jnp.broadcast_to(
        c_mat.astype(jnp.float32)[:, None], (bsz, h, l, n)
    ).reshape(bsz * h, l, n)

    y, s_fin = ssd_fwd(xdt, loga, bb, cc, chunk=chunk, interpret=interpret)
    y = y.reshape(bsz, h, l, p).transpose(0, 2, 1, 3).astype(x.dtype)
    return y, s_fin.reshape(bsz, h, n, p)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd(x, dt, a, b_mat, c_mat, chunk, impl):
    if impl == "pallas":
        return _pallas_path(x, dt, a, b_mat, c_mat, chunk, interpret=False)
    if impl == "interpret":
        return _pallas_path(x, dt, a, b_mat, c_mat, chunk, interpret=True)
    return ref.ssd_chunked_jnp(x, dt, a, b_mat, c_mat, chunk=chunk)


def _fwd(x, dt, a, b_mat, c_mat, chunk, impl):
    out = _ssd(x, dt, a, b_mat, c_mat, chunk, impl)
    return out, (x, dt, a, b_mat, c_mat)


def _bwd(chunk, impl, res, g):
    x, dt, a, b_mat, c_mat = res

    def f(x, dt, a, b_mat, c_mat):
        return ref.ssd_chunked_jnp(x, dt, a, b_mat, c_mat, chunk=chunk)

    _, vjp = jax.vjp(f, x, dt, a, b_mat, c_mat)
    return vjp(g)


_ssd.defvjp(_fwd, _bwd)


def ssd(
    x: jnp.ndarray,  # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H), positive
    a: jnp.ndarray,  # (H,), negative
    b_mat: jnp.ndarray,  # (B, L, N)
    c_mat: jnp.ndarray,  # (B, L, N)
    *,
    chunk: int = 128,
    impl: str = "auto",  # auto | pallas | interpret | ref
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mamba2 SSD core: returns (y (B,L,H,P), final_state (B,H,N,P))."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    l = x.shape[1]
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        # identity padding: dt=0 -> decay=1, contribution=0
        padlen = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        y, s_fin = _ssd(
            padlen(x), padlen(dt), a, padlen(b_mat), padlen(c_mat), chunk, impl
        )
        return y[:, :l], s_fin
    return _ssd(x, dt, a, b_mat, c_mat, chunk, impl)


ssd_decode_step = ref.ssd_decode_step
