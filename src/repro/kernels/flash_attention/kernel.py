"""Flash attention forward — Pallas TPU kernel.

Two-loop online-softmax attention blocked for VMEM/MXU (DESIGN.md §6):

  grid = (batch, q_heads, Lq/Bq, Lk/Bk)     # last axis sequential on TPU

Running max/denominator/accumulator live in VMEM scratch carried across the
kv-block axis.  Causal and sliding-window geometry prunes fully-masked kv
blocks with ``pl.when`` (no MXU work issued).  GQA folds G query heads onto
each kv head via the kv index_map.  Optional logit soft-capping (gemma2).

MXU alignment: Bq/Bk default 128; head_dim padded to a multiple of 128 by the
``ops.py`` wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref,  # (Bq, D)
    k_ref,  # (Bk, D)
    v_ref,  # (Bk, D)
    o_ref,  # (Bq, D)
    m_ref,  # scratch (Bq, 128) running max (lane-replicated)
    l_ref,  # scratch (Bq, 128) running denom
    acc_ref,  # scratch (Bq, D) running numerator
    *,
    scale: float,
    causal: bool,
    window: int | None,
    softcap: float | None,
    q_offset: int,
    block_q: int,
    block_k: int,
    kv_valid: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # --- block-level geometry: is any (row, col) pair in this tile live? ---
    row_min = iq * block_q + q_offset
    row_max = row_min + block_q - 1
    col_min = ik * block_k
    col_max = col_min + block_k - 1
    live = col_min <= jnp.minimum(row_max, kv_valid - 1) if causal else col_min < kv_valid
    if window is not None:
        live = jnp.logical_and(live, col_max > row_min - window)

    @pl.when(live)
    def _update():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (Bq, Bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        rows = row_min + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = col_min + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = cols < kv_valid
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        if window is not None:
            mask = jnp.logical_and(mask, cols > rows - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (Bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)  # (Bq, 1)
        p = jnp.exp(s - m_new)  # (Bq, Bk); masked entries exp(-inf)=0
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)

        pv = jax.lax.dot_general(
            p, v_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == pl.num_programs(3) - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[...] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,  # (B, Hq, Lq, D) — D multiple of 128, Lq/Lk multiples of blocks
    k: jnp.ndarray,  # (B, Hkv, Lk, D)
    v: jnp.ndarray,
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
    kv_valid: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert lq % block_q == 0 and lk % block_k == 0, (lq, lk, block_q, block_k)
    assert hq % hkv == 0
    g = hq // hkv
    kv_valid = lk if kv_valid is None else kv_valid

    grid = (b, hq, lq // block_q, lk // block_k)
    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        kv_valid=kv_valid,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((None, None, block_k, d), lambda b_, h, iq, ik: (b_, h // g, ik, 0)),
            pl.BlockSpec((None, None, block_k, d), lambda b_, h, iq, ik: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
