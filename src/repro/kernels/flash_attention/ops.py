"""Jit'd public wrapper for flash attention.

``impl='auto'`` picks the Pallas kernel on TPU backends and the jnp reference
everywhere else (CPU tests / 512-device dry-run compiles), padding shapes to
kernel alignment as needed.  Gradients always flow: a ``custom_vjp`` routes
the backward pass through the reference implementation (recompute), which is
exact; a dedicated backward kernel is a TPU-only optimisation the ref bwd
stands in for off-TPU (DESIGN.md §6).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> tuple[jnp.ndarray, int]:
    size = x.shape[axis]
    target = (size + mult - 1) // mult * mult
    if target == size:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad), size


def _pallas_path(q, k, v, *, causal, window, softcap, scale, q_offset, block_q, block_k, interpret):
    lq, lk = q.shape[2], k.shape[2]
    off = lk - lq if q_offset is None else q_offset
    qp, _ = _pad_to(q, 2, block_q)
    kp, _ = _pad_to(k, 2, block_k)
    vp, _ = _pad_to(v, 2, block_k)
    qp, d0 = _pad_to(qp, 3, 128)
    kp, _ = _pad_to(kp, 3, 128)
    vp, _ = _pad_to(vp, 3, 128)
    out = flash_attention_fwd(
        qp, kp, vp,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        q_offset=off,
        kv_valid=lk,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
    return out[:, :, :lq, :d0]


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10)
)
def _flash_attention(
    q, k, v, causal, window, softcap, scale, q_offset, block_q, block_k, use_pallas
):
    if use_pallas == "pallas":
        return _pallas_path(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
            q_offset=q_offset, block_q=block_q, block_k=block_k, interpret=False,
        )
    if use_pallas == "interpret":
        return _pallas_path(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
            q_offset=q_offset, block_q=block_q, block_k=block_k, interpret=True,
        )
    if use_pallas == "naive":
        return ref.mha_reference(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
            q_offset=q_offset,
        )
    # "ref": blocked online-softmax jnp — the memory-bounded off-TPU path
    return ref.mha_blocked_jnp(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, block_k=block_k,
    )


def _fwd(q, k, v, causal, window, softcap, scale, q_offset, block_q, block_k, use_pallas):
    out = _flash_attention(
        q, k, v, causal, window, softcap, scale, q_offset, block_q, block_k, use_pallas
    )
    return out, (q, k, v)


def _bwd(causal, window, softcap, scale, q_offset, block_q, block_k, use_pallas, res, g):
    q, k, v = res

    def f(q, k, v):
        return ref.mha_blocked_jnp(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
            q_offset=q_offset, block_k=block_k,
        )

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


_flash_attention.defvjp(_fwd, _bwd)


def flash_attention(
    q: jnp.ndarray,  # (B, Hq, Lq, D)
    k: jnp.ndarray,  # (B, Hkv, Lk, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    q_offset: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    impl: str = "auto",  # auto | pallas | interpret | ref (blocked jnp) | naive
) -> jnp.ndarray:
    """IO-aware attention; see module docstring for dispatch semantics."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    return _flash_attention(
        q, k, v, causal, window, softcap, scale, q_offset, block_q, block_k, impl
    )
