"""Pure-jnp oracle for flash attention (also the CPU/dry-run execution path).

Supports GQA (Hq = G * Hkv), causal masking with query offset (decode /
chunked prefill alignment), sliding-window attention and logit soft-capping
(gemma2).  All reductions in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_mask(
    q_len: int,
    kv_len: int,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int | None = None,
) -> jnp.ndarray:
    """(q_len, kv_len) boolean mask; True = attend.

    ``q_offset`` is the global position of query row 0 within the kv axis;
    defaults to kv_len - q_len (queries at the end — decode alignment).
    """
    off = kv_len - q_len if q_offset is None else q_offset
    rows = jnp.arange(q_len)[:, None] + off
    cols = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    return mask


def mha_reference(
    q: jnp.ndarray,  # (B, Hq, Lq, D)
    k: jnp.ndarray,  # (B, Hkv, Lk, D)
    v: jnp.ndarray,  # (B, Hkv, Lk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    q_offset: int | None = None,
    kv_valid_len: jnp.ndarray | None = None,  # () int — mask cols >= this
) -> jnp.ndarray:
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = (d**-0.5) if scale is None else scale

    qf = q.astype(jnp.float32).reshape(b, hkv, g, lq, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = attention_mask(lq, lk, causal=causal, window=window, q_offset=q_offset)
    if kv_valid_len is not None:
        mask = mask & (jnp.arange(lk)[None, :] < kv_valid_len)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(b, hq, lq, d).astype(q.dtype)


def mha_blocked_jnp(
    q: jnp.ndarray,  # (B, Hq, Lq, D)
    k: jnp.ndarray,  # (B, Hkv, Lk, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    q_offset: int | None = None,
    block_k: int = 1024,
) -> jnp.ndarray:
    """Online-softmax blocked attention in pure jnp — the flash algorithm as
    a lax.scan over kv blocks.

    This is the *execution* path off-TPU (models, dry-run compiles): it never
    materialises the (Lq, Lk) score matrix, so the compiled memory footprint
    matches what the Pallas kernel achieves on TPU (the naive
    ``mha_reference`` above stays as the test oracle).  Differentiable; the
    body is checkpointed so the backward recomputes blocks.
    """
    from repro.utils import unroll_scans_enabled

    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    scale = (d**-0.5) if scale is None else scale
    off = lk - lq if q_offset is None else q_offset

    pad = (-lk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = k.shape[2] // block_k

    qf = q.astype(jnp.float32).reshape(b, hkv, g, lq, d)
    kb = jnp.moveaxis(k.astype(jnp.float32).reshape(b, hkv, nk, block_k, d), 2, 0)
    vb = jnp.moveaxis(v.astype(jnp.float32).reshape(b, hkv, nk, block_k, d), 2, 0)

    rows = (jnp.arange(lq) + off)[:, None]  # (Lq, 1)

    @jax.checkpoint
    def body(carry, inp):
        m_prev, l_prev, acc = carry
        kc, vc, ik = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kc) * scale  # (B,Hkv,G,Lq,Bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        cols = ik * block_k + jnp.arange(block_k)[None, :]
        mask = cols < lk
        if causal:
            mask = mask & (cols <= rows)
        if window is not None:
            mask = mask & (cols > rows - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, vc)
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, hkv, g, lq), NEG_INF, jnp.float32),
        jnp.zeros((b, hkv, g, lq), jnp.float32),
        jnp.zeros((b, hkv, g, lq, d), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        body, init, (kb, vb, jnp.arange(nk)), unroll=unroll_scans_enabled()
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, lq, d).astype(q.dtype)
