"""Pallas TPU kernels for the framework's compute hot-spots (DESIGN.md §6).

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd dispatch wrapper with custom_vjp) and ref.py (pure-jnp oracle that is
also the CPU / dry-run execution path).
"""
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.chargax_step.ops import fused_step as chargax_fused_step
from repro.kernels.mamba2_ssd.ops import ssd, ssd_decode_step
from repro.kernels.rwkv6_wkv.ops import wkv, wkv_decode_step

__all__ = [
    "flash_attention",
    "chargax_fused_step",
    "ssd",
    "ssd_decode_step",
    "wkv",
    "wkv_decode_step",
]
