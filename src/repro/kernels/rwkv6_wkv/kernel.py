"""RWKV6 WKV — Pallas TPU kernel (chunked, per-channel data-dependent decay).

    grid = (B * H, L / Q)          # chunk axis sequential

The (K, V) state is carried in VMEM scratch.  Unlike SSD, the decay is
per-*channel*, so the intra-chunk pair weights form a (Q, Q, K) tensor; with
Q = K = 64 this is a 1 MB VMEM intermediate — deliberate: it keeps every
exponent a difference of cumulative log decays with j <= i-1 (<= 0, overflow-
free), instead of the unstable exp(+cum) trick used by matmul-only chunked
GLA formulations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(
    r_ref,  # (Q, K)
    k_ref,  # (Q, K)
    v_ref,  # (Q, V)
    lw_ref,  # (Q, K) log decay
    u_ref,  # (8, K) bonus, row 0 real
    y_ref,  # out (Q, V)
    s_out_ref,  # out (K, V)
    s_ref,  # scratch (K, V)
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    lw = lw_ref[...].astype(jnp.float32)
    u = u_ref[0, :]

    cw = jnp.cumsum(lw, axis=0)  # (Q, K) inclusive
    cw_shift = cw - lw  # exclusive
    total = cw[chunk - 1]  # (K,)

    # intra-chunk: (Q, Q, K) pair decays, strictly-lower-triangular mask
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    diff = cw_shift[:, None, :] - cw[None, :, :]
    decay = jnp.exp(jnp.where((cols < rows)[:, :, None], diff, -1e30))
    score = jnp.sum(r[:, None, :] * k[None, :, :] * decay, axis=-1)  # (Q, Q)
    y = jax.lax.dot_general(
        score, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    coeff = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)  # (Q, 1)
    y += coeff * v

    # inter-chunk
    s_prev = s_ref[...]
    y += jax.lax.dot_general(
        r * jnp.exp(cw_shift), s_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y_ref[...] = y.astype(y_ref.dtype)

    # state update
    wk = k * jnp.exp(total[None, :] - cw)  # (Q, K)
    s_new = jnp.exp(total)[:, None] * s_prev + jax.lax.dot_general(
        wk, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new

    @pl.when(ci == pl.num_programs(1) - 1)
    def _emit():
        s_out_ref[...] = s_new


def wkv_fwd(
    r: jnp.ndarray,  # (BH, L, K)
    k: jnp.ndarray,
    v: jnp.ndarray,  # (BH, L, V)
    lw: jnp.ndarray,  # (BH, L, K)
    u: jnp.ndarray,  # (BH, 8, K) per-(batch,head) bonus rows
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    bh, l, kd = r.shape
    vd = v.shape[-1]
    assert l % chunk == 0

    grid = (bh, l // chunk)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    seq_spec = lambda d: pl.BlockSpec((None, chunk, d), lambda g, c: (g, c, 0))
    y, s_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            seq_spec(kd),
            seq_spec(kd),
            seq_spec(vd),
            seq_spec(kd),
            pl.BlockSpec((None, 8, kd), lambda g, c: (g, 0, 0)),
        ],
        out_specs=[
            seq_spec(vd),
            pl.BlockSpec((None, kd, vd), lambda g, c: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, l, vd), r.dtype),
            jax.ShapeDtypeStruct((bh, kd, vd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kd, vd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u)
    return y, s_fin
