"""Jit'd wrapper for the RWKV6 WKV core (dispatch + custom_vjp, as flash/ssd)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv import ref
from repro.kernels.rwkv6_wkv.kernel import wkv_fwd


def _pallas_path(r, k, v, w, u, chunk, interpret):
    bsz, l, h, kd = r.shape
    vd = v.shape[-1]
    fold = lambda x: x.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(bsz * h, l, -1)
    lw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-20, 1.0))
    u_rows = jnp.broadcast_to(
        u.astype(jnp.float32)[None, :, None, :], (bsz, h, 8, kd)
    ).reshape(bsz * h, 8, kd)
    y, s_fin = wkv_fwd(
        fold(r), fold(k), fold(v), fold(lw), u_rows, chunk=chunk, interpret=interpret
    )
    y = y.reshape(bsz, h, l, vd).transpose(0, 2, 1, 3).astype(r.dtype)
    return y, s_fin.reshape(bsz, h, kd, vd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _wkv(r, k, v, w, u, chunk, impl):
    if impl == "pallas":
        return _pallas_path(r, k, v, w, u, chunk, interpret=False)
    if impl == "interpret":
        return _pallas_path(r, k, v, w, u, chunk, interpret=True)
    return ref.wkv_chunked_jnp(r, k, v, w, u, chunk=chunk)


def _fwd(r, k, v, w, u, chunk, impl):
    return _wkv(r, k, v, w, u, chunk, impl), (r, k, v, w, u)


def _bwd(chunk, impl, res, g):
    r, k, v, w, u = res

    def f(r, k, v, w, u):
        return ref.wkv_chunked_jnp(r, k, v, w, u, chunk=chunk)

    _, vjp = jax.vjp(f, r, k, v, w, u)
    return vjp(g)


_wkv.defvjp(_fwd, _bwd)


def wkv(
    r: jnp.ndarray,  # (B, L, H, K)
    k: jnp.ndarray,
    v: jnp.ndarray,  # (B, L, H, V)
    w: jnp.ndarray,  # (B, L, H, K) decay in (0,1)
    u: jnp.ndarray,  # (H, K)
    *,
    chunk: int = 64,
    impl: str = "auto",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV6 WKV core: returns (y (B,L,H,V), final_state (B,H,K,V))."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    l = r.shape[1]
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        # identity padding: w=1 (log w = 0), k=0 -> state preserved
        pz = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        po = lambda t: jnp.pad(
            t, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0
        )
        y, s_fin = _wkv(pz(r), pz(k), pz(v), po(w), u, chunk, impl)
        return y[:, :l], s_fin
    return _wkv(r, k, v, w, u, chunk, impl)


wkv_decode_step = ref.wkv_decode_step
