"""Oracles for the RWKV6 ("Finch") WKV core with data-dependent decay.

Semantics per (batch, head); state S in R^{K x V}:

    y_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)        # u: per-channel bonus
    S_t = diag(w_t) S_{t-1} + k_t^T v_t              # w_t in (0,1), per token

Two references: ``wkv_scan_ref`` (sequential oracle) and ``wkv_chunked_jnp``
(the chunk-parallel math the Pallas kernel implements; all in-chunk exponents
are differences of cumulative log decays with j <= i-1, hence <= 0 — no
overflow by construction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_scan_ref(
    r: jnp.ndarray,  # (B, L, H, K)
    k: jnp.ndarray,  # (B, L, H, K)
    v: jnp.ndarray,  # (B, L, H, V)
    w: jnp.ndarray,  # (B, L, H, K) decay in (0, 1)
    u: jnp.ndarray,  # (H, K) bonus
    s0: jnp.ndarray | None = None,  # (B, H, K, V)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    bsz, l, h, kd = r.shape
    vd = v.shape[-1]

    def per_bh(rr, kk, vv, ww, uu, s_init):
        def step(s, inp):
            rt, kt, vt, wt = inp
            kv = kt[:, None] * vt[None, :]  # (K, V)
            y = rt @ (s + uu[:, None] * kv)
            s = wt[:, None] * s + kv
            return s, y

        s_fin, ys = jax.lax.scan(step, s_init, (rr, kk, vv, ww))
        return ys, s_fin

    if s0 is None:
        s0 = jnp.zeros((bsz, h, kd, vd), jnp.float32)
    f32 = lambda x: x.astype(jnp.float32)
    f = jax.vmap(
        jax.vmap(per_bh, in_axes=(1, 1, 1, 1, 0, 0), out_axes=(1, 0)),
        in_axes=(0, 0, 0, 0, None, 0),
        out_axes=(0, 0),
    )
    y, s_fin = f(f32(r), f32(k), f32(v), f32(w), f32(u), s0)
    return y.astype(r.dtype), s_fin


def wkv_chunked_jnp(
    r: jnp.ndarray,  # (B, L, H, K)
    k: jnp.ndarray,
    v: jnp.ndarray,  # (B, L, H, V)
    w: jnp.ndarray,  # (B, L, H, K)
    u: jnp.ndarray,  # (H, K)
    chunk: int = 64,
    s0: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked WKV as a scan over chunks (memory-bounded; see mamba2 ref)."""
    from repro.utils import unroll_scans_enabled

    bsz, l, h, kd = r.shape
    vd = v.shape[-1]
    assert l % chunk == 0
    nc = l // chunk
    f32 = lambda x: x.astype(jnp.float32)
    cs = lambda t: jnp.moveaxis(t.reshape(bsz, nc, chunk, *t.shape[2:]), 1, 0)
    rr = cs(f32(r))
    kk = cs(f32(k))
    vv = cs(f32(v))
    lw = cs(jnp.log(jnp.clip(f32(w), 1e-20, 1.0)))
    uf = f32(u)

    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    if s0 is None:
        s0 = jnp.zeros((bsz, h, kd, vd), jnp.float32)

    @jax.checkpoint
    def body(s, inp):
        rc, kc, vc, lwc = inp  # (B,Q,H,K), ..., (B,Q,H,V), (B,Q,H,K)
        cw = jnp.cumsum(lwc, axis=1)  # inclusive
        cw_shift = cw - lwc  # exclusive: cw_{i-1}, 0 at i=0
        total = cw[:, -1]  # (B,H,K)
        diff = cw_shift[:, :, None] - cw[:, None]  # (B,Qi,Qj,H,K)
        # clamp inside exp (masked diffs are positive; see mamba2_ssd/ref.py)
        decay = jnp.exp(jnp.where(mask[None, :, :, None, None], diff, -1e30))
        score = jnp.einsum("bihk,bjhk,bijhk->bijh", rc, kc, decay)
        y = jnp.einsum("bijh,bjhv->bihv", score, vc)
        coeff = jnp.einsum("bihk,hk,bihk->bih", rc, uf, kc)
        y += coeff[..., None] * vc
        y += jnp.einsum("bihk,bhkv->bihv", rc * jnp.exp(cw_shift), s)
        wk = kc * jnp.exp(total[:, None] - cw)  # (B,Q,H,K)
        s_new = jnp.exp(total)[..., None] * s + jnp.einsum(
            "bjhk,bjhv->bhkv", wk, vc
        )
        return s_new, y

    s_fin, ys = jax.lax.scan(body, s0, (rr, kk, vv, lw), unroll=unroll_scans_enabled())
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, l, h, vd)
    return y.astype(r.dtype), s_fin


def wkv_decode_step(
    r: jnp.ndarray,  # (B, H, K)
    k: jnp.ndarray,
    v: jnp.ndarray,  # (B, H, V)
    w: jnp.ndarray,  # (B, H, K)
    u: jnp.ndarray,  # (H, K)
    s: jnp.ndarray,  # (B, H, K, V)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent decode step (long_500k serving path)."""
    f32 = lambda x: x.astype(jnp.float32)
    kv = f32(k)[..., :, None] * f32(v)[..., None, :]  # (B,H,K,V)
    y = jnp.einsum("bhk,bhkv->bhv", f32(r), s + f32(u)[None, :, :, None] * kv)
    s_new = f32(w)[..., :, None] * s + kv
    return y.astype(r.dtype), s_new
