"""Fused Chargax station step — Pallas TPU kernel (DESIGN.md §6).

At 10^5-10^6 parallel environments the station transition is the RL
training-loop inner loop.  This kernel fuses action clipping, the Eq. 5 tree
constraint, and the charging integration into one VMEM-resident pass:

  grid = (n_envs / B_blk,)            # one grid step per env block

Per block, all pole-state slabs (B_blk, P) live in VMEM; the constraint check
is a single (B_blk, P) x (P, Nn) MXU matmul followed by a static min-loop over
the (tiny, padded) node axis; charging is a fused elementwise epilogue.  The
pole axis P is padded to a lane multiple (128) and the node axis Nn to a
sublane multiple (8) by ``ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.chargax_step.ref import BIG


def _chargax_kernel(
    # dynamic state slabs, all (B_blk, P)
    target_ref, occupied_ref, soc_ref, e_remain_ref, cap_ref, rbar_ref, tau_ref,
    grid_cap_ref,  # (B_blk, 128) feeder cap [kW], lane-replicated scalar
    # static params
    voltage_ref,  # (8, P) — row 0 real, sublane-padded
    imax_ref,  # (8, P)
    eff_ref,  # (8, P) storage efficiency (1 cars, eta_b battery)
    power_w_ref,  # (8, P) grid-side watts per charging amp (0 on padding)
    member_t_ref,  # (P, Nn)  — transposed membership for the MXU
    node_budget_ref,  # (8, Nn)
    # outputs, (B_blk, P) unless noted
    current_out, soc_out, e_remain_out, rhat_out, e_pole_out,
    excess_out,  # (B_blk, 128) lane-replicated scalar
    p_req_out,  # (B_blk, 128) lane-replicated scalar [kW]
    *,
    dt_hours: float,
    n_nodes: int,
):
    v = voltage_ref[0, :]
    imax = imax_ref[0, :]
    eff = eff_ref[0, :]
    budget = node_budget_ref[0, :]

    soc = soc_ref[...]
    rbar = rbar_ref[...]
    tau = tau_ref[...]
    cap = cap_ref[...]
    e_remain = e_remain_ref[...]
    occ = occupied_ref[...]

    inv_tau = 1.0 / jnp.maximum(1.0 - tau, 1e-6)
    rhat_chg = jnp.where(soc <= tau, rbar, rbar * (1.0 - soc) * inv_tau)
    rhat_dis = jnp.where((1.0 - soc) <= tau, rbar, rbar * soc * inv_tau)

    amp_per_kwh = 1000.0 / jnp.maximum(v * dt_hours, 1e-9)
    up = jnp.minimum(
        jnp.minimum(rhat_chg, imax),
        jnp.minimum(
            e_remain * amp_per_kwh,
            (1.0 - soc) * cap * amp_per_kwh / jnp.maximum(eff, 1e-9),
        ),
    )
    down = -jnp.minimum(
        jnp.minimum(rhat_dis, imax),
        soc * cap * eff * amp_per_kwh,
    )
    i = jnp.clip(target_ref[...], down, jnp.maximum(up, 0.0)) * occ

    # --- Eq. 5: (B, P) @ (P, Nn) on the MXU ---------------------------------
    load = jax.lax.dot_general(
        jnp.abs(i), member_t_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (B, Nn)
    s_node = jnp.minimum(1.0, budget / jnp.maximum(load, 1e-9))
    excess = jnp.max(jnp.maximum(load - budget, 0.0), axis=-1, keepdims=True)

    scale = jnp.full_like(i, 1.0)
    for n in range(n_nodes):  # static unroll over the tiny node axis
        row = member_t_ref[:, n]  # (P,)
        scale = jnp.minimum(scale, jnp.where(row > 0, s_node[:, n : n + 1], BIG))
    i = i * scale

    # --- feeder envelope (allocate stage, fused in) ---------------------------
    # Only charging amps draw grid power; unlimited cap -> gscale == 1.0,
    # a bitwise no-op, matching transition.allocate/curtail.
    pw = power_w_ref[0, :]
    p_req = jnp.sum(jnp.maximum(i, 0.0) * pw, axis=-1, keepdims=True) / 1000.0
    gscale = jnp.minimum(1.0, grid_cap_ref[:, :1] / jnp.maximum(p_req, 1e-9))
    i = jnp.where(i > 0.0, i * gscale, i)

    # --- charge epilogue ------------------------------------------------------
    e = v * i * dt_hours / 1000.0
    soc_delta = jnp.where(e >= 0, e * eff, e / jnp.maximum(eff, 1e-9))
    soc_new = jnp.clip(soc + soc_delta / jnp.maximum(cap, 1e-6), 0.0, 1.0)
    headroom = jnp.where(e_remain >= 0.5 * BIG, BIG, (1.0 - soc_new) * cap)
    e_rem_new = jnp.minimum(jnp.maximum(e_remain - e, 0.0), headroom)
    rhat_new = jnp.where(soc_new <= tau, rbar, rbar * (1.0 - soc_new) * inv_tau) * occ

    current_out[...] = i
    soc_out[...] = soc_new
    e_remain_out[...] = e_rem_new
    rhat_out[...] = rhat_new
    e_pole_out[...] = e
    excess_out[...] = jnp.broadcast_to(excess, excess_out.shape)
    p_req_out[...] = jnp.broadcast_to(p_req, p_req_out.shape)


def chargax_fused_step(
    slabs_arrays: tuple[jnp.ndarray, ...],  # 7 x (B, P) in PoleSlabs order
    params_arrays: tuple[jnp.ndarray, ...],  # voltage/imax/eff/power_w (8,P), member_t (P,Nn), budget (8,Nn)
    grid_cap: jnp.ndarray,  # (B, 128) feeder cap [kW], lane-replicated
    *,
    dt_hours: float,
    block_envs: int = 256,
    interpret: bool = False,
):
    b, p = slabs_arrays[0].shape
    member_t = params_arrays[4]
    nn = member_t.shape[1]
    assert b % block_envs == 0, (b, block_envs)

    grid = (b // block_envs,)
    state_spec = pl.BlockSpec((block_envs, p), lambda e: (e, 0))
    scalar_spec = pl.BlockSpec((block_envs, 128), lambda e: (e, 0))
    param_spec_row = pl.BlockSpec((8, p), lambda e: (0, 0))
    kernel = functools.partial(_chargax_kernel, dt_hours=dt_hours, n_nodes=nn)
    out_shapes = [jax.ShapeDtypeStruct((b, p), jnp.float32) for _ in range(5)]
    out_shapes += [jax.ShapeDtypeStruct((b, 128), jnp.float32)] * 2
    out_specs = [state_spec] * 5 + [scalar_spec] * 2

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[state_spec] * 7
        + [scalar_spec]
        + [param_spec_row] * 4
        + [
            pl.BlockSpec((p, nn), lambda e: (0, 0)),
            pl.BlockSpec((8, nn), lambda e: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(*slabs_arrays, grid_cap, *params_arrays)
