"""Jit'd wrapper for the fused Chargax station step.

Builds padded pole slabs from core env structures, dispatches to the Pallas
kernel (TPU) or the jnp reference (CPU / other backends), and unpacks results
back into env-shaped pieces.  The battery is pole index ``n_evse``
(the paper's (N+1)-th pole).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import EnvParams, EnvState
from repro.kernels.chargax_step import ref
from repro.kernels.chargax_step.kernel import chargax_fused_step
from repro.kernels.chargax_step.ref import BIG, FusedOut, PoleParams, PoleSlabs


def _pad_lanes(x: np.ndarray | jnp.ndarray, target: int, fill=0.0):
    pad = target - x.shape[-1]
    if pad <= 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths, constant_values=fill)


def build_pole_params(params: EnvParams, n_pad: int | None = None) -> PoleParams:
    """Lift EnvParams into lane-padded PoleParams (poles = EVSEs + battery)."""
    n = params.evse_voltage.shape[0]
    p = n_pad or ((n + 1 + 127) // 128 * 128)

    voltage = _pad_lanes(jnp.append(params.evse_voltage, params.batt_voltage), p, 1.0)
    imax = _pad_lanes(jnp.append(params.evse_max_current, params.batt_max_current), p)
    ones = jnp.ones((n,), jnp.float32)
    eff = _pad_lanes(jnp.append(ones, params.batt_eff), p, 1.0)

    nn_real, n_leaf = params.member.shape  # member already has the battery col
    nn = (nn_real + 7) // 8 * 8
    member = jnp.zeros((nn, p), jnp.float32).at[:nn_real, : n + 1].set(params.member)
    budget = jnp.full((nn,), BIG, jnp.float32).at[:nn_real].set(params.node_budget)
    return PoleParams(voltage, imax, eff, member, budget)


def build_slabs(
    params: EnvParams,
    state: EnvState,
    target_evse: jnp.ndarray,
    target_batt: jnp.ndarray,
    pp: PoleParams,
) -> PoleSlabs:
    """Build (..., P) pole slabs from env state (leading dims = env batch)."""
    p = pp.voltage.shape[-1]

    def cat(evse_val, batt_scalar, fill=0.0):
        batt = jnp.broadcast_to(batt_scalar, target_batt.shape)
        x = jnp.concatenate([evse_val, batt[..., None]], axis=-1)
        return _pad_lanes(x, p, fill)

    return PoleSlabs(
        target=cat(target_evse, target_batt * 1.0),
        occupied=cat(state.occupied, 1.0),
        soc=cat(state.soc, state.batt_soc),
        e_remain=cat(state.e_remain, BIG),
        cap=cat(state.cap, params.batt_capacity),
        rbar=cat(state.rbar, params.batt_max_current),
        tau=cat(state.tau, params.batt_tau),
    )


def fused_step(
    params: EnvParams,
    state: EnvState,
    target_evse: jnp.ndarray,  # (..., N)
    target_batt: jnp.ndarray,  # (...,)
    dt_hours: float,
    *,
    impl: str = "auto",  # auto | pallas | interpret | ref
    block_envs: int = 256,
) -> FusedOut:
    """Stages 1-2 of the transition for a (possibly batched) env state.

    Returns pole-indexed FusedOut; callers slice [..., :N] for EVSEs and
    [..., N] for the battery.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    pp = build_pole_params(params)
    slabs = build_slabs(params, state, target_evse, target_batt, pp)

    if impl == "ref":
        return ref.fused_step_ref(slabs, pp, dt_hours)

    # pallas path: flatten env batch, pad to block multiple
    lead = slabs.soc.shape[:-1]
    p = slabs.soc.shape[-1]
    b = int(np.prod(lead)) if lead else 1
    bp = (b + block_envs - 1) // block_envs * block_envs

    def flat(x):
        x = x.reshape(b, p)
        return jnp.pad(x, ((0, bp - b), (0, 0)))

    slab_arrays = tuple(flat(x) for x in slabs)
    nn = pp.member.shape[0]

    def sub(x):  # params rows padded to 8 sublanes
        return jnp.broadcast_to(x, (8,) + x.shape)

    param_arrays = (
        sub(pp.voltage), sub(pp.imax), sub(pp.eff),
        pp.member.T, sub(pp.node_budget),
    )
    outs = chargax_fused_step(
        slab_arrays,
        param_arrays,
        dt_hours=dt_hours,
        block_envs=block_envs,
        interpret=(impl == "interpret"),
    )
    current, soc, e_remain, rhat, e_pole, excess = outs
    shape = lead + (p,)
    return FusedOut(
        current=current[:b].reshape(shape),
        soc=soc[:b].reshape(shape),
        e_remain=e_remain[:b].reshape(shape),
        rhat=rhat[:b].reshape(shape),
        e_pole=e_pole[:b].reshape(shape),
        excess=excess[:b, 0].reshape(lead),
    )
