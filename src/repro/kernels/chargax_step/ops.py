"""Jit'd wrapper for the fused Chargax station step.

Builds padded pole slabs from core env structures, dispatches to the Pallas
kernel (TPU/GPU) or the jnp reference (CPU / other backends), and unpacks
results back into env-shaped pieces.  The battery is pole index ``n_evse``
(the paper's (N+1)-th pole).

Two granularities are exposed:

- :func:`fused_step` — pole-slab in, pole-slab out; the kernel-parity
  surface (``tests/kernels``).
- :func:`fused_transition` — EnvState in, ``(AllocationResult,
  ChargeResult)`` out; the hot-path entry :meth:`ChargaxEnv.step` routes
  through when ``EnvConfig.fused_step`` is on.  On CPU it runs
  :func:`fused_request` (bit-identical to the staged ``apply_actions`` —
  natural-shape clips, padded-matmul Eq. 5) plus the staged
  allocate/deliver stages; on TPU/GPU it runs the Pallas slab kernel and
  reuses :func:`repro.core.transition.charge_bookkeeping` for the state
  assembly.

Backend dispatch (:func:`resolve_impl`) honours the ``CHARGAX_FUSED_IMPL``
environment variable (``pallas`` | ``interpret`` | ``ref``) so CI can force
Pallas interpret mode on CPU.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import EnvParams, EnvState
from repro.core.transition import (
    AllocationResult,
    AppliedActions,
    ChargeResult,
    allocate,
    charge_bookkeeping,
    charge_cars,
    constraint_scale,
    grid_cap_kw,
    pole_bounds,
    pole_clip,
)
from repro.kernels.chargax_step import ref
from repro.kernels.chargax_step.kernel import chargax_fused_step
from repro.kernels.chargax_step.ref import BIG, FusedOut, PoleParams, PoleSlabs

IMPL_ENV_VAR = "CHARGAX_FUSED_IMPL"


def resolve_impl(impl: str = "auto") -> str:
    """Resolve the fused-step backend: pallas | interpret | ref.

    ``auto`` picks the Pallas kernel on TPU/GPU and the jnp reference on
    CPU (where the reference is also the bit-exact choice — see
    :func:`fused_request`).  The ``CHARGAX_FUSED_IMPL`` env var overrides
    ``auto`` (CI uses it to exercise Pallas interpret mode on CPU).
    """
    if impl != "auto":
        return impl
    forced = os.environ.get(IMPL_ENV_VAR, "").strip().lower()
    if forced in ("pallas", "interpret", "ref"):
        return forced
    return "pallas" if jax.default_backend() in ("tpu", "gpu") else "ref"


def _pad_lanes(x: np.ndarray | jnp.ndarray, target: int, fill=0.0):
    pad = target - x.shape[-1]
    if pad <= 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths, constant_values=fill)


def build_pole_params(params: EnvParams, n_pad: int | None = None) -> PoleParams:
    """Lift EnvParams into lane-padded PoleParams (poles = EVSEs + battery).

    When ``EnvConfig.fused_step`` hoisted the pack at ``make_params`` time it
    lives on ``params.pole`` and is returned as-is — per-step callers never
    rebuild it.
    """
    if params.pole is not None and n_pad is None:
        return params.pole
    n = params.evse_voltage.shape[0]
    p = n_pad or ((n + 1 + 127) // 128 * 128)

    voltage = _pad_lanes(jnp.append(params.evse_voltage, params.batt_voltage), p, 1.0)
    imax = _pad_lanes(jnp.append(params.evse_max_current, params.batt_max_current), p)
    ones = jnp.ones((n,), jnp.float32)
    eff = _pad_lanes(jnp.append(ones, params.batt_eff), p, 1.0)
    # grid-side watts per charging amp (requested_power_kw's per-lane factor)
    power_w = _pad_lanes(
        jnp.append(
            params.evse_voltage / jnp.maximum(params.evse_path_eff, 1e-9),
            jnp.asarray(params.batt_voltage, jnp.float32),
        ),
        p,
    )

    nn_real, n_leaf = params.member.shape  # member already has the battery col
    nn = (nn_real + 7) // 8 * 8
    member = jnp.zeros((nn, p), jnp.float32).at[:nn_real, : n + 1].set(params.member)
    budget = jnp.full((nn,), BIG, jnp.float32).at[:nn_real].set(params.node_budget)
    return PoleParams(voltage, imax, eff, member, budget, power_w)


def build_slabs(
    params: EnvParams,
    state: EnvState,
    target_evse: jnp.ndarray,
    target_batt: jnp.ndarray,
    pp: PoleParams,
) -> PoleSlabs:
    """Build (..., P) pole slabs from env state (leading dims = env batch)."""
    p = pp.voltage.shape[-1]

    def cat(evse_val, batt_scalar, fill=0.0):
        batt = jnp.broadcast_to(batt_scalar, target_batt.shape)
        x = jnp.concatenate([evse_val, batt[..., None]], axis=-1)
        return _pad_lanes(x, p, fill)

    return PoleSlabs(
        target=cat(target_evse, target_batt * 1.0),
        occupied=cat(state.occupied, 1.0),
        soc=cat(state.soc, state.batt_soc),
        e_remain=cat(state.e_remain, BIG),
        cap=cat(state.cap, params.batt_capacity),
        rbar=cat(state.rbar, params.batt_max_current),
        tau=cat(state.tau, params.batt_tau),
    )


def fused_step(
    params: EnvParams,
    state: EnvState,
    target_evse: jnp.ndarray,  # (..., N)
    target_batt: jnp.ndarray,  # (...,)
    dt_hours: float,
    *,
    cap_kw: jnp.ndarray | None = None,  # (...,) feeder cap [kW]; None = unlimited
    impl: str = "auto",  # auto | pallas | interpret | ref
    block_envs: int = 256,
) -> FusedOut:
    """Stages 1-3 of the transition for a (possibly batched) env state.

    Returns pole-indexed FusedOut; callers slice [..., :N] for EVSEs and
    [..., N] for the battery.
    """
    impl = resolve_impl(impl)
    pp = build_pole_params(params)
    slabs = build_slabs(params, state, target_evse, target_batt, pp)

    if impl == "ref":
        return ref.fused_step_ref(slabs, pp, dt_hours, cap_kw)

    # pallas path: flatten env batch, pad to block multiple.  The block
    # adapts downward so a per-env call under vmap (b == 1) pads to the
    # 8-sublane minimum tile, not to 256 envs.
    lead = slabs.soc.shape[:-1]
    p = slabs.soc.shape[-1]
    b = int(np.prod(lead)) if lead else 1
    block = min(block_envs, (b + 7) // 8 * 8)
    bp = (b + block - 1) // block * block

    def flat(x):
        x = x.reshape(b, p)
        return jnp.pad(x, ((0, bp - b), (0, 0)))

    slab_arrays = tuple(flat(x) for x in slabs)

    cap = jnp.full(lead, BIG, jnp.float32) if cap_kw is None else cap_kw
    cap = jnp.broadcast_to(jnp.asarray(cap, jnp.float32), lead).reshape(b, 1)
    cap = jnp.pad(cap, ((0, bp - b), (0, 0)), constant_values=BIG)
    cap = jnp.broadcast_to(cap, (bp, 128))

    def sub(x):  # params rows padded to 8 sublanes
        return jnp.broadcast_to(x, (8,) + x.shape)

    param_arrays = (
        sub(pp.voltage), sub(pp.imax), sub(pp.eff), sub(pp.power_w),
        pp.member.T, sub(pp.node_budget),
    )
    outs = chargax_fused_step(
        slab_arrays,
        param_arrays,
        cap,
        dt_hours=dt_hours,
        block_envs=block,
        interpret=(impl == "interpret"),
    )
    current, soc, e_remain, rhat, e_pole, excess, p_req = outs
    shape = lead + (p,)
    return FusedOut(
        current=current[:b].reshape(shape),
        soc=soc[:b].reshape(shape),
        e_remain=e_remain[:b].reshape(shape),
        rhat=rhat[:b].reshape(shape),
        e_pole=e_pole[:b].reshape(shape),
        excess=excess[:b, 0].reshape(lead),
        p_req=p_req[:b, 0].reshape(lead),
    )


def fused_request(
    params: EnvParams,
    state: EnvState,
    target_evse: jnp.ndarray,
    target_batt: jnp.ndarray,
    dt_hours: float,
) -> AppliedActions:
    """Bit-exact fused form of the staged ``apply_actions`` request stage.

    Bounds/clip/battery/Eq. 5 all run the staged pipeline's own helpers at
    their natural shapes, so XLA lowers the fused route identically to the
    staged one — parity is structural, not a tolerance.  (The padded-matmul
    Eq. 5 reduction lives only in the slab kernel path, where the MXU's
    reduction order is covered by fp32 tolerance, not bitwise equality:
    XLA's natural-shape matvec and the 128-lane vecmat associate the sum
    differently for some inputs.)
    """
    up, down = pole_bounds(
        state.soc, state.e_remain, state.cap, state.rbar, state.tau,
        params.evse_voltage, params.evse_max_current, 1.0, dt_hours,
    )
    i_evse = pole_clip(target_evse, up, down, state.occupied)
    b_up, b_down = pole_bounds(
        state.batt_soc, jnp.float32(BIG), params.batt_capacity,
        params.batt_max_current, params.batt_tau,
        params.batt_voltage, params.batt_max_current,
        params.batt_eff, dt_hours,
    )
    i_batt = pole_clip(target_batt, b_up, b_down, 1.0)

    leaf = jnp.concatenate([i_evse, i_batt[None]])
    scale, excess = constraint_scale(leaf, params.member, params.node_budget)
    leaf = leaf * scale
    return AppliedActions(leaf[:-1], leaf[-1], excess)


def fused_transition(
    params: EnvParams,
    state: EnvState,
    target_evse: jnp.ndarray,
    target_batt: jnp.ndarray,
    dt_hours: float,
    *,
    cap_kw: jnp.ndarray | None = None,
    impl: str = "auto",
    block_envs: int = 256,
) -> tuple[AllocationResult, ChargeResult]:
    """request + allocate + deliver for ONE env state (the step hot path).

    Drop-in replacement for the staged ``apply_actions`` →
    ``transition.allocate`` → ``charge_cars`` sequence.  ``ref`` (CPU
    default) is bit-identical to the staged pipeline; ``pallas`` /
    ``interpret`` run the slab kernel and agree within fp32 op-reorder
    tolerance.
    """
    impl = resolve_impl(impl)
    cap = grid_cap_kw(params, state) if cap_kw is None else cap_kw

    if impl == "ref":
        applied = fused_request(params, state, target_evse, target_batt, dt_hours)
        alloc = allocate(params, state, applied, cap)
        return alloc, charge_cars(params, state, alloc.applied, dt_hours)

    out = fused_step(
        params, state, target_evse, target_batt, dt_hours,
        cap_kw=cap, impl=impl, block_envs=block_envs,
    )
    n = params.evse_voltage.shape[0]
    applied = AppliedActions(out.current[..., :n], out.current[..., n], out.excess)
    alloc = AllocationResult(
        applied=applied,
        power_req_kw=out.p_req,
        power_kw=jnp.minimum(out.p_req, cap),
        cap_kw=cap,
        violation_kw=jnp.maximum(out.p_req - cap, 0.0),
    )
    charged = charge_bookkeeping(
        state,
        applied,
        out.e_pole[..., :n],
        out.soc[..., :n],
        out.e_remain[..., :n],
        out.rhat[..., :n],
        out.e_pole[..., n],
        out.soc[..., n],
    )
    return alloc, charged
