"""Pure-jnp oracle for the fused Chargax station step (stages 1-2 of App. A.2).

Operates on a *unified pole representation*: the station battery is pole
index ``n_evse`` (the paper's "(N+1)-th charging pole"), with per-pole
asymmetric SoC-efficiency vectors:

    cars:    eff_in = eff_out = 1          (port losses live in path_eff)
    battery: eff_in = eta_b, eff_out = 1/eta_b

so one elementwise pipeline serves every pole.  ``poles_from_env`` builds the
padded slabs from core env structures; ``fused_step_ref`` is the oracle the
Pallas kernel must match bit-for-bit (same op order, fp32).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

BIG = 1e30


class PoleSlabs(NamedTuple):
    """Per-pole dynamic state, all (..., P) float32 (P = padded poles)."""

    target: jnp.ndarray  # requested current [A], signed
    occupied: jnp.ndarray
    soc: jnp.ndarray
    e_remain: jnp.ndarray  # kWh (BIG for the battery)
    cap: jnp.ndarray  # kWh
    rbar: jnp.ndarray  # max current [A]
    tau: jnp.ndarray


class PoleParams(NamedTuple):
    """Static per-pole / per-node parameters (P-padded, node-padded)."""

    voltage: jnp.ndarray  # (P,)
    imax: jnp.ndarray  # (P,)
    eff_in: jnp.ndarray  # (P,)
    eff_out: jnp.ndarray  # (P,)
    member: jnp.ndarray  # (Nn, P) 0/1
    node_budget: jnp.ndarray  # (Nn,)  BIG on padding rows


class FusedOut(NamedTuple):
    current: jnp.ndarray  # (..., P) post-constraint amps
    soc: jnp.ndarray
    e_remain: jnp.ndarray
    rhat: jnp.ndarray
    e_pole: jnp.ndarray  # (..., P) kWh delivered (signed, pole-side)
    excess: jnp.ndarray  # (...,) max node violation pre-rescale [A]


def charge_rate(soc, rbar, tau):
    return jnp.where(soc <= tau, rbar, rbar * (1.0 - soc) / jnp.maximum(1.0 - tau, 1e-6))


def fused_step_ref(slabs: PoleSlabs, pp: PoleParams, dt_hours: float) -> FusedOut:
    v = pp.voltage
    amp_per_kwh = 1000.0 / jnp.maximum(v * dt_hours, 1e-9)  # (P,)

    rhat_chg = charge_rate(slabs.soc, slabs.rbar, slabs.tau)
    rhat_dis = charge_rate(1.0 - slabs.soc, slabs.rbar, slabs.tau)

    up = jnp.minimum(
        jnp.minimum(rhat_chg, pp.imax),
        jnp.minimum(
            slabs.e_remain * amp_per_kwh,
            (1.0 - slabs.soc) * slabs.cap * amp_per_kwh / jnp.maximum(pp.eff_in, 1e-9),
        ),
    )
    down = -jnp.minimum(
        jnp.minimum(rhat_dis, pp.imax),
        slabs.soc * slabs.cap * amp_per_kwh / jnp.maximum(pp.eff_out, 1e-9),
    )
    i = jnp.clip(slabs.target, down, jnp.maximum(up, 0.0)) * slabs.occupied

    # --- Eq. 5 tree constraints --------------------------------------------
    load = jnp.abs(i) @ pp.member.T  # (..., Nn)
    s_node = jnp.minimum(1.0, pp.node_budget / jnp.maximum(load, 1e-9))
    excess = jnp.max(jnp.maximum(load - pp.node_budget, 0.0), axis=-1)
    scale = jnp.full_like(i, 1.0)
    for n in range(pp.member.shape[0]):  # static, tiny node count
        scale = jnp.minimum(
            scale, jnp.where(pp.member[n] > 0, s_node[..., n : n + 1], BIG)
        )
    i = i * scale

    # --- charge over dt ------------------------------------------------------
    e = v * i * dt_hours / 1000.0  # kWh, pole-side
    soc_delta = jnp.where(e >= 0, e * pp.eff_in, e * pp.eff_out)
    soc = jnp.clip(slabs.soc + soc_delta / jnp.maximum(slabs.cap, 1e-6), 0.0, 1.0)
    # car lanes: requests grown by discharge clamp at pack headroom (matches
    # core charge_cars); the battery pole (e_remain sentinel BIG) stays BIG
    headroom = jnp.where(
        slabs.e_remain >= 0.5 * BIG, BIG, (1.0 - soc) * slabs.cap
    )
    e_remain = jnp.minimum(jnp.maximum(slabs.e_remain - e, 0.0), headroom)
    rhat = charge_rate(soc, slabs.rbar, slabs.tau) * slabs.occupied
    return FusedOut(i, soc, e_remain, rhat, e, excess)
