"""Pure-jnp oracle for the fused Chargax station step (stages 1-2 of App. A.2).

Operates on a *unified pole representation*: the station battery is pole
index ``n_evse`` (the paper's "(N+1)-th charging pole"), with a per-pole
storage efficiency vector:

    cars:    eff = 1                       (port losses live in path_eff)
    battery: eff = eta_b                   (store eta*E, drain E/eta)

so one elementwise pipeline serves every pole.  The per-pole physics IS the
core staged pipeline's — :func:`repro.core.transition.pole_bounds` /
``pole_clip`` / ``pole_integrate`` are called directly, so kernel/core
parity is structural rather than a hand-kept duplicate; only the Eq. 5 tree
constraint is re-expressed here in its batched matmul form (the shape the
Pallas kernel's MXU pass mirrors).  ``fused_step_ref`` is the oracle the
Pallas kernel must match within fp32 op-reorder tolerance.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.transition import (
    BIG,
    charge_rate,
    pole_bounds,
    pole_clip,
    pole_integrate,
)

__all__ = [
    "BIG",
    "PoleSlabs",
    "PoleParams",
    "FusedOut",
    "charge_rate",
    "fused_step_ref",
]


class PoleSlabs(NamedTuple):
    """Per-pole dynamic state, all (..., P) float32 (P = padded poles)."""

    target: jnp.ndarray  # requested current [A], signed
    occupied: jnp.ndarray
    soc: jnp.ndarray
    e_remain: jnp.ndarray  # kWh (BIG for the battery)
    cap: jnp.ndarray  # kWh
    rbar: jnp.ndarray  # max current [A]
    tau: jnp.ndarray


class PoleParams(NamedTuple):
    """Static per-pole / per-node parameters (P-padded, node-padded)."""

    voltage: jnp.ndarray  # (P,)
    imax: jnp.ndarray  # (P,)
    eff: jnp.ndarray  # (P,) storage efficiency: 1 for cars, eta_b battery
    member: jnp.ndarray  # (Nn, P) 0/1
    node_budget: jnp.ndarray  # (Nn,)  BIG on padding rows
    power_w: jnp.ndarray  # (P,) grid-side watts per charging amp:
    #     evse_voltage/path_eff for EVSE lanes, batt_voltage for the battery
    #     lane, 0 on padding — so p_req = sum(max(i,0) * power_w) / 1000 [kW]


class FusedOut(NamedTuple):
    current: jnp.ndarray  # (..., P) post-constraint amps
    soc: jnp.ndarray
    e_remain: jnp.ndarray
    rhat: jnp.ndarray
    e_pole: jnp.ndarray  # (..., P) kWh delivered (signed, pole-side)
    excess: jnp.ndarray  # (...,) max node violation pre-rescale [A]
    p_req: jnp.ndarray  # (...,) requested grid power [kW] pre-curtail


def fused_step_ref(
    slabs: PoleSlabs,
    pp: PoleParams,
    dt_hours: float,
    cap_kw: jnp.ndarray | None = None,
) -> FusedOut:
    # --- per-pole clips: the core pipeline's shared physics -----------------
    up, down = pole_bounds(
        slabs.soc,
        slabs.e_remain,
        slabs.cap,
        slabs.rbar,
        slabs.tau,
        pp.voltage,
        pp.imax,
        pp.eff,
        dt_hours,
    )
    i = pole_clip(slabs.target, up, down, slabs.occupied)

    # --- Eq. 5 tree constraints (batched matmul form of the core's
    # constraint_scale; the Pallas kernel mirrors this MXU shape) ------------
    load = jnp.abs(i) @ pp.member.T  # (..., Nn)
    s_node = jnp.minimum(1.0, pp.node_budget / jnp.maximum(load, 1e-9))
    excess = jnp.max(jnp.maximum(load - pp.node_budget, 0.0), axis=-1)
    scale = jnp.full_like(i, 1.0)
    for n in range(pp.member.shape[0]):  # static, tiny node count
        scale = jnp.minimum(
            scale, jnp.where(pp.member[n] > 0, s_node[..., n : n + 1], BIG)
        )
    i = i * scale

    # --- feeder envelope (core's allocate stage, folded in) -----------------
    # Only *charging* amps draw grid power; an unlimited cap lowers to
    # scale == 1.0, a bitwise no-op (matching transition.allocate/curtail).
    p_req = jnp.sum(jnp.maximum(i, 0.0) * pp.power_w, axis=-1) / 1000.0
    if cap_kw is not None:
        gscale = jnp.minimum(1.0, cap_kw / jnp.maximum(p_req, 1e-9))
        i = jnp.where(i > 0.0, i * gscale[..., None], i)

    # --- charge over dt (shared integrator) ---------------------------------
    e, soc, e_remain, rhat = pole_integrate(
        slabs.soc,
        slabs.e_remain,
        slabs.cap,
        slabs.rbar,
        slabs.tau,
        slabs.occupied,
        pp.voltage,
        i,
        pp.eff,
        dt_hours,
    )
    return FusedOut(i, soc, e_remain, rhat, e, excess, p_req)
