"""qwen3-4b [dense]: qk-norm, GQA (hf:Qwen/Qwen3-4B family).

36L, d_model=2560, 32H (GQA kv=8), d_ff=9728, vocab=151936.
"""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        act="swiglu",
        tied_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
        qk_norm=True,
        param_dtype="float32",
        compute_dtype="float32",
    )
