"""Architecture registry: ``--arch <id>`` resolution for every launcher.

Each config module exposes ``full_config()`` (the exact assigned public
config) and ``smoke_config()`` (a reduced same-family config for CPU tests).
``applicable_shapes()`` encodes the per-arch shape-applicability rules from
the assignment (DESIGN.md §4): encoder-only would skip decode (none here);
``long_500k`` runs only for sub-quadratic archs (ssm / hybrid / gemma2's
half-sliding-window stack).
"""
from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = [
    "whisper-base",
    "zamba2-1.2b",
    "qwen3-moe-30b-a3b",
    "granite-moe-3b-a800m",
    "qwen3-4b",
    "chatglm3-6b",
    "tinyllama-1.1b",
    "gemma2-9b",
    "chameleon-34b",
    "rwkv6-3b",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "p") for a in ARCH_IDS}

# archs whose long_500k cell runs (sub-quadratic sequence mixing)
LONG_CONTEXT_OK = {"zamba2-1.2b", "rwkv6-3b", "gemma2-9b"}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.smoke_config() if smoke else mod.full_config()


def applicable_shapes(arch: str) -> list[ShapeConfig]:
    out = []
    for name, shape in SHAPES.items():
        if name == "long_500k" and arch not in LONG_CONTEXT_OK:
            continue
        out.append(shape)
    return out


def build_model(cfg: ModelConfig):
    from repro.models.encdec import EncDecLM
    from repro.models.lm import CausalLM

    return EncDecLM(cfg) if cfg.family == "encdec" else CausalLM(cfg)
