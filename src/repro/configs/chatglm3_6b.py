"""chatglm3-6b [dense]: RoPE on half the head dims ("2d"), 2 KV groups
(arXiv:2406.12793).  28L, d_model=4096, 32H (GQA kv=2), d_ff=13696, vocab=65024.
"""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        vocab=65024,
        rope_mode="half",
        act="swiglu",
        tied_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        rope_mode="half",
        param_dtype="float32",
        compute_dtype="float32",
        tied_embeddings=False,
    )
