"""zamba2-1.2b [hybrid]: Mamba2 blocks + one weight-shared attention block
(arXiv:2411.15242).  38L, d_model=2048, shared attn 32H (kv=32), d_ff=8192,
vocab=32000, ssm_state=64.  Shared block applied every 6 Mamba blocks."""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32000,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        shared_attn_every=6,
        act="swiglu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        ssm_state=16,
        ssm_head_dim=32,
        ssm_expand=2,
        shared_attn_every=2,
        param_dtype="float32",
        compute_dtype="float32",
    )
