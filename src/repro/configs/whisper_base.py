"""whisper-base [audio]: enc-dec, conv frontend stubbed (arXiv:2212.04356).

6L encoder + 6L decoder, d_model=512, 8 heads (kv=8), d_ff=2048, vocab=51865.
Deviation: sinusoidal positions extended beyond Whisper's 448 text positions
to serve the assigned 32k shapes (DESIGN.md §4).
"""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="encdec",
        n_layers=6,
        n_enc_layers=6,
        enc_seq=1500,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab=51865,
        act="gelu",
        rope_mode="none",
        tied_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base-smoke",
        family="encdec",
        n_layers=2,
        n_enc_layers=2,
        enc_seq=32,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        act="gelu",
        rope_mode="none",
        param_dtype="float32",
        compute_dtype="float32",
    )
