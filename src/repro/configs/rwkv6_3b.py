"""rwkv6-3b [ssm]: "Finch" — attention-free, data-dependent decay
(arXiv:2404.05892).  32L, d_model=2560, d_ff=8960, vocab=65536.
"""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,  # = d_model / rwkv_head_dim
        n_kv_heads=40,
        d_ff=8960,
        vocab=65536,
        rwkv_head_dim=64,
        tied_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        rwkv_head_dim=32,
        param_dtype="float32",
        compute_dtype="float32",
        tied_embeddings=False,
    )
