"""gemma2-9b [dense]: alternating local(4096)/global attention, logit
softcaps, sandwich RMSNorm, GeGLU (arXiv:2408.00118).

42L, d_model=3584, 16H (GQA kv=8, head_dim=256), d_ff=14336, vocab=256000.
long_500k RUNS: half the stack is sliding-window; global layers pay full-KV
decode reads (DESIGN.md §4).
"""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab=256000,
        act="geglu",
        window=4096,
        alt_local_global=True,
        sandwich_norm=True,
        attn_softcap=50.0,
        final_softcap=30.0,
        tied_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke",
        family="dense",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
        act="geglu",
        window=16,
        alt_local_global=True,
        sandwich_norm=True,
        attn_softcap=50.0,
        final_softcap=30.0,
        param_dtype="float32",
        compute_dtype="float32",
    )
