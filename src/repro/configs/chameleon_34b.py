"""chameleon-34b [vlm]: early-fusion — VQ image tokens are ordinary vocab
entries; the image tokenizer is a stub (arXiv:2405.09818).

48L, d_model=8192, 64H (GQA kv=8), d_ff=22016, vocab=65536, qk-norm.
"""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        family="dense",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab=65536,
        qk_norm=True,
        act="swiglu",
        tied_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        qk_norm=True,
        param_dtype="float32",
        compute_dtype="float32",
        tied_embeddings=False,
    )
