"""qwen3-moe-30b-a3b [moe]: 128 experts top-8 (hf:Qwen/Qwen3-30B-A3B).

48L, d_model=2048, 32H (GQA kv=4), per-expert d_ff=768, vocab=151936, qk-norm.
"""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        d_ff_expert=768,
        n_experts=128,
        top_k=8,
        vocab=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        act="swiglu",
        tied_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        d_ff_expert=96,
        n_experts=8,
        top_k=2,
        router_group=64,
        vocab=256,
        qk_norm=True,
        param_dtype="float32",
        compute_dtype="float32",
        tied_embeddings=False,
    )
