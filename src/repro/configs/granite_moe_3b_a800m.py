"""granite-moe-3b-a800m [moe]: 40 experts top-8 (hf:ibm-granite/granite-3.0-3b-a800m).

32L, d_model=1536, 24H (GQA kv=8), per-expert d_ff=512, vocab=49155.
(The pool comment says "32 experts" but its own spec line says 40e — we follow
the explicit 40e, which matches the 3b-a800m public config; DESIGN.md §4.)
"""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        d_ff_expert=512,
        n_experts=40,
        top_k=8,
        vocab=49155,
        act="swiglu",
        tied_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        d_ff_expert=64,
        n_experts=5,
        top_k=2,
        router_group=32,
        vocab=128,
        param_dtype="float32",
        compute_dtype="float32",
    )
