"""Deterministic synthetic LM data pipeline (offline stand-in for a corpus).

Counter-based (stateless-random): batch ``i`` is a pure function of
(seed, i), so the pipeline state is a single int64 step counter — trivially
checkpointable, shardable and restart-safe (DESIGN.md §5).  Token streams are
Zipf-distributed with a Markov structure so losses behave like text rather
than uniform noise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticTokens:
    """Iterator-free: ``batch(i)`` is jit-friendly and order-independent."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed Zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self.log_probs = jnp.asarray(np.log(probs / probs.sum()), jnp.float32)

    def batch(self, index: jnp.ndarray | int) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.key(cfg.seed), index)
        k1, k2 = jax.random.split(key)
        base = jax.random.categorical(
            k1, self.log_probs, shape=(cfg.batch, cfg.seq_len + 1)
        )
        # light Markov structure: with p=0.3 repeat previous token + 1
        rep = jax.random.bernoulli(k2, 0.3, (cfg.batch, cfg.seq_len + 1))
        shifted = jnp.roll(base, 1, axis=1) + 1
        stream = jnp.where(rep, jnp.mod(shifted, cfg.vocab), base).astype(jnp.int32)
        return {"tokens": stream[:, :-1], "labels": stream[:, 1:]}

    def frames(self, index: jnp.ndarray | int, enc_seq: int, d_model: int) -> jnp.ndarray:
        """Stub audio/image frontend features for enc-dec archs."""
        key = jax.random.fold_in(jax.random.key(self.cfg.seed ^ 0xF00D), index)
        return jax.random.normal(key, (self.cfg.batch, enc_seq, d_model), jnp.float32)
