"""Data layer: synthetic pipelines + real-data ingest.

* :mod:`repro.data.pipeline` — deterministic synthetic LM token streams
  (counter-based, checkpoint-free).
* :mod:`repro.data.ingest` — offline loaders for real exogenous series
  (ENTSO-E day-ahead prices, PVGIS hourly solar) feeding the scenario DSL.
"""
