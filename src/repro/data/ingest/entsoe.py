"""ENTSO-E day-ahead price ingest (transparency-platform CSV + API XML).

Parses the two export formats the `ENTSO-E transparency platform
<https://transparency.entsoe.eu>`_ hands out for *Day-ahead Prices* —
the web UI's CSV (local-clock ``MTU (CET/CEST)`` ranges, ``EUR/MWh``) and
the REST API's ``Publication_MarketDocument`` XML (UTC periods with
positioned points) — into the canonical ``(365, steps_per_day)`` table the
scenario DSL lowers into ``EnvParams.price_buy_table``.

Normalisation (shared machinery in :mod:`repro.data.ingest.resample`):
DST-transition days are regularised to 24 local hours (the fall-back
duplicate hour is averaged, the spring-forward hole interpolated), ``N/A``
gaps are linearly interpolated, Feb 29 is dropped, EUR/MWh becomes EUR/kWh,
and hourly MTUs are regridded to any ``dt_minutes`` conserving the daily
time-weighted average.

Doctest (CSV shape is the platform's own, inline here so it runs offline):

    >>> csv = '\\n'.join([
    ...     '"MTU (CET/CEST)","Day-ahead Price [EUR/MWh]","Currency","BZN|NL"',
    ...     '"01.01.2024 00:00 - 01.01.2024 01:00","50.00","EUR","NL"',
    ...     '"01.01.2024 01:00 - 01.01.2024 02:00","N/A","EUR","NL"',
    ...     '"01.01.2024 02:00 - 01.01.2024 03:00","80.00","EUR","NL"'])
    >>> recs = parse_csv(csv)
    >>> [(h, round(v, 4)) for _, h, v in recs if v == v]  # N/A -> NaN
    [(0, 0.05), (2, 0.08)]
    >>> table = price_table(csv, dt_minutes=60.0)         # gap interpolated
    >>> round(float(table[0, 1]), 4)                      # EUR/kWh
    0.065
"""
from __future__ import annotations

import datetime as dt
import re
import xml.etree.ElementTree as ET

import numpy as np

from repro.data.ingest import resample

EUR_PER_MWH_TO_EUR_PER_KWH = 1e-3

# "01.01.2024 00:00" (web CSV) or "2024-01-01T00:00" / "2024-01-01 00:00"
_TS_EU = re.compile(r"(\d{2})\.(\d{2})\.(\d{4})\s+(\d{2}):(\d{2})")
_TS_ISO = re.compile(r"(\d{4})-(\d{2})-(\d{2})[T ](\d{2}):(\d{2})")
_MISSING = {"", "-", "n/a", "n/e", "null"}


def _parse_stamp(cell: str) -> tuple[dt.date, int] | None:
    m = _TS_EU.search(cell)
    if m:
        d, mo, y, h, _ = (int(g) for g in m.groups())
        return dt.date(y, mo, d), h
    m = _TS_ISO.search(cell)
    if m:
        y, mo, d, h, _ = (int(g) for g in m.groups())
        return dt.date(y, mo, d), h
    return None


def _parse_value(cell: str) -> float:
    cell = cell.strip().strip('"')
    if cell.lower() in _MISSING:
        return float("nan")
    try:
        return float(cell.replace(",", "."))
    except ValueError:
        return float("nan")


def parse_csv(text: str) -> list[tuple[dt.date, int, float]]:
    """``(local date, local hour, EUR/kWh)`` rows from a web-UI CSV export.

    Column detection is header-driven (the MTU/timestamp column and the
    ``[EUR/MWh]`` price column), falling back to the first two columns, so
    region variants of the export parse without configuration.  Values keep
    the local clock exactly as exported: DST artefacts (23/25-hour days) are
    preserved here and regularised later by ``canonical_year``.
    """
    lines = [ln for ln in text.splitlines() if ln.strip()]
    if not lines:
        raise ValueError("empty ENTSO-E CSV")
    delim = ";" if lines[0].count(";") > lines[0].count(",") else ","
    header = [c.strip().strip('"') for c in lines[0].split(delim)]
    t_col, p_col = 0, 1
    for i, cell in enumerate(header):
        low = cell.lower()
        if "mtu" in low or "time" in low:
            t_col = i
        if "eur/mwh" in low or "price" in low:
            p_col = i
    records = []
    for ln in lines[1:]:
        cells = ln.split(delim)
        if len(cells) <= max(t_col, p_col):
            continue
        stamp = _parse_stamp(cells[t_col])
        if stamp is None:
            continue
        date, hour = stamp
        value = _parse_value(cells[p_col]) * EUR_PER_MWH_TO_EUR_PER_KWH
        records.append((date, hour, value))
    if not records:
        raise ValueError("no price rows found in ENTSO-E CSV")
    return records


def _eu_dst_active(stamp_utc: dt.datetime) -> bool:
    """EU summer time: last Sunday of March 01:00 UTC to last Sunday of
    October 01:00 UTC (all EU bidding zones switch simultaneously)."""

    def last_sunday(year: int, month: int) -> dt.datetime:
        d = dt.date(year, month + 1, 1) - dt.timedelta(days=1)
        d -= dt.timedelta(days=(d.weekday() + 1) % 7)
        return dt.datetime(d.year, d.month, d.day, 1)

    return (
        last_sunday(stamp_utc.year, 3)
        <= stamp_utc
        < last_sunday(stamp_utc.year, 10)
    )


def parse_xml(
    text: str, tz_offset_hours: int = 1, observe_eu_dst: bool = True
) -> list[tuple[dt.date, int, float]]:
    """``(local date, local hour, EUR/kWh)`` rows from an API XML document.

    The API's ``Publication_MarketDocument`` carries UTC period starts with
    1-based point positions at a fixed resolution; ``tz_offset_hours`` is
    the bidding zone's *standard-time* offset (CET = +1) and, because
    day-ahead prices follow the DST-observing civil clock (the web CSV
    export's clock), the EU summer-time hour is added on top while it is in
    force — so XML and CSV exports of the same data land in the same
    columns.  Pass ``observe_eu_dst=False`` for zones without DST.  Points
    may be omitted under the A03 curve profile (a value repeats until the
    next position, or to the period end for trailing omissions) — handled
    by forward-filling positions up to the declared ``timeInterval`` end.
    """
    root = ET.fromstring(text)

    def strip(tag: str) -> str:
        return tag.rsplit("}", 1)[-1]

    records: list[tuple[dt.date, int, float]] = []
    for period in root.iter():
        if strip(period.tag) != "Period":
            continue
        start = end = resolution = None
        points: list[tuple[int, float]] = []
        for el in period.iter():
            t = strip(el.tag)
            if t in ("start", "end"):
                m = _TS_ISO.search(el.text or "")
                if m:
                    y, mo, d, h, _ = (int(g) for g in m.groups())
                    stamp = dt.datetime(y, mo, d, h)
                    start = stamp if t == "start" else start
                    end = stamp if t == "end" else end
            elif t == "resolution":
                resolution = (el.text or "").strip()
            elif t == "Point":
                pos = amount = None
                for sub in el:
                    if strip(sub.tag) == "position":
                        pos = int(sub.text)
                    elif strip(sub.tag) == "price.amount":
                        amount = float(sub.text)
                if pos is not None and amount is not None:
                    points.append((pos, amount))
        if start is None or not points:
            continue
        if resolution not in (None, "PT60M"):
            raise ValueError(f"unsupported ENTSO-E resolution {resolution!r}")
        points.sort()
        # period length from the declared interval when present: under the
        # A03 curve profile even *trailing* positions may be omitted (the
        # last value repeats to the period end), so the last point's
        # position alone can undercount the hours
        n = points[-1][0]
        if end is not None:
            n = max(n, int((end - start).total_seconds() // 3600))
        dense = dict(points)
        value = points[0][1]
        for pos in range(1, n + 1):
            value = dense.get(pos, value)  # A03: repeat until next position
            stamp_utc = start + dt.timedelta(hours=pos - 1)
            offset = tz_offset_hours
            if observe_eu_dst and _eu_dst_active(stamp_utc):
                offset += 1
            stamp = stamp_utc + dt.timedelta(hours=offset)
            records.append(
                (stamp.date(), stamp.hour, value * EUR_PER_MWH_TO_EUR_PER_KWH)
            )
    if not records:
        raise ValueError("no Period/Point data found in ENTSO-E XML")
    return records


def price_table(
    text: str, dt_minutes: float, tz_offset_hours: int = 1
) -> np.ndarray:
    """Canonical ``(365, steps_per_day)`` EUR/kWh table from CSV or XML text.

    ``tz_offset_hours`` applies to XML only (API timestamps are UTC); the
    web CSV already carries the local clock.
    """
    stripped = text.lstrip()
    if stripped.startswith("<"):
        records = parse_xml(stripped, tz_offset_hours=tz_offset_hours)
    else:
        records = parse_csv(text)
    hourly = resample.canonical_year(records)
    spd = int(round(24 * 60 / dt_minutes))
    return resample.regrid_table(hourly, spd).astype(np.float32)
