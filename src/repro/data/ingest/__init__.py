"""Real-data ingest: offline loaders for ENTSO-E prices and PVGIS solar.

The scenario DSL's synthetic generators (:mod:`repro.scenarios.processes`)
and this package meet at one contract: a ``(365, steps_per_day)`` numpy
table per exogenous series.  Loaders here parse real-world export formats —
ENTSO-E day-ahead CSV/XML (:mod:`.entsoe`) and PVGIS hourly JSON/CSV
(:mod:`.pvgis`) — through shared timezone/DST/gap normalisation and
energy-conserving regridding (:mod:`.resample`), so a real table drops into
``EnvParams`` exactly where a synthetic one would and the whole catalog
still compiles once.

Sources are referenced by registry name (vendored sample extracts under
``fixtures/``, always available, never touch the network) or by filesystem
path to a full export you downloaded yourself (``docs/data_provenance.md``
has the fetch recipes).  ``.xz``/``.gz`` files decompress transparently.

    >>> load_price_table("nl_2024", dt_minutes=60.0).shape
    (365, 24)
    >>> shape = load_pv_table("pvgis_nl_delft", dt_minutes=60.0)
    >>> float(shape.max())                  # peak-normalised: kW = shape * peak_kw
    1.0
"""
from __future__ import annotations

import dataclasses
import functools
import gzip
import lzma
import os

import numpy as np

from repro.data.ingest import entsoe, pvgis, resample

FIXTURE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "fixtures")

# hard budget for everything vendored under fixtures/ (tests + CI + the
# regeneration script all enforce this one constant)
FIXTURE_BUDGET_BYTES = 100 * 1024


@dataclasses.dataclass(frozen=True)
class Source:
    """One registered exogenous data source (a vendored sample extract)."""

    kind: str  # "entsoe" | "pvgis"
    filename: str
    description: str
    tz_offset_hours: int = 1  # standard-time offset for UTC-stamped series

    @property
    def path(self) -> str:
        return os.path.join(FIXTURE_DIR, self.filename)


SOURCES: dict[str, Source] = {
    "nl_2024": Source(
        kind="entsoe",
        filename="entsoe_nl_2024.csv.xz",
        description="NL bidding zone day-ahead prices, calendar 2024 "
        "(CET/CEST clock, DST days + N/A gaps preserved)",
    ),
    "pvgis_nl_delft": Source(
        kind="pvgis",
        filename="pvgis_nl_delft.csv.xz",
        description="PVGIS seriescalc CSV, Delft NL (52.0N), hourly 2023",
    ),
    "pvgis_es_seville": Source(
        kind="pvgis",
        filename="pvgis_es_seville.json.xz",
        description="PVGIS seriescalc JSON, Seville ES (37.4N), hourly 2023",
    ),
}


def read_text(path: str) -> str:
    """Read a data file, transparently decompressing ``.xz`` / ``.gz``."""
    with open(path, "rb") as f:
        head = f.read(6)
    if head.startswith(b"\xfd7zXZ\x00"):
        with lzma.open(path, "rt") as f:
            return f.read()
    if head.startswith(b"\x1f\x8b"):
        with gzip.open(path, "rt") as f:
            return f.read()
    with open(path, "r") as f:
        return f.read()


def _resolve(source: str, kind: str, tz_offset_hours: int | None) -> tuple[str, int]:
    """Registry name or filesystem path -> (file path, tz offset).

    An explicit ``tz_offset_hours`` wins; otherwise registry sources carry
    their own offset and bare paths default to CET (+1).
    """
    src = SOURCES.get(source)
    if src is not None:
        if src.kind != kind:
            raise ValueError(
                f"source {source!r} is a {src.kind} source, not {kind}"
            )
        tz = src.tz_offset_hours if tz_offset_hours is None else tz_offset_hours
        return src.path, tz
    if os.path.exists(source):
        return source, 1 if tz_offset_hours is None else tz_offset_hours
    raise KeyError(
        f"unknown {kind} source {source!r}: not a registered name "
        f"({sorted(n for n, s in SOURCES.items() if s.kind == kind)}) "
        "and not an existing file"
    )


@functools.lru_cache(maxsize=None)
def _load_price_cached(
    source: str, dt_minutes: float, tz_offset_hours: int | None
) -> np.ndarray:
    path, tz = _resolve(source, "entsoe", tz_offset_hours)
    return entsoe.price_table(read_text(path), dt_minutes, tz_offset_hours=tz)


@functools.lru_cache(maxsize=None)
def _load_pv_cached(
    source: str, dt_minutes: float, tz_offset_hours: int | None
) -> np.ndarray:
    path, tz = _resolve(source, "pvgis", tz_offset_hours)
    return pvgis.pv_table(read_text(path), dt_minutes, tz_offset_hours=tz)


def load_price_table(
    source: str, dt_minutes: float = 5.0, tz_offset_hours: int | None = None
) -> np.ndarray:
    """``(365, steps_per_day)`` float32 EUR/kWh day-ahead price table.

    ``source`` is a registry name (e.g. ``"nl_2024"``) or a path to an
    ENTSO-E CSV/XML export.  ``tz_offset_hours`` sets the bidding zone's
    standard-time offset for UTC-stamped XML (default: the registry
    source's own offset, or CET +1 for a bare path; the web CSV is already
    local-clock).  Cached per (source, dt, tz): repeated scenario lowering
    is free.  Returns a copy — callers may mutate.
    """
    return _load_price_cached(
        str(source),
        float(dt_minutes),
        None if tz_offset_hours is None else int(tz_offset_hours),
    ).copy()


def load_pv_table(
    source: str, dt_minutes: float = 5.0, tz_offset_hours: int | None = None
) -> np.ndarray:
    """``(365, steps_per_day)`` float32 peak-normalised PV shape table.

    ``source`` is a registry name (e.g. ``"pvgis_nl_delft"``) or a path to
    a PVGIS seriescalc JSON/CSV file.  ``tz_offset_hours`` is the site's
    standard-time offset from the UTC timestamps (default: the registry
    source's own offset, or +1 for a bare path).  Multiply by the plant's
    peak kW to get generation in kW.  Cached per (source, dt, tz); returns
    a copy.
    """
    return _load_pv_cached(
        str(source),
        float(dt_minutes),
        None if tz_offset_hours is None else int(tz_offset_hours),
    ).copy()


def fixture_bytes() -> int:
    """Total size of the vendored extracts (budgeted at FIXTURE_BUDGET_BYTES)."""
    return sum(
        os.path.getsize(os.path.join(FIXTURE_DIR, f))
        for f in os.listdir(FIXTURE_DIR)
    )


def check_fixture_budget(verbose: bool = False) -> int:
    """Assert the vendored extracts fit the budget; returns the total.

    Shared by the test suite, the CI guard step and the fixture
    regeneration script, so the budget lives in exactly one place.
    """
    total = fixture_bytes()
    if verbose:
        for f in sorted(os.listdir(FIXTURE_DIR)):
            print(f"{os.path.getsize(os.path.join(FIXTURE_DIR, f)):>8,}  {f}")
        print(f"{total:>8,}  total (budget {FIXTURE_BUDGET_BYTES:,})")
    if not 0 < total <= FIXTURE_BUDGET_BYTES:
        raise AssertionError(
            f"vendored fixtures at {total:,} bytes exceed the "
            f"{FIXTURE_BUDGET_BYTES:,}-byte budget"
        )
    return total


__all__ = [
    "FIXTURE_BUDGET_BYTES",
    "FIXTURE_DIR",
    "check_fixture_budget",
    "SOURCES",
    "Source",
    "entsoe",
    "fixture_bytes",
    "load_price_table",
    "load_pv_table",
    "pvgis",
    "read_text",
    "resample",
]
