"""Canonicalisation + energy-conserving regridding for ingested time series.

Every loader in this package funnels its rows through the same two stages:

1. :func:`canonical_year` — per-calendar-day hourly records (possibly with
   DST holes/duplicates, ``NaN`` gaps, a leap day, or a partial year) become
   one dense ``(365, 24)`` local-clock table;
2. :func:`regrid_table` — the hourly table is resampled onto the
   environment's ``(365, steps_per_day)`` grid by *integrating* the
   piecewise-constant hourly series, so the daily totals (energy for PV,
   time-weighted average for prices) are conserved at any ``dt_minutes``.

Both are plain numpy and deterministic; doctest-checked:

    >>> import numpy as np
    >>> hourly = np.zeros((1, 24)); hourly[0, 12] = 6.0   # one sunny hour
    >>> fine = regrid_table(hourly, 96)                   # 15-minute grid
    >>> fine.shape
    (1, 96)
    >>> float(fine.sum() * 0.25) == float(hourly.sum() * 1.0)  # kWh conserved
    True
"""
from __future__ import annotations

import datetime as dt

import numpy as np

DAYS_PER_YEAR = 365
HOURS_PER_DAY = 24


def regrid_table(hourly: np.ndarray, steps_per_day: int) -> np.ndarray:
    """Resample ``(days, 24)`` mean-value rows onto ``(days, steps_per_day)``.

    The hourly series is treated as piecewise-constant (each value is the
    mean over its hour — exactly what ENTSO-E MTUs and PVGIS hourly means
    are).  Its running integral is evaluated at the new step edges and
    differenced, which conserves the integral for *any* output resolution:
    upsampling holds values, downsampling takes time-weighted means, and
    grids that straddle hour boundaries split hours proportionally.
    """
    hourly = np.asarray(hourly, dtype=np.float64)
    days, n_in = hourly.shape
    if steps_per_day == n_in:
        return hourly.copy()
    # cumulative integral in units of value * hour, one extra leading zero
    cum = np.concatenate(
        [np.zeros((days, 1)), np.cumsum(hourly, axis=1)], axis=1
    )
    edges = np.linspace(0.0, n_in, steps_per_day + 1)  # in input-step units
    idx = np.minimum(edges.astype(np.int64), n_in - 1)
    frac = edges - idx
    cum_at_edges = cum[:, idx] * (1.0 - frac) + cum[:, idx + 1] * frac
    # mean value per output step = integral over the step / step length
    return np.diff(cum_at_edges, axis=1) * (steps_per_day / n_in)


def canonical_year(
    records: "list[tuple[dt.date, int, float]]",
) -> np.ndarray:
    """Dense ``(365, 24)`` hourly table from raw ``(date, hour, value)`` rows.

    Normalisations applied, in order:

    * **fall-back DST days** (a local hour occurs twice) — duplicates are
      averaged, which conserves the day's time-weighted total;
    * **spring-forward DST days and data gaps** (missing hours, entirely
      missing days inside the observed range, ``NaN`` values) — filled by
      linear interpolation along the flattened year, with edge hold, so
      every calendar day between the first and last record ends up with
      exactly 24 entries and no day silently shifts position;
    * **leap years** — Feb 29 is dropped (the simulator's calendar is a
      fixed 365-day year);
    * **partial years** — the available days are tiled periodically to 365
      (documented escape hatch for small extracts; full-year sources are
      unaffected).
    """
    if not records:
        raise ValueError("no records to canonicalise")
    by_day: dict[dt.date, np.ndarray] = {}
    counts: dict[dt.date, np.ndarray] = {}
    for date, hour, value in records:
        if not 0 <= hour < HOURS_PER_DAY:
            raise ValueError(f"hour {hour} out of range on {date}")
        if date not in by_day:
            by_day[date] = np.zeros(HOURS_PER_DAY)
            counts[date] = np.zeros(HOURS_PER_DAY)
        if np.isfinite(value):
            by_day[date][hour] += value
            counts[date][hour] += 1.0
    # walk the contiguous calendar between the first and last observed date
    # (entirely missing days become NaN rows to interpolate — skipping them
    # would silently shift every later day one index earlier)
    first, last = min(by_day), max(by_day)
    days = [
        first + dt.timedelta(days=i)
        for i in range((last - first).days + 1)
    ]
    days = [d for d in days if not (d.month == 2 and d.day == 29)]
    table = np.full((len(days), HOURS_PER_DAY), np.nan)
    for i, date in enumerate(days):
        if date not in by_day:
            continue
        seen = counts[date] > 0
        table[i, seen] = by_day[date][seen] / counts[date][seen]

    flat = table.reshape(-1)
    holes = np.isnan(flat)
    if holes.all():
        raise ValueError("every record value is missing")
    if holes.any():
        t = np.arange(flat.size)
        flat[holes] = np.interp(t[holes], t[~holes], flat[~holes])
    table = flat.reshape(len(days), HOURS_PER_DAY)

    if len(days) < DAYS_PER_YEAR:
        reps = -(-DAYS_PER_YEAR // len(days))  # ceil
        table = np.tile(table, (reps, 1))
    return table[:DAYS_PER_YEAR]
