"""PVGIS hourly solar ingest (``seriescalc`` JSON + CSV output formats).

Parses the hourly PV-power series the `PVGIS
<https://re.jrc.ec.europa.eu/pvg_tools/en/>`_ ``seriescalc`` tool returns —
either the JSON API document (``outputs.hourly[*].P`` in W) or the CSV
download (prose header lines, a ``time,P,...`` block, prose footer) — into a
canonical **peak-normalised** ``(365, steps_per_day)`` shape table.  The
scenario DSL multiplies it by ``Scenario.pv_peak_kw``, so one vendored site
serves plants of any size and the synthetic/real tables stay interchangeable
(identical shapes, identical units).

Normalisation: PVGIS timestamps are UTC (with a mid-hour minute marker such
as ``:11``); a fixed standard-time ``tz_offset_hours`` rotates the series
onto the site's local clock (solar noon doesn't observe DST, so a fixed
offset is the faithful choice).  Leap days are dropped, gaps interpolated,
hourly means are regridded energy-conservingly to any ``dt_minutes``, and
the result is normalised by its own peak (W cancel out).

Doctest (CSV layout is PVGIS's own, inline so it runs offline):

    >>> csv = '\\n'.join([
    ...     'Latitude (decimal degrees):\\t52.0', '', 'time,P,G(i)',
    ...     '20230701:1011,2500.0,610.0', '20230701:1111,5000.0,790.0',
    ...     '', 'P: PV system power (W)'])
    >>> parse_csv(csv)
    [(datetime.date(2023, 7, 1), 10, 2500.0), (datetime.date(2023, 7, 1), 11, 5000.0)]
    >>> table = pv_table(csv, dt_minutes=60.0, tz_offset_hours=0)
    >>> float(table.max())                      # peak-normalised shape
    1.0
"""
from __future__ import annotations

import datetime as dt
import json
import re

import numpy as np

from repro.data.ingest import resample

# "20230101:0011" — PVGIS compact UTC stamp (minutes are a radiation marker)
_TS = re.compile(r"(\d{4})(\d{2})(\d{2}):(\d{2})(\d{2})")


def _parse_stamp(cell: str) -> tuple[dt.date, int] | None:
    m = _TS.search(cell)
    if not m:
        return None
    y, mo, d, h, _ = (int(g) for g in m.groups())
    return dt.date(y, mo, d), h


def parse_json(text: str) -> list[tuple[dt.date, int, float]]:
    """``(UTC date, UTC hour, watts)`` rows from a seriescalc JSON document."""
    doc = json.loads(text)
    try:
        hourly = doc["outputs"]["hourly"]
    except (KeyError, TypeError):
        raise ValueError("not a PVGIS seriescalc document (no outputs.hourly)")
    records = []
    for row in hourly:
        stamp = _parse_stamp(str(row.get("time", "")))
        if stamp is None:
            continue
        date, hour = stamp
        try:
            watts = float(row["P"])
        except (KeyError, TypeError, ValueError):
            watts = float("nan")
        records.append((date, hour, watts))
    if not records:
        raise ValueError("no hourly rows in PVGIS JSON")
    return records


def parse_csv(text: str) -> list[tuple[dt.date, int, float]]:
    """``(UTC date, UTC hour, watts)`` rows from a seriescalc CSV download.

    The download wraps the data block in prose (site metadata above, column
    legends below); rows are recognised by their timestamp, and the ``P``
    column is located from the ``time,P,...`` header (default: second
    column), so extracts with any subset of the optional columns parse.
    """
    p_col = 1
    records = []
    for ln in text.splitlines():
        cells = [c.strip() for c in ln.split(",")]
        if cells and cells[0].lower() == "time" and "P" in cells:
            p_col = cells.index("P")
            continue
        stamp = _parse_stamp(cells[0]) if cells else None
        if stamp is None:
            continue
        date, hour = stamp
        try:
            watts = float(cells[p_col])
        except (IndexError, ValueError):
            watts = float("nan")
        records.append((date, hour, watts))
    if not records:
        raise ValueError("no hourly rows in PVGIS CSV")
    return records


def pv_table(
    text: str, dt_minutes: float, tz_offset_hours: int = 1
) -> np.ndarray:
    """Peak-normalised ``(365, steps_per_day)`` shape table from JSON or CSV."""
    stripped = text.lstrip()
    if stripped.startswith("{"):
        records = parse_json(stripped)
    else:
        records = parse_csv(text)
    hourly = resample.canonical_year(records)
    # UTC -> site standard time: rotate the flattened year by the offset
    flat = np.roll(hourly.reshape(-1), int(tz_offset_hours))
    hourly = flat.reshape(hourly.shape)
    spd = int(round(24 * 60 / dt_minutes))
    table = resample.regrid_table(hourly, spd)
    peak = float(table.max())
    if peak <= 0.0:
        raise ValueError("PVGIS series is identically zero")
    table = np.maximum(table, 0.0) / peak
    return table.astype(np.float32)
