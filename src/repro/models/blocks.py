"""Per-layer blocks: GQA attention, (MoE-)MLP, Mamba2, RWKV6.

Every block exposes ``init_*`` / ``*_train`` / ``*_decode``:

  * train:  full-sequence causal pass, (B, L, d) -> (B, L, d)
  * decode: single-token pass with an explicit cache pytree,
            (B, 1, d), cache -> (B, 1, d), cache

Blocks of the same kind share a parameter structure so layers stack under
``jax.vmap(init)`` and run under ``jax.lax.scan`` (compact HLO, fast AOT
compiles — essential for the 80-cell dry-run matrix).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mamba2_ssd.ops import ssd, ssd_decode_step
from repro.kernels.rwkv6_wkv.ops import wkv, wkv_decode_step
from repro.models.config import ModelConfig
from repro.models.modules import (
    apply_rope,
    dense_param,
    glu_act,
    rms_norm,
    softcap,
)

NEG_INF = -1e30


# ===========================================================================
# Attention (GQA + qk-norm + sliding window + softcap + RoPE variants)
# ===========================================================================
def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "q_proj": dense_param(ks[0], d, h * hd, dtype),
        "k_proj": dense_param(ks[1], d, hkv * hd, dtype),
        "v_proj": dense_param(ks[2], d, hkv * hd, dtype),
        "o_proj": dense_param(ks[3], h * hd, d, dtype, scale=(h * hd) ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(p, cfg: ModelConfig, x, kv_x=None):
    """Project and reshape to (B, H, L, hd) / (B, Hkv, L, hd)."""
    b, l, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    kv_x = x if kv_x is None else kv_x
    lk = kv_x.shape[1]
    q = (x @ p["q_proj"]).reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    k = (kv_x @ p["k_proj"]).reshape(b, lk, hkv, hd).transpose(0, 2, 1, 3)
    v = (kv_x @ p["v_proj"]).reshape(b, lk, hkv, hd).transpose(0, 2, 1, 3)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attn_train(
    p, x, cfg: ModelConfig, *, window: int | None = None, causal: bool = True,
    positions=None, kv_x=None,
):
    b, l, _ = x.shape
    q, k, v = _qkv(p, cfg, x, kv_x)
    if causal and kv_x is None:
        pos = jnp.arange(l) if positions is None else positions
        q = apply_rope(q, pos, cfg.rope_theta, cfg.rope_mode)
        k = apply_rope(k, pos, cfg.rope_theta, cfg.rope_mode)
    out = flash_attention(
        q, k, v, causal=causal and kv_x is None, window=window,
        softcap=cfg.attn_softcap, scale=cfg.hd**-0.5,
    )
    out = out.transpose(0, 2, 1, 3).reshape(b, l, -1)
    return out @ p["o_proj"]


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    hkv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, hkv, max_len, hd), dtype),
        "v": jnp.zeros((batch, hkv, max_len, hd), dtype),
    }


def attn_decode(
    p, x_t, cache: dict, pos, cfg: ModelConfig, *, window: int | None = None,
):
    """One-token decode against the KV cache.  ``pos``: () int32 current index."""
    b = x_t.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // hkv
    q, k_new, v_new = _qkv(p, cfg, x_t)
    pos_arr = jnp.full((1,), pos, jnp.int32)
    q = apply_rope(q, pos_arr, cfg.rope_theta, cfg.rope_mode)
    k_new = apply_rope(k_new, pos_arr, cfg.rope_theta, cfg.rope_mode)

    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, 0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, 0, pos, 0))

    s_len = k_cache.shape[2]
    qf = q.astype(jnp.float32).reshape(b, hkv, g, hd)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qf, k_cache.astype(jnp.float32)) * cfg.hd**-0.5
    scores = softcap(scores, cfg.attn_softcap)
    idx = jnp.arange(s_len)
    valid = idx <= pos
    if window is not None:
        valid &= idx > pos - window
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", probs, v_cache.astype(jnp.float32))
    out = out.reshape(b, 1, h * hd).astype(x_t.dtype)
    return out @ p["o_proj"], {"k": k_cache, "v": v_cache}


# ===========================================================================
# Dense MLP (SwiGLU / GeGLU / plain GELU for whisper)
# ===========================================================================
def init_mlp(key, cfg: ModelConfig, dtype, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu":
        return {
            "up_proj": dense_param(ks[0], d, ff, dtype),
            "down_proj": dense_param(ks[1], ff, d, dtype, scale=ff**-0.5 / (2 * cfg.n_layers) ** 0.5),
        }
    return {
        "gate_proj": dense_param(ks[0], d, ff, dtype),
        "up_proj": dense_param(ks[1], d, ff, dtype),
        "down_proj": dense_param(ks[2], ff, d, dtype, scale=ff**-0.5 / (2 * cfg.n_layers) ** 0.5),
    }


def mlp_apply(p, x, cfg: ModelConfig):
    if "gate_proj" in p:
        h = glu_act(x @ p["gate_proj"], x @ p["up_proj"], cfg.act)
    else:
        h = jax.nn.gelu(x @ p["up_proj"], approximate=True)
    return h @ p["down_proj"]


# ===========================================================================
# MoE (top-k, GShard-style grouped one-hot dispatch — DESIGN.md §5)
# ===========================================================================
def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 4)
    std_in, std_out = d**-0.5, ff**-0.5 / (2 * cfg.n_layers) ** 0.5
    tn = lambda k, shape, std: (
        jax.random.truncated_normal(k, -3.0, 3.0, shape, jnp.float32) * std
    ).astype(dtype)
    return {
        "router": dense_param(ks[0], d, e, jnp.float32),  # router in fp32
        "expert_w_gate": tn(ks[1], (e, d, ff), std_in),
        "expert_w_up": tn(ks[2], (e, d, ff), std_in),
        "expert_w_down": tn(ks[3], (e, ff, d), std_out),
    }


def moe_apply(p, x, cfg: ModelConfig):
    """Returns (y, aux_loss).  x: (B, L, d).

    Dispatch/combine/expert tensors carry explicit sharding annotations
    (token groups over the data axes, experts over 'model' = EP) — without
    them GSPMD replicates the (g, sg, E, cap) one-hots, which dominated the
    MoE cells' memory (§Perf iteration 2).
    """
    import math

    from repro.distributed.sharding import DP, constrain

    b, l, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tokens = b * l
    sg = cfg.router_group if tokens % cfg.router_group == 0 else math.gcd(tokens, cfg.router_group)
    g = tokens // sg
    cap = max(int(sg * k * cfg.capacity_factor / e), 1)

    # token groups ride the strategy's batch axes (DP sentinel); the dedupe
    # in `constrain` then leaves the expert dim to inherit EP from the
    # weights.  (Pinning groups to data-only axes was REFUTED in
    # §Perf-hillclimb h2: it forces a reshard at every MoE layer.)
    token_axes = DP
    xg = constrain(x.reshape(g, sg, d), token_axes, None, None)
    logits = (xg.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (g, sg, e)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)  # (g, sg, k)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    # slot-sequential dispatch: earlier slots get capacity priority
    counts = jnp.zeros((g, e), jnp.float32)
    combine = jnp.zeros((g, sg, e, cap), jnp.float32)
    dispatch = jnp.zeros((g, sg, e, cap), jnp.float32)
    for j in range(k):
        onehot = jax.nn.one_hot(top_idx[..., j], e, dtype=jnp.float32)  # (g, sg, e)
        pos = counts[:, None, :] + jnp.cumsum(onehot, axis=1) - onehot  # rank
        keep = (pos < cap) * onehot
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        slot = keep[..., None] * pos_oh  # (g, sg, e, cap)
        dispatch = dispatch + slot
        combine = combine + slot * top_vals[..., j][..., None, None]
        counts = counts + onehot.sum(axis=1)

    cd = jnp.bfloat16 if x.dtype == jnp.bfloat16 else jnp.float32
    dispatch = constrain(dispatch.astype(cd), token_axes, None, "model", None)
    combine = constrain(combine.astype(cd), token_axes, None, "model", None)
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, xg.astype(cd))  # (e,g,cap,d)
    expert_in = constrain(expert_in, "model", token_axes, None, None)
    h = glu_act(
        jnp.einsum("egcd,edf->egcf", expert_in, p["expert_w_gate"]),
        jnp.einsum("egcd,edf->egcf", expert_in, p["expert_w_up"]),
        "swiglu",
    )
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["expert_w_down"])
    expert_out = constrain(expert_out, "model", token_axes, None, None)
    y = jnp.einsum("gsec,egcd->gsd", combine, expert_out)

    # Switch-style load-balance aux loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return y.reshape(b, l, d).astype(x.dtype), aux


def moe_decode(p, x_t, cfg: ModelConfig):
    """Single-token MoE: dense top-k gather (tiny batch; no dispatch tensors)."""
    b, l, d = x_t.shape
    k = cfg.top_k
    logits = x_t.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)  # (b, 1, k)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    wg = p["expert_w_gate"][top_idx[:, 0]]  # (b, k, d, ff)
    wu = p["expert_w_up"][top_idx[:, 0]]
    wd = p["expert_w_down"][top_idx[:, 0]]
    xt = x_t[:, 0]  # (b, d)
    h = glu_act(
        jnp.einsum("bd,bkdf->bkf", xt, wg), jnp.einsum("bd,bkdf->bkf", xt, wu), "swiglu"
    )
    y = jnp.einsum("bkf,bkfd->bkd", h, wd)
    y = jnp.einsum("bkd,bk->bd", y, top_vals[:, 0].astype(y.dtype))
    return y[:, None].astype(x_t.dtype)


# ===========================================================================
# Mamba2 block (zamba2's SSM component)
# ===========================================================================
def _mamba_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def init_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_inner, nh, conv_dim = _mamba_dims(cfg)
    proj_out = 2 * d_inner + 2 * cfg.ssm_state + nh  # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    return {
        "ssm_in_proj": dense_param(ks[0], d, proj_out, dtype),
        "ssm_conv": (
            jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32) * 0.1
        ).astype(dtype),
        "ssm_dt_bias": jnp.zeros((nh,), jnp.float32),
        "ssm_a_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "ssm_d_skip": jnp.ones((nh,), jnp.float32),
        "ssm_norm": jnp.ones((d_inner,), dtype),
        "ssm_out_proj": dense_param(
            ks[2], d_inner, d, dtype, scale=d_inner**-0.5 / (2 * cfg.n_layers) ** 0.5
        ),
    }


def _causal_conv(x, w):
    """Depthwise causal 1D conv.  x (B, L, C), w (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out


def _mamba_project(p, x, cfg: ModelConfig):
    d_inner, nh, conv_dim = _mamba_dims(cfg)
    n = cfg.ssm_state
    zxbcdt = x @ p["ssm_in_proj"]
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + conv_dim]
    dt_raw = zxbcdt[..., d_inner + conv_dim :]  # (B, L, nh)
    return z, xbc, dt_raw


def mamba2_train(p, x, cfg: ModelConfig):
    from repro.distributed.sharding import DP, constrain

    b, l, _ = x.shape
    d_inner, nh, conv_dim = _mamba_dims(cfg)
    n, hd = cfg.ssm_state, cfg.ssm_head_dim
    z, xbc, dt_raw = _mamba_project(p, x, cfg)
    # GSPMD loses the batch sharding through the conv/reshape chain without
    # these pins — zamba2 activations replicated per-device otherwise
    # (§Perf iteration 3)
    z = constrain(z, DP, None, None)
    xbc = constrain(jax.nn.silu(_causal_conv(xbc, p["ssm_conv"])), DP, None, None)
    xs = constrain(
        xbc[..., :d_inner].reshape(b, l, nh, hd), DP, None, "model", None
    )
    b_mat = xbc[..., d_inner : d_inner + n]
    c_mat = xbc[..., d_inner + n :]
    dt = constrain(
        jax.nn.softplus(dt_raw.astype(jnp.float32) + p["ssm_dt_bias"]),
        DP, None, "model",
    )
    a = -jnp.exp(p["ssm_a_log"])
    y, _ = ssd(xs, dt, a, b_mat, c_mat)
    y = constrain(y, DP, None, "model", None)
    y = y + xs * p["ssm_d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(b, l, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["ssm_norm"], cfg.norm_eps)
    return y @ p["ssm_out_proj"]


def init_mamba_cache(cfg: ModelConfig, batch: int) -> dict:
    d_inner, nh, conv_dim = _mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), jnp.float32),
        "ssm": jnp.zeros((batch, nh, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
    }


def mamba2_decode(p, x_t, cache: dict, cfg: ModelConfig):
    b = x_t.shape[0]
    d_inner, nh, conv_dim = _mamba_dims(cfg)
    n, hd = cfg.ssm_state, cfg.ssm_head_dim
    z, xbc, dt_raw = _mamba_project(p, x_t, cfg)  # (B, 1, ...)

    window = jnp.concatenate([cache["conv"], xbc.astype(jnp.float32)], axis=1)  # (B, K, C)
    w = p["ssm_conv"].astype(jnp.float32)
    xbc_c = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w))  # (B, C)
    new_conv = window[:, 1:]

    xs = xbc_c[..., :d_inner].reshape(b, nh, hd)
    b_t = xbc_c[..., d_inner : d_inner + n]
    c_t = xbc_c[..., d_inner + n :]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["ssm_dt_bias"])
    a = -jnp.exp(p["ssm_a_log"])
    y, s_new = ssd_decode_step(xs, dt, a, b_t, c_t, cache["ssm"])
    y = y + xs * p["ssm_d_skip"][None, :, None]
    y = y.reshape(b, 1, d_inner).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["ssm_norm"], cfg.norm_eps)
    return y @ p["ssm_out_proj"], {"conv": new_conv, "ssm": s_new}


# ===========================================================================
# RWKV6 block (time-mix with data-dependent decay + channel-mix)
# ===========================================================================
def init_rwkv6(key, cfg: ModelConfig, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    hd = cfg.rwkv_head_dim
    nh = d // hd
    dw = max(d // 16, 32)  # decay-LoRA rank
    ks = jax.random.split(key, 10)
    mix = lambda k_: (jax.random.uniform(k_, (d,), jnp.float32)).astype(jnp.float32)
    return {
        "tm_mix_r": mix(ks[0]) * 0.5,
        "tm_mix_k": mix(ks[1]) * 0.5,
        "tm_mix_v": mix(ks[2]) * 0.5,
        "tm_mix_w": mix(ks[3]) * 0.5,
        "tm_mix_g": mix(ks[4]) * 0.5,
        "r_proj": dense_param(ks[5], d, d, dtype),
        "k_proj": dense_param(ks[6], d, d, dtype),
        "v_proj": dense_param(ks[7], d, d, dtype),
        "g_proj": dense_param(ks[8], d, d, dtype),
        "o_proj": dense_param(ks[9], d, d, dtype, scale=d**-0.5 / (2 * cfg.n_layers) ** 0.5),
        "w_base": jnp.full((d,), -4.0, jnp.float32),  # decay bias (w = exp(-exp(.)))
        "w_lora_a": dense_param(jax.random.fold_in(key, 1), d, dw, jnp.float32),
        "w_lora_b": dense_param(jax.random.fold_in(key, 2), dw, d, jnp.float32) * 0.1,
        "u_bonus": (jax.random.normal(jax.random.fold_in(key, 3), (nh, hd), jnp.float32) * 0.3),
        "wkv_norm": jnp.ones((d,), dtype),
        # channel mix
        "cm_mix_k": mix(jax.random.fold_in(key, 4)) * 0.5,
        "cm_mix_r": mix(jax.random.fold_in(key, 5)) * 0.5,
        "cm_k_proj": dense_param(jax.random.fold_in(key, 6), d, ff, dtype),
        "cm_v_proj": dense_param(
            jax.random.fold_in(key, 7), ff, d, dtype, scale=ff**-0.5 / (2 * cfg.n_layers) ** 0.5
        ),
        "cm_r_proj": dense_param(jax.random.fold_in(key, 8), d, d, dtype),
    }


def _token_shift(x, last=None):
    """x_{t-1} (zeros / ``last`` at t=0).  x: (B, L, d)."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _rwkv_wkv_inputs(p, x, xs, cfg: ModelConfig):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    nh = d // hd
    lerp = lambda mu: x + (xs - x) * mu.astype(x.dtype)
    shape = x.shape[:-1] + (nh, hd)
    r = (lerp(p["tm_mix_r"]) @ p["r_proj"]).reshape(shape)
    k = (lerp(p["tm_mix_k"]) @ p["k_proj"]).reshape(shape)
    v = (lerp(p["tm_mix_v"]) @ p["v_proj"]).reshape(shape)
    g = jax.nn.silu((lerp(p["tm_mix_g"]) @ p["g_proj"]).astype(jnp.float32))
    xw = lerp(p["tm_mix_w"]).astype(jnp.float32)
    w_log = p["w_base"] + jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp(w_log)).reshape(shape)  # data-dependent decay
    return r, k, v, g, w


def rwkv6_time_mix_train(p, x, cfg: ModelConfig):
    b, l, d = x.shape
    xs = _token_shift(x)
    r, k, v, g, w = _rwkv_wkv_inputs(p, x, xs, cfg)
    y, _ = wkv(r, k, v, w, p["u_bonus"])
    y = y.reshape(b, l, d)
    y = rms_norm(y, p["wkv_norm"], cfg.norm_eps)
    y = (y.astype(jnp.float32) * g).astype(x.dtype)
    return y @ p["o_proj"]


def rwkv6_channel_mix_train(p, x, cfg: ModelConfig, last=None):
    xs = _token_shift(x, last)
    lerp = lambda mu: x + (xs - x) * mu.astype(x.dtype)
    kk = jnp.square(jax.nn.relu(lerp(p["cm_mix_k"]) @ p["cm_k_proj"]))
    rr = jax.nn.sigmoid((lerp(p["cm_mix_r"]) @ p["cm_r_proj"]).astype(jnp.float32))
    return (rr * (kk @ p["cm_v_proj"]).astype(jnp.float32)).astype(x.dtype)


def init_rwkv_cache(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    nh = d // hd
    return {
        "tm_last": jnp.zeros((batch, d), jnp.float32),
        "cm_last": jnp.zeros((batch, d), jnp.float32),
        "wkv": jnp.zeros((batch, nh, hd, hd), jnp.float32),
    }


def rwkv6_decode(p, x_t, cache: dict, cfg: ModelConfig):
    """Full RWKV6 layer decode (time-mix + channel-mix with residuals applied
    by the caller around each half)."""
    raise NotImplementedError("decode is assembled in lm.py per half-layer")


def rwkv6_time_mix_decode(p, x_t, cache, cfg: ModelConfig):
    b, _, d = x_t.shape
    xs = cache["tm_last"][:, None].astype(x_t.dtype)
    r, k, v, g, w = _rwkv_wkv_inputs(p, x_t, xs, cfg)
    y, s_new = wkv_decode_step(
        r[:, 0], k[:, 0], v[:, 0], w[:, 0], p["u_bonus"], cache["wkv"]
    )
    y = y.reshape(b, 1, d)
    y = rms_norm(y, p["wkv_norm"], cfg.norm_eps)
    y = (y.astype(jnp.float32) * g).astype(x_t.dtype)
    cache = dict(cache, tm_last=x_t[:, 0].astype(jnp.float32), wkv=s_new)
    return y @ p["o_proj"], cache


def rwkv6_channel_mix_decode(p, x_t, cache, cfg: ModelConfig):
    y = rwkv6_channel_mix_train(
        p, x_t, cfg, last=cache["cm_last"].astype(x_t.dtype)
    )
    cache = dict(cache, cm_last=x_t[:, 0].astype(jnp.float32))
    return y, cache
