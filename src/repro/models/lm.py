"""Decoder-only LM assembly for the dense / moe / ssm / hybrid families.

Layers are weight-stacked (``jax.vmap`` over init) and executed under
``jax.lax.scan`` — compact HLO, fast AOT compiles for the dry-run matrix, and
the natural structure for per-layer remat.  Hybrid (zamba2) runs grouped
scans with a weight-shared attention block between groups.  Gemma2 scans over
(local, global) layer *pairs*.

Decode: the stacked per-layer cache rides through the same scan.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.config import ModelConfig
from repro.models.modules import embed_param, rms_norm, softcap, _dtype


# ---------------------------------------------------------------------------
# per-layer init/apply for each family
# ---------------------------------------------------------------------------
def _init_dense_layer(key, cfg: ModelConfig, dtype) -> dict:
    ka, km = jax.random.split(key)
    p = {
        "attn": blocks.init_attention(ka, cfg, dtype),
        "input_norm": jnp.ones((cfg.d_model,), dtype),
        "pre_mlp_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.family == "moe":
        p["moe"] = blocks.init_moe(km, cfg, dtype)
    else:
        p["mlp"] = blocks.init_mlp(km, cfg, dtype)
    if cfg.sandwich_norm:
        p["post_attn_norm"] = jnp.ones((cfg.d_model,), dtype)
        p["post_mlp_norm"] = jnp.ones((cfg.d_model,), dtype)
    return p


def _dense_layer_train(lp, x, cfg: ModelConfig, window, gemma: bool):
    h = rms_norm(x, lp["input_norm"], cfg.norm_eps, plus_one=gemma)
    a = blocks.attn_train(lp["attn"], h, cfg, window=window)
    if cfg.sandwich_norm:
        a = rms_norm(a, lp["post_attn_norm"], cfg.norm_eps, plus_one=gemma)
    x = x + a
    h = rms_norm(x, lp["pre_mlp_norm"], cfg.norm_eps, plus_one=gemma)
    aux = 0.0
    if "moe" in lp:
        m, aux = blocks.moe_apply(lp["moe"], h, cfg)
    else:
        m = blocks.mlp_apply(lp["mlp"], h, cfg)
    if cfg.sandwich_norm:
        m = rms_norm(m, lp["post_mlp_norm"], cfg.norm_eps, plus_one=gemma)
    return x + m, aux


def _dense_layer_decode(lp, x_t, cache, pos, cfg: ModelConfig, window, gemma: bool):
    h = rms_norm(x_t, lp["input_norm"], cfg.norm_eps, plus_one=gemma)
    a, cache = blocks.attn_decode(lp["attn"], h, cache, pos, cfg, window=window)
    if cfg.sandwich_norm:
        a = rms_norm(a, lp["post_attn_norm"], cfg.norm_eps, plus_one=gemma)
    x_t = x_t + a
    h = rms_norm(x_t, lp["pre_mlp_norm"], cfg.norm_eps, plus_one=gemma)
    if "moe" in lp:
        m = blocks.moe_decode(lp["moe"], h, cfg)
    else:
        m = blocks.mlp_apply(lp["mlp"], h, cfg)
    if cfg.sandwich_norm:
        m = rms_norm(m, lp["post_mlp_norm"], cfg.norm_eps, plus_one=gemma)
    return x_t + m, cache


def _init_rwkv_layer(key, cfg: ModelConfig, dtype) -> dict:
    return {
        "rwkv": blocks.init_rwkv6(key, cfg, dtype),
        "input_norm": jnp.ones((cfg.d_model,), dtype),
        "pre_mlp_norm": jnp.ones((cfg.d_model,), dtype),
    }


def _rwkv_layer_train(lp, x, cfg: ModelConfig):
    x = x + blocks.rwkv6_time_mix_train(
        lp["rwkv"], rms_norm(x, lp["input_norm"], cfg.norm_eps), cfg
    )
    x = x + blocks.rwkv6_channel_mix_train(
        lp["rwkv"], rms_norm(x, lp["pre_mlp_norm"], cfg.norm_eps), cfg
    )
    return x, 0.0


def _rwkv_layer_decode(lp, x_t, cache, cfg: ModelConfig):
    h = rms_norm(x_t, lp["input_norm"], cfg.norm_eps)
    y, cache = blocks.rwkv6_time_mix_decode(lp["rwkv"], h, cache, cfg)
    x_t = x_t + y
    h = rms_norm(x_t, lp["pre_mlp_norm"], cfg.norm_eps)
    y, cache = blocks.rwkv6_channel_mix_decode(lp["rwkv"], h, cache, cfg)
    return x_t + y, cache


def _init_mamba_layer(key, cfg: ModelConfig, dtype) -> dict:
    return {
        "mamba": blocks.init_mamba2(key, cfg, dtype),
        "input_norm": jnp.ones((cfg.d_model,), dtype),
    }


def _mamba_layer_train(lp, x, cfg: ModelConfig):
    return x + blocks.mamba2_train(
        lp["mamba"], rms_norm(x, lp["input_norm"], cfg.norm_eps), cfg
    )


def _mamba_layer_decode(lp, x_t, cache, cfg: ModelConfig):
    y, cache = blocks.mamba2_decode(
        lp["mamba"], rms_norm(x_t, lp["input_norm"], cfg.norm_eps), cache, cfg
    )
    return x_t + y, cache


# ---------------------------------------------------------------------------
# Chunked cross-entropy: never materialises the (tokens, vocab) logits.
# The (B, L, V) fp32 logits tensor was the dominant memory term of every
# train/prefill cell (hundreds of GiB/device for the 256k-vocab archs) —
# scanning the unembed+CE over token chunks with per-chunk remat removes it
# (EXPERIMENTS.md §Perf, iteration 1).
# ---------------------------------------------------------------------------
def _pow2_divisor(n: int, target: int) -> int:
    c = 1
    while c * 2 <= target and n % (c * 2) == 0:
        c *= 2
    return c


def chunked_softmax_xent(
    x: jnp.ndarray,  # (B, L, d) final hidden states
    w: jnp.ndarray,  # (d, V) unembedding
    labels: jnp.ndarray,  # (B, L) int32
    softcap_val: float | None = None,
    chunk_len: int = 512,
    unroll: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (mean nll, mean logz^2) over all tokens.

    Chunks the SEQUENCE axis (scan xs = (B, chunk, d)) so the batch dim — and
    its data-axis sharding — survives into every chunk's logits.
    """
    from repro.distributed.sharding import constrain
    from repro.utils import unroll_scans_enabled

    unroll = unroll or unroll_scans_enabled()
    b, l, d = x.shape
    if unroll:  # probe compiles: fewer, larger chunks keep compile tractable
        chunk_len = max(l // 8, 1)
    chunk = _pow2_divisor(l, min(chunk_len, l))
    n = l // chunk
    xs = jnp.moveaxis(x.reshape(b, n, chunk, d), 1, 0)  # (n, B, chunk, d)
    ls = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)
    # hoist ONE bf16 gather of the (FSDP-sharded) unembedding out of the
    # chunk loop — otherwise every chunk re-gathers it, in f32, which was
    # the dominant collective of the fsdp train cells (§Perf-hillclimb h4).
    # Gated by table size: for 256k-vocab archs (gemma2) replicating the
    # table + its full fp32 cotangent per microbatch costs more memory than
    # the per-chunk gathers save (measured: gemma2 train 19 -> 82 GiB/dev
    # ungated — §Perf iteration 6)
    if w.shape[0] * w.shape[1] <= 4 * 10**8:
        w = constrain(w.astype(x.dtype), None, None)

    def body(carry, inp):
        nll_sum, z_sum = carry
        xc, lc = inp  # (B, chunk, d), (B, chunk)
        logits = (xc @ w.astype(xc.dtype)).astype(jnp.float32)
        logits = softcap(logits, softcap_val)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return (nll_sum + jnp.sum(logz - gold), z_sum + jnp.sum(jnp.square(logz))), None

    body = jax.checkpoint(body)
    (nll_sum, z_sum), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (xs, ls), unroll=unroll
    )
    t = b * l
    return nll_sum / t, z_sum / t


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------
class CausalLM:
    """Functional LM; all methods are jit/vmap-safe pure functions of params."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = _dtype(cfg.param_dtype)
        if cfg.family == "hybrid":
            n = cfg.n_layers
            k = cfg.shared_attn_every
            bounds = list(range(0, n, k)) + [n]
            self.groups = [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]

    # -------------------------- init ---------------------------------
    def init(self, key: jax.Array) -> dict:
        cfg, dtype = self.cfg, self.dtype
        k_embed, k_layers, k_shared, k_out = jax.random.split(key, 4)
        params: dict[str, Any] = {
            "embed": embed_param(k_embed, cfg.vocab, cfg.d_model, dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
        if not cfg.tied_embeddings:
            params["unembed"] = embed_param(k_out, cfg.vocab, cfg.d_model, dtype).T

        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        if cfg.family in ("dense", "moe"):
            if cfg.alt_local_global:
                assert cfg.n_layers % 2 == 0
                pair_keys = layer_keys.reshape(cfg.n_layers // 2, 2)
                init_pair = lambda kk: {
                    "local": _init_dense_layer(kk[0], cfg, dtype),
                    "global": _init_dense_layer(kk[1], cfg, dtype),
                }
                params["layers"] = jax.vmap(init_pair)(pair_keys)
            else:
                params["layers"] = jax.vmap(
                    lambda kk: _init_dense_layer(kk, cfg, dtype)
                )(layer_keys)
        elif cfg.family == "ssm":
            params["layers"] = jax.vmap(lambda kk: _init_rwkv_layer(kk, cfg, dtype))(
                layer_keys
            )
        elif cfg.family == "hybrid":
            params["layers"] = jax.vmap(lambda kk: _init_mamba_layer(kk, cfg, dtype))(
                layer_keys
            )
            shared = _init_dense_layer(k_shared, cfg, dtype)
            params["shared_attn"] = shared
        else:
            raise ValueError(cfg.family)
        return params

    # -------------------------- train forward -------------------------
    def apply_train(self, params: dict, tokens: jnp.ndarray, remat: bool = True, unroll: bool = False):
        """tokens (B, L) int32 -> (logits (B, L, V) f32, aux_loss).

        Materialises full logits — fine for smoke/eval scales; ``loss`` uses
        the chunked CE path instead (never builds (B, L, V)).
        """
        x, aux_total = self.apply_hidden(params, tokens, remat, unroll)
        return self._unembed(params, x), aux_total

    def _run_layers(self, params: dict, x: jnp.ndarray, remat: bool, unroll: bool):
        cfg = self.cfg
        from repro.utils import unroll_scans_enabled

        unroll = unroll or unroll_scans_enabled()
        aux_total = jnp.float32(0.0)
        if cfg.family in ("dense", "moe"):
            if cfg.alt_local_global:

                def body(x, lp):
                    x, a1 = _dense_layer_train(lp["local"], x, cfg, cfg.window, True)
                    x, a2 = _dense_layer_train(lp["global"], x, cfg, None, True)
                    return x, a1 + a2

            else:
                gemma = cfg.name.startswith("gemma")

                def body(x, lp):
                    return _dense_layer_train(lp, x, cfg, cfg.window, gemma)

            f = jax.checkpoint(body) if remat else body
            x, auxs = jax.lax.scan(f, x, params["layers"], unroll=unroll)
            aux_total = jnp.sum(auxs)
        elif cfg.family == "ssm":

            def body(x, lp):
                return _rwkv_layer_train(lp, x, cfg)

            f = jax.checkpoint(body) if remat else body
            x, _ = jax.lax.scan(f, x, params["layers"], unroll=unroll)
        elif cfg.family == "hybrid":

            def body(x, lp):
                return _mamba_layer_train(lp, x, cfg), None

            f = jax.checkpoint(body) if remat else body
            for gi, (s, e) in enumerate(self.groups):
                sub = jax.tree_util.tree_map(lambda a: a[s:e], params["layers"])
                x, _ = jax.lax.scan(f, x, sub, unroll=unroll)
                x, _ = _dense_layer_train(params["shared_attn"], x, cfg, None, False)
        else:
            raise ValueError(cfg.family)
        return x, aux_total

    def _unembed(self, params, x):
        cfg = self.cfg
        w = params["embed"].T if cfg.tied_embeddings else params["unembed"]
        logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
        return softcap(logits, cfg.final_softcap)

    # -------------------------- loss ----------------------------------
    def apply_hidden(self, params: dict, tokens: jnp.ndarray, remat: bool = True, unroll: bool = False):
        """Final hidden states (B, L, d) before unembedding, + moe aux loss."""
        cfg = self.cfg
        x = params["embed"][tokens].astype(_dtype(cfg.compute_dtype))
        if cfg.name.startswith("gemma"):
            x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
        x, aux_total = self._run_layers(params, x, remat, unroll)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps,
                     plus_one=cfg.name.startswith("gemma"))
        return x, aux_total

    def loss(self, params: dict, tokens: jnp.ndarray, labels: jnp.ndarray, remat: bool = True, unroll: bool = False):
        cfg = self.cfg
        x, aux = self.apply_hidden(params, tokens, remat, unroll)
        w = params["embed"].T if cfg.tied_embeddings else params["unembed"]
        nll, logz_sq = chunked_softmax_xent(
            x, w, labels, softcap_val=cfg.final_softcap, unroll=unroll
        )
        z_loss = cfg.z_loss * logz_sq
        total = nll + z_loss + cfg.moe_aux_loss * aux
        return total, {"nll": nll, "z_loss": z_loss, "moe_aux": aux}

    # -------------------------- decode --------------------------------
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        kv_dtype = _dtype(cfg.compute_dtype)
        def stacked(n, tree):
            return jax.tree_util.tree_map(
                lambda t: jnp.zeros((n,) + t.shape, t.dtype), tree
            )

        if cfg.family in ("dense", "moe"):
            one = blocks.init_attn_cache(cfg, batch, max_len, kv_dtype)
            if cfg.alt_local_global:
                return stacked(cfg.n_layers // 2, {"local": one, "global": one})
            return stacked(cfg.n_layers, one)
        if cfg.family == "ssm":
            return stacked(cfg.n_layers, blocks.init_rwkv_cache(cfg, batch))
        if cfg.family == "hybrid":
            # the weight-shared attention block has one KV cache PER invocation
            # site (its inputs differ per site even though weights are tied)
            return {
                "mamba": stacked(cfg.n_layers, blocks.init_mamba_cache(cfg, batch)),
                "shared_attn": stacked(
                    len(self.groups), blocks.init_attn_cache(cfg, batch, max_len, kv_dtype)
                ),
            }
        raise ValueError(cfg.family)

    def decode_step(self, params: dict, cache: dict, tokens_t: jnp.ndarray, pos, unroll: bool = False):
        """tokens_t (B, 1) at position ``pos`` -> (logits (B, 1, V), cache)."""
        cfg = self.cfg
        x = params["embed"][tokens_t].astype(_dtype(cfg.compute_dtype))
        if cfg.name.startswith("gemma"):
            x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)

        if cfg.family in ("dense", "moe"):
            gemma = cfg.name.startswith("gemma")
            if cfg.alt_local_global:

                def body(x, inp):
                    lp, lc = inp
                    x, c1 = _dense_layer_decode(
                        lp["local"], x, lc["local"], pos, cfg, cfg.window, True
                    )
                    x, c2 = _dense_layer_decode(
                        lp["global"], x, lc["global"], pos, cfg, None, True
                    )
                    return x, {"local": c1, "global": c2}

            else:

                def body(x, inp):
                    lp, lc = inp
                    return _dense_layer_decode(lp, x, lc, pos, cfg, cfg.window, gemma)

            x, cache = jax.lax.scan(body, x, (params["layers"], cache), unroll=unroll)
        elif cfg.family == "ssm":

            def body(x, inp):
                lp, lc = inp
                return _rwkv_layer_decode(lp, x, lc, cfg)

            x, cache = jax.lax.scan(body, x, (params["layers"], cache), unroll=unroll)
        elif cfg.family == "hybrid":
            new_mamba, new_shared = [], []
            for gi, (s, e) in enumerate(self.groups):
                sub_p = jax.tree_util.tree_map(lambda a: a[s:e], params["layers"])
                sub_c = jax.tree_util.tree_map(lambda a: a[s:e], cache["mamba"])

                def body(x, inp):
                    lp, lc = inp
                    return _mamba_layer_decode(lp, x, lc, cfg)

                x, sub_c = jax.lax.scan(body, x, (sub_p, sub_c), unroll=unroll)
                new_mamba.append(sub_c)
                site_cache = jax.tree_util.tree_map(
                    lambda a: a[gi], cache["shared_attn"]
                )
                x, site_cache = _dense_layer_decode(
                    params["shared_attn"], x, site_cache, pos, cfg, None, False
                )
                new_shared.append(site_cache)
            cache = {
                "mamba": jax.tree_util.tree_map(
                    lambda *xs: jnp.concatenate(xs, axis=0), *new_mamba
                ),
                "shared_attn": jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs, axis=0), *new_shared
                ),
            }

        x = rms_norm(x, params["final_norm"], cfg.norm_eps,
                     plus_one=cfg.name.startswith("gemma"))
        return self._unembed(params, x), cache
