"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec
    # core dims
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int | None = None  # default d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    # attention features
    qk_norm: bool = False
    rope_mode: str = "full"  # full | half (chatglm 2d) | none (whisper sinusoidal)
    rope_theta: float = 10_000.0
    attn_softcap: float | None = None  # gemma2: 50.0
    final_softcap: float | None = None  # gemma2: 30.0
    window: int | None = None  # sliding-window size for local layers
    alt_local_global: bool = False  # gemma2: even layers local, odd global
    sandwich_norm: bool = False  # gemma2: post-norm after attn/mlp too
    # mlp
    act: str = "swiglu"  # swiglu | geglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_group: int = 512  # tokens per dispatch group
    # SSM (mamba2)
    ssm_state: int = 64
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    # hybrid (zamba2): one weight-shared attention block every k ssm blocks
    shared_attn_every: int = 6
    # RWKV6
    rwkv_head_dim: int = 64
    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500  # stubbed audio frames
    # embeddings
    tied_embeddings: bool = True
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # norm
    norm_eps: float = 1e-6
    # loss
    z_loss: float = 1e-4
    moe_aux_loss: float = 1e-2

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm" and self.name.startswith("rwkv")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell from the assignment table."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
