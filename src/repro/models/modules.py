"""Parameter/initializer helpers and elementary layers (flax-free).

Parameters are nested dicts of jnp arrays.  Sharding is *path-based*: leaf key
names are globally meaningful (``q_proj``, ``expert_w1``, ...) and
``repro/distributed/sharding.py`` maps them to PartitionSpecs — the MaxText
"logical axis" idea without a module system.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def dense_param(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (LM standard)."""
    std = scale if scale is not None else in_dim**-0.5
    w = jax.random.truncated_normal(key, -3.0, 3.0, (in_dim, out_dim), jnp.float32) * std
    return w.astype(dtype)


def embed_param(key, vocab: int, dim: int, dtype):
    w = jax.random.truncated_normal(key, -3.0, 3.0, (vocab, dim), jnp.float32)
    return w.astype(dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6, plus_one: bool = False) -> jnp.ndarray:
    """RMSNorm in fp32 (gemma uses (1 + scale))."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    s = scale.astype(jnp.float32)
    y = y * (1.0 + s) if plus_one else y * s
    return y.astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Mean-centred LayerNorm in fp32 (whisper)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(
    x: jnp.ndarray,  # (..., L, D) — heads folded into leading dims
    positions: jnp.ndarray,  # (..., L) or (L,)
    theta: float = 10_000.0,
    mode: str = "full",  # full | half | none
) -> jnp.ndarray:
    """Neox-style rotate-half RoPE; ``half`` rotates only the first D/2 dims
    (ChatGLM's 2D rotary)."""
    if mode == "none":
        return x
    d = x.shape[-1]
    rot_d = d if mode == "full" else d // 2
    freqs = jnp.asarray(rope_freqs(rot_d, theta))  # (rot_d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., L, rot_d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)

    xr = x[..., :rot_d].astype(jnp.float32)
    x1, x2 = xr[..., : rot_d // 2], xr[..., rot_d // 2 :]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = jnp.concatenate([rotated, x[..., rot_d:].astype(jnp.float32)], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> np.ndarray:
    """Whisper-style sinusoidal absolute positional embedding table."""
    log_timescale = np.log(10_000.0) / (dim // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(dim // 2, dtype=np.float32))
    scaled = np.arange(length, dtype=np.float32)[:, None] * inv[None, :]
    return np.concatenate([np.sin(scaled), np.cos(scaled)], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------
def glu_act(gate: jnp.ndarray, up: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        return jax.nn.silu(gate) * up
    if kind == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    raise ValueError(kind)


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x.astype(jnp.float32) / cap).astype(x.dtype)
