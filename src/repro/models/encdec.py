"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()`` feeds
precomputed (B, enc_seq, d_model) frame embeddings.  The decoder is a
standard pre-LN transformer with causal self-attention + cross-attention;
positions are sinusoidal (extended past Whisper's 448 text positions for the
assigned long shapes — DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.config import ModelConfig
from repro.models.modules import (
    _dtype,
    dense_param,
    embed_param,
    layer_norm,
    sinusoidal_positions,
)


def _ln_params(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def _ln(x, p, eps=1e-5):
    return layer_norm(x, p["scale"], p["bias"], eps)


def _init_enc_layer(key, cfg: ModelConfig, dtype):
    ka, km = jax.random.split(key)
    return {
        "attn": blocks.init_attention(ka, cfg, dtype),
        "mlp": blocks.init_mlp(km, cfg, dtype),
        "attn_ln": _ln_params(cfg.d_model, dtype),
        "mlp_ln": _ln_params(cfg.d_model, dtype),
    }


def _init_dec_layer(key, cfg: ModelConfig, dtype):
    ka, kc, km = jax.random.split(key, 3)
    return {
        "attn": blocks.init_attention(ka, cfg, dtype),
        "cross": blocks.init_attention(kc, cfg, dtype, cross=True),
        "mlp": blocks.init_mlp(km, cfg, dtype),
        "attn_ln": _ln_params(cfg.d_model, dtype),
        "cross_ln": _ln_params(cfg.d_model, dtype),
        "mlp_ln": _ln_params(cfg.d_model, dtype),
    }


class EncDecLM:
    """Whisper backbone: encode stubbed frames once, decode text tokens."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = _dtype(cfg.param_dtype)

    def init(self, key: jax.Array) -> dict:
        cfg, dtype = self.cfg, self.dtype
        ks = jax.random.split(key, 4)
        enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
        dec_keys = jax.random.split(ks[1], cfg.n_layers)
        return {
            "embed": embed_param(ks[2], cfg.vocab, cfg.d_model, dtype),
            "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(enc_keys),
            "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(dec_keys),
            "enc_ln": _ln_params(cfg.d_model, dtype),
            "dec_ln": _ln_params(cfg.d_model, dtype),
        }

    # ------------------------------------------------------------------
    def encode(self, params, frames: jnp.ndarray, unroll: bool = False) -> jnp.ndarray:
        """frames (B, enc_seq, d) — precomputed stub embeddings."""
        cfg = self.cfg
        pos = jnp.asarray(sinusoidal_positions(frames.shape[1], cfg.d_model))
        x = frames.astype(_dtype(cfg.compute_dtype)) + pos.astype(frames.dtype)

        def body(x, lp):
            h = _ln(x, lp["attn_ln"])
            x = x + blocks.attn_train(lp["attn"], h, cfg, causal=False)
            h = _ln(x, lp["mlp_ln"])
            x = x + blocks.mlp_apply(lp["mlp"], h, cfg)
            return x, None

        from repro.utils import unroll_scans_enabled

        unroll = unroll or unroll_scans_enabled()
        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"], unroll=unroll)
        return _ln(x, params["enc_ln"])

    def decode_train(self, params, tokens: jnp.ndarray, enc_out: jnp.ndarray, unroll: bool = False):
        x = self.decode_hidden(params, tokens, enc_out, unroll)
        return (x @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)

    def apply_train(self, params, tokens, frames, remat: bool = True, unroll: bool = False):
        enc_out = self.encode(params, frames, unroll)
        return self.decode_train(params, tokens, enc_out, unroll), jnp.float32(0.0)

    def decode_hidden(self, params, tokens: jnp.ndarray, enc_out: jnp.ndarray, unroll: bool = False):
        """Decoder final hidden states (B, L, d) — the chunked-CE input."""
        cfg = self.cfg
        pos = jnp.asarray(sinusoidal_positions(tokens.shape[1], cfg.d_model))
        x = params["embed"][tokens].astype(_dtype(cfg.compute_dtype))
        x = x + pos.astype(x.dtype)

        def body(x, lp):
            h = _ln(x, lp["attn_ln"])
            x = x + blocks.attn_train(lp["attn"], h, cfg)
            h = _ln(x, lp["cross_ln"])
            x = x + blocks.attn_train(lp["cross"], h, cfg, kv_x=enc_out, causal=False)
            h = _ln(x, lp["mlp_ln"])
            x = x + blocks.mlp_apply(lp["mlp"], h, cfg)
            return x, None

        from repro.utils import unroll_scans_enabled

        unroll = unroll or unroll_scans_enabled()
        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"], unroll=unroll)
        return _ln(x, params["dec_ln"])

    def loss(self, params, tokens, labels, frames, remat: bool = True, unroll: bool = False):
        from repro.models.lm import chunked_softmax_xent

        enc_out = self.encode(params, frames, unroll)
        x = self.decode_hidden(params, tokens, enc_out, unroll)
        nll, logz_sq = chunked_softmax_xent(
            x, params["embed"].T, labels, unroll=unroll
        )
        z_loss = self.cfg.z_loss * logz_sq
        return nll + z_loss, {"nll": nll, "z_loss": z_loss, "moe_aux": jnp.float32(0.0)}

    # ------------------------------------------------------------------
    # serving: cross-attention K/V precomputed once; self-attn KV cached
    # ------------------------------------------------------------------
    def init_cache(self, params, batch: int, max_len: int, enc_out: jnp.ndarray) -> dict:
        cfg = self.cfg
        kv_dtype = _dtype(cfg.compute_dtype)
        hkv, hd = cfg.n_kv_heads, cfg.hd
        b, lk, _ = enc_out.shape

        def cross_kv(lp):
            k = (enc_out @ lp["cross"]["k_proj"]).reshape(b, lk, hkv, hd).transpose(0, 2, 1, 3)
            v = (enc_out @ lp["cross"]["v_proj"]).reshape(b, lk, hkv, hd).transpose(0, 2, 1, 3)
            return {"ck": k.astype(kv_dtype), "cv": v.astype(kv_dtype)}

        cross = jax.vmap(cross_kv)(params["dec_layers"])
        self_kv = jax.tree_util.tree_map(
            lambda t: jnp.zeros((cfg.n_layers,) + t.shape, t.dtype),
            blocks.init_attn_cache(cfg, batch, max_len, kv_dtype),
        )
        return {"self": self_kv, "cross": cross}

    def decode_step(self, params, cache: dict, tokens_t: jnp.ndarray, pos, unroll: bool = False):
        cfg = self.cfg
        b = tokens_t.shape[0]
        h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        g = h // hkv
        x = params["embed"][tokens_t].astype(_dtype(cfg.compute_dtype))
        x = x + _runtime_sinusoid(pos, cfg.d_model).astype(x.dtype)

        def body(x, inp):
            lp, lc = inp
            hdn = _ln(x, lp["attn_ln"])
            a, new_self = blocks.attn_decode(lp["attn"], hdn, lc[0], pos, cfg)
            x = x + a
            hdn = _ln(x, lp["cross_ln"])
            q = (hdn @ lp["cross"]["q_proj"]).reshape(b, 1, h, hd).transpose(0, 2, 1, 3)
            qf = q.astype(jnp.float32).reshape(b, hkv, g, hd)
            sc = jnp.einsum("bhgd,bhsd->bhgs", qf, lc[1]["ck"].astype(jnp.float32)) * hd**-0.5
            pr = jax.nn.softmax(sc, axis=-1)
            o = jnp.einsum("bhgs,bhsd->bhgd", pr, lc[1]["cv"].astype(jnp.float32))
            o = o.reshape(b, 1, h * hd).astype(x.dtype)
            x = x + o @ lp["cross"]["o_proj"]
            hdn = _ln(x, lp["mlp_ln"])
            x = x + blocks.mlp_apply(lp["mlp"], hdn, cfg)
            return x, new_self

        x, new_self = jax.lax.scan(
            body, x, (params["dec_layers"], (cache["self"], cache["cross"])), unroll=unroll
        )
        x = _ln(x, params["dec_ln"])
        logits = (x @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
        return logits, {"self": new_self, "cross": cache["cross"]}


def _runtime_sinusoid(pos, dim: int) -> jnp.ndarray:
    import numpy as np

    log_timescale = np.log(10_000.0) / (dim // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(dim // 2, dtype=jnp.float32))
    scaled = pos.astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)])[None, None, :]
