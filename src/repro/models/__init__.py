"""Model zoo: the 10 assigned architectures as selectable configs."""
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.models.lm import CausalLM
from repro.models.encdec import EncDecLM

__all__ = ["SHAPES", "ModelConfig", "ShapeConfig", "CausalLM", "EncDecLM"]
