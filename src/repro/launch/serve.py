"""Batched serving loop: prefill + decode with a KV/state cache.

``python -m repro.launch.serve --arch tinyllama-1.1b --smoke`` runs a small
batched generation end-to-end on CPU; the same ``serve_step`` is what the
decode_32k / long_500k dry-run cells compile for the production meshes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import build_model, get_config
from repro.distributed.train_step import make_serve_step


def generate(
    model,
    params,
    prompts: jnp.ndarray,  # (B, P) int32
    max_new_tokens: int = 32,
    frames: jnp.ndarray | None = None,
):
    """Greedy generation: teacher-forced prefill then cached decode."""
    cfg = model.cfg
    b, p_len = prompts.shape
    total = p_len + max_new_tokens

    if cfg.family == "encdec":
        enc_out = model.encode(params, frames)
        cache = model.init_cache(params, b, total, enc_out)
    else:
        cache = model.init_cache(b, total)
    step = jax.jit(model.decode_step)

    # prefill by stepping the prompt (simple, exercises the decode path;
    # a chunked-prefill fast path is the prefill_32k dry-run target)
    tok = prompts[:, :1]
    logits = None
    for t in range(p_len):
        logits, cache = step(params, cache, prompts[:, t : t + 1], jnp.int32(t))

    out = [prompts]
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for t in range(p_len, total):
        out.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    key = jax.random.key(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    frames = (
        jax.random.normal(key, (args.batch, cfg.enc_seq, cfg.d_model))
        if cfg.family == "encdec"
        else None
    )

    t0 = time.perf_counter()
    seqs = generate(model, params, prompts, args.new_tokens, frames)
    dt = time.perf_counter() - t0
    n_new = args.batch * args.new_tokens
    print(f"generated {seqs.shape} in {dt:.2f}s ({n_new/dt:,.1f} tok/s)")
    print("first sequence:", seqs[0, : args.prompt_len + 8].tolist())
    return seqs


if __name__ == "__main__":
    main()
