"""End-to-end LM trainer: ``python -m repro.launch.train --arch <id> ...``

Production posture on any topology (1 CPU device to 512-chip multi-pod):
  * sharded init straight onto the mesh (jit with out_shardings),
  * deterministic restart-safe data pipeline (counter in the checkpoint),
  * atomic async checkpoints every --ckpt-every steps, keep-k,
  * --resume picks up bit-exact from the latest step (tested),
  * straggler watchdog: a step exceeding --straggler-factor x the median
    step time logs a warning and forces an early checkpoint (the node-
    failure playbook on a real cluster: snapshot, then reschedule),
  * preemption-safe: SIGTERM triggers checkpoint-and-exit.
"""
from __future__ import annotations

import argparse
import signal
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import build_model, get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.distributed import sharding as shd
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.train_step import (
    TrainStepConfig,
    init_train_state,
    make_train_step,
)
from repro.launch.mesh import make_single_device_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="checkpoints/lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    mesh = make_single_device_mesh() if jax.device_count() == 1 else None

    ts_cfg = TrainStepConfig(
        lr=args.lr,
        total_steps=args.steps,
        num_microbatches=args.microbatches,
        compress_grads=args.compress_grads,
    )
    data = SyntheticTokens(
        DataConfig(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq_len)
    )

    # --- init or resume ----------------------------------------------------
    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    state = init_train_state(model, jax.random.key(0), ts_cfg)
    start_step = 0
    if args.resume and mgr.latest_step() is not None:
        state, extras = mgr.restore(jax.eval_shape(lambda: state))
        start_step = int(extras["step"])
        print(f"[resume] from step {start_step}")

    step_fn = jax.jit(make_train_step(model, ts_cfg), donate_argnums=(0,))

    # --- preemption hook ----------------------------------------------------
    preempted = {"flag": False}

    def _on_sigterm(signum, frame):
        preempted["flag"] = True

    signal.signal(signal.SIGTERM, _on_sigterm)

    # --- loop ----------------------------------------------------------------
    times: list[float] = []
    for step in range(start_step, args.steps):
        batch = data.batch(step)
        if cfg.family == "encdec":
            batch["frames"] = data.frames(step, cfg.enc_seq, cfg.d_model)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        times.append(dt)

        if len(times) > 5:
            med = statistics.median(times[-50:])
            if dt > args.straggler_factor * med:
                print(
                    f"[watchdog] step {step} took {dt:.2f}s (median {med:.2f}s) — "
                    "straggler suspected; forcing checkpoint",
                    flush=True,
                )
                mgr.save(step + 1, state, extras={"step": step + 1}, blocking=False)

        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq_len / dt
            print(
                f"step {step:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.2f} {tok_s:,.0f} tok/s",
                flush=True,
            )
        if (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state, extras={"step": step + 1}, blocking=False)
        if preempted["flag"]:
            print("[preempt] SIGTERM received — checkpointing and exiting")
            mgr.save(step + 1, state, extras={"step": step + 1}, blocking=True)
            sys.exit(0)

    mgr.save(args.steps, state, extras={"step": args.steps}, blocking=True)
    mgr.wait()
    print(f"[done] final loss {float(metrics['loss']):.4f}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
