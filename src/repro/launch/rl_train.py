import os
import sys

if "--dryrun" in sys.argv:  # must precede ANY jax import (device-count lock)
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Chargax PPO at pod scale — the paper's technique as a first-class feature.

Two modes:
  * real training (any device count):   python -m repro.launch.rl_train
  * production-mesh dry-run (512 dev):  python -m repro.launch.rl_train --dryrun

The dry-run lowers ONE full PPO update (rollout scan + GAE + minibatch
epochs) with the environment batch sharded across the data axes of the
16x16 / 2x16x16 meshes — the paper-representative cell of EXPERIMENTS.md
§Roofline: on-device env steps mean rollouts never leave the chips, the
paper's core claim generalised to pods (DESIGN.md §3).
"""
import argparse
import json
import time

import jax
import numpy as np

from repro.analysis.hlo import collective_stats, cost_analysis_dict
from repro.core import ChargaxEnv, EnvConfig
from repro.distributed import env_sharding, sharding
from repro.rl import PPOConfig, make_train

# env-batch constraint now lives in the distributed layer, shared with
# FleetEnv and the benchmarks
make_shard_envs = env_sharding.make_shard_envs


def run_dryrun(args) -> dict:
    from repro.launch.mesh import make_production_mesh

    results = []
    for multi_pod in (False, True):
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.devices.size
        env = ChargaxEnv(
            EnvConfig(
                scenario=args.scenario, traffic=args.traffic, fused_step=args.fused
            )
        )
        cfg = PPOConfig(
            num_envs=args.num_envs * n_dev,
            rollout_steps=args.rollout,
            total_timesteps=args.num_envs * n_dev * args.rollout,  # 1 update
            num_minibatches=4,
            hidden=(128, 128),
        )
        with sharding.set_mesh(mesh):
            train = make_train(cfg, env, shard_envs=make_shard_envs(mesh))
            t0 = time.perf_counter()
            lowered = jax.jit(train).lower(jax.random.key(0))
            compiled = lowered.compile()
            wall = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        rec = {
            "cell": "chargax-ppo-update",
            "mesh": "2x16x16" if multi_pod else "16x16",
            "num_envs": cfg.num_envs,
            "rollout_steps": cfg.rollout_steps,
            "compile_s": round(wall, 2),
            "bytes_per_device": int(
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            ),
            "hlo_flops": float(cost.get("flops", -1)),
            "hlo_bytes": float(cost.get("bytes accessed", -1)),
            "collectives": collective_stats(compiled.as_text()),
            "ok": True,
        }
        print(json.dumps(rec, indent=1))
        results.append(rec)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return results


def _expand_scenarios(spec: str) -> list[str]:
    """Expand ``--scenarios`` tokens: names pass through, pack names
    (``REAL_PACK``, ``GRID_PACK``, ``CITY_PACK``, ``V2G_PACK``, ``V2G_MIXED_PACK``,
    ``CATALOG``) expand to
    their members — so ``--scenarios REAL_PACK,shopping_flat`` trains across
    the real-data worlds plus the synthetic baseline in one distribution."""
    from repro import scenarios as _scen

    packs = {
        "REAL_PACK": _scen.REAL_PACK,
        "GRID_PACK": _scen.GRID_PACK,
        "CITY_PACK": _scen.CITY_PACK,
        "V2G_PACK": _scen.V2G_PACK,
        "V2G_MIXED_PACK": _scen.V2G_MIXED_PACK,
        "CATALOG": tuple(s.name for s in _scen.CATALOG),
    }
    names: list[str] = []
    for tok in spec.split(","):
        tok = tok.strip()
        names.extend(packs.get(tok, (tok,)))
    return names


def _profile_probe(args, cfg, env, shard_envs, scenario_params, obs):
    """Emit a perfetto-viewable trace of ONE representative PPO update.

    The real training run stays untraced (the CPU tracer records every op
    execution — tracing thousands of updates produces multi-GB buffers and
    a multi-minute flush).  Every update executes the same compiled program,
    so one update IS the profile.  Inside the session:

      * trace+lower+compile of the probe happens with annotations ON, so
        the host timeline carries the named phase spans (``env/*``,
        ``wrap/*``, ``ppo/*``) nested exactly as the program is structured;
      * one update executes with minimal loop trip counts (short rollout,
        one epoch/minibatch — op set identical, fewer repeated events), so
        the device timeline shows the runtime op mix.
    """
    probe_rollout = min(args.rollout, 8)
    probe_cfg = PPOConfig(
        total_timesteps=cfg.num_envs * probe_rollout,
        num_envs=cfg.num_envs,
        rollout_steps=probe_rollout,
        num_minibatches=1,
        update_epochs=1,
        hidden=cfg.hidden,
    )
    probe = make_train(
        probe_cfg, env, shard_envs=shard_envs, scenario_params=scenario_params
    )
    key = jax.random.key(args.seed)
    with obs.trace_session(args.profile, keep_xplane=False):
        with obs.annotate("profile/trace_and_compile"):
            compiled = jax.jit(probe).lower(key).compile()
        with obs.annotate("profile/run_one_update"):
            pout = compiled(key)
            jax.block_until_ready(pout["metrics"]["rollout_reward"])


def run_train(args):
    from repro import obs

    env = ChargaxEnv(
        EnvConfig(
            scenario=args.scenario,
            traffic=args.traffic,
            allow_v2g=args.v2g,
            fused_step=args.fused,
        )
    )
    if args.fused:
        from repro.kernels.chargax_step.ops import resolve_impl

        print(f"[ppo] fused step kernel ON (impl={resolve_impl()})")
    # typed env surface (repro.envs): PPO wraps this in
    # LogWrapper(AutoReset(VmapWrapper)) with on-device KPI accumulation
    print(f"[ppo] obs={env.observation_space} actions={env.action_space}")
    cfg = PPOConfig(
        total_timesteps=args.timesteps,
        num_envs=args.num_envs,
        rollout_steps=args.rollout,
    )
    scenario_names = _expand_scenarios(args.scenarios) if args.scenarios else None
    if args.v2g and scenario_names is None:
        # default --v2g distribution: V2G-heavy worlds mixed with their
        # charge-only counterparts (per-port v2g masks are plain arrays, so
        # the mix still compiles once)
        from repro.scenarios import V2G_MIXED_PACK

        # largest pack prefix that divides num_envs (nested vmap needs an
        # even envs-per-scenario split)
        n_scen = max(
            s for s in range(1, len(V2G_MIXED_PACK) + 1) if args.num_envs % s == 0
        )
        scenario_names = list(V2G_MIXED_PACK[:n_scen])
        print(f"[ppo] --v2g default mix: {','.join(scenario_names)}")
    scenario_params = None
    if scenario_names:
        from repro import scenarios as _scen

        per_scenario = [_scen.make(n).make_params(env) for n in scenario_names]
        scenario_params = _scen.stack_params(per_scenario)
        print(
            f"[ppo] training across {len(scenario_names)} scenarios "
            "(one table copy each)"
        )
        if args.preflight:
            # recompile sentinel: every selected scenario must reuse ONE
            # compiled step (pure array swaps) — seconds to check here vs
            # minutes of silently duplicated training compiles later
            obs.assert_one_compiled_step(
                env, per_scenario, label=f"scenarios {','.join(scenario_names)}"
            )
            print(
                f"[obs] preflight: {len(per_scenario)} scenarios share one "
                "compiled step (no recompiles)"
            )

    # multi-device: shard the env batch over a data mesh built from every
    # visible device; single device degrades to no mesh / no constraints
    n_dev = jax.device_count()
    mesh_ctx = None
    shard_envs = None
    if n_dev > 1 and cfg.num_envs % n_dev == 0:
        from repro.launch.mesh import make_data_mesh

        mesh = make_data_mesh()
        mesh_ctx = sharding.set_mesh(mesh)
        shard_envs = env_sharding.make_shard_envs(mesh)
        print(f"[ppo] sharding {cfg.num_envs} envs over {n_dev} devices")
    elif n_dev > 1:
        print(
            f"[ppo] WARNING: num_envs={cfg.num_envs} not divisible by "
            f"{n_dev} devices — env sharding disabled, running replicated"
        )

    import contextlib

    with mesh_ctx if mesh_ctx is not None else contextlib.nullcontext():
        train = jax.jit(
            make_train(cfg, env, shard_envs=shard_envs, scenario_params=scenario_params)
        )
        t0 = time.perf_counter()
        out = train(jax.random.key(args.seed))
        jax.block_until_ready(out["metrics"]["rollout_reward"])
        wall = time.perf_counter() - t0
        if args.profile:
            _profile_probe(args, cfg, env, shard_envs, scenario_params, obs)
    rr = out["metrics"]["rollout_reward"]
    print(
        f"[ppo] {args.timesteps:,} steps in {wall:.1f}s "
        f"({args.timesteps/wall:,.0f} env-steps/s) | "
        f"reward first->last: {float(rr[0]):.1f} -> {float(rr[-1]):.1f}"
    )
    kpis = {
        k.split("/", 1)[1]: float(np.asarray(v)[-1])
        for k, v in out["metrics"].items()
        if k.startswith("kpi/")
    }
    if kpis:
        print(
            "[kpi] last update, per env-step: "
            + " ".join(f"{k}={v:.3f}" for k, v in sorted(kpis.items()))
        )
    if args.profile:
        trace = obs.latest_trace(args.profile)
        print(
            f"[obs] profile trace: {trace} "
            "(open at https://ui.perfetto.dev — phases env/*, wrap/*, ppo/*)"
        )
    writer = None
    if args.metrics_out:
        from repro.kernels.chargax_step.ops import resolve_impl

        writer = obs.MetricsWriter(
            args.metrics_out,
            run="rl_train",
            scenario=args.scenario,
            scenarios=scenario_names,
            timesteps=args.timesteps,
            num_envs=cfg.num_envs,
            seed=args.seed,
            fused_step=args.fused,
            fused_impl=resolve_impl() if args.fused else None,
        )
        writer.write(
            {
                "wall_s": round(wall, 2),
                "env_steps_per_sec": round(args.timesteps / wall, 1),
                "rollout_reward_first": float(rr[0]),
                "rollout_reward_last": float(rr[-1]),
                "episode_return_last": float(
                    np.asarray(out["metrics"]["episode_return"])[-1]
                ),
                **{f"kpi/{k}": v for k, v in kpis.items()},
            },
            kind="train",
        )
    if args.v2g and scenario_names:
        # discharge/degradation report: trained agent vs the always-max and
        # arbitrage baselines on the first (V2G-heavy) scenario of the mix
        from repro import scenarios as _scen
        from repro.rl import evaluate, make_ppo_policy
        from repro.rl.baselines import max_charge_policy, v2g_arbitrage_policy

        sc_params = _scen.make(scenario_names[0]).make_params(env)
        policies = {
            "ppo": (make_ppo_policy(env), out["runner_state"].params),
            "max_charge": (max_charge_policy(env), None),
            "v2g_arbitrage": (v2g_arbitrage_policy(env, sc_params), None),
        }
        for name, (pol, pol_params) in policies.items():
            res = evaluate(
                env, pol, pol_params, jax.random.key(17), 16, env_params=sc_params,
                writer=writer, tag=f"{scenario_names[0]}/{name}",
            )
            print(
                f"[v2g eval] {scenario_names[0]} {name}: "
                f"profit={res['daily_profit']:.1f} "
                f"discharged={res['energy_discharged_kwh']:.1f}kWh "
                f"discharge_frac={res['v2g_discharge_frac']:.3f} "
                f"missing={res['missing_kwh']:.1f}kWh"
            )
    if writer is not None:
        writer.close()
        print(f"[obs] metrics JSONL: {writer.path}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument(
        "--scenarios",
        default=None,
        help="comma-separated catalog scenarios to train across "
        "(nested-vmap distribution training; num-envs must be a multiple); "
        "pack names REAL_PACK / GRID_PACK / CITY_PACK / V2G_PACK / V2G_MIXED_PACK "
        "/ CATALOG expand",
    )
    ap.add_argument("--scenario", default="shopping")
    ap.add_argument("--traffic", default="medium")
    ap.add_argument(
        "--v2g",
        action="store_true",
        help="allow car discharging (EnvConfig.allow_v2g); without --scenarios "
        "this trains across the bundled mixed v2g/non-v2g pack",
    )
    ap.add_argument(
        "--fused",
        action="store_true",
        help="route the env step through the fused kernel hot path "
        "(EnvConfig.fused_step; Pallas on TPU/GPU, bit-exact jnp ref on CPU; "
        "override with CHARGAX_FUSED_IMPL=pallas|interpret|ref)",
    )
    ap.add_argument("--timesteps", type=int, default=300_000)
    ap.add_argument("--num-envs", type=int, default=12)
    ap.add_argument("--rollout", type=int, default=300)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/ppo_dryrun.json")
    ap.add_argument(
        "--profile",
        default=None,
        metavar="DIR",
        help="write a perfetto-viewable trace of the training run to DIR "
        "(phases annotated: env/*, wrap/*, ppo/*; open at ui.perfetto.dev)",
    )
    ap.add_argument(
        "--metrics-out",
        default=None,
        metavar="PATH",
        help="append run manifest + train/eval KPI records to a JSONL sink",
    )
    ap.add_argument(
        "--preflight",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="with --scenarios: assert the catalog shares ONE compiled step "
        "before training (recompile sentinel); --no-preflight skips",
    )
    args = ap.parse_args(argv)
    if args.dryrun:
        return run_dryrun(args)
    return run_train(args)


if __name__ == "__main__":
    main()
