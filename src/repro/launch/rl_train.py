import os
import sys

if "--dryrun" in sys.argv:  # must precede ANY jax import (device-count lock)
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Chargax PPO at pod scale — the paper's technique as a first-class feature.

Two modes:
  * real training (any device count):   python -m repro.launch.rl_train
  * production-mesh dry-run (512 dev):  python -m repro.launch.rl_train --dryrun

The dry-run lowers ONE full PPO update (rollout scan + GAE + minibatch
epochs) with the environment batch sharded across the data axes of the
16x16 / 2x16x16 meshes — the paper-representative cell of EXPERIMENTS.md
§Roofline: on-device env steps mean rollouts never leave the chips, the
paper's core claim generalised to pods (DESIGN.md §3).
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_stats, cost_analysis_dict
from repro.core import ChargaxEnv, EnvConfig
from repro.distributed import sharding
from repro.rl import PPOConfig, make_train


def make_shard_envs(mesh):
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    spec = P(dp if len(dp) > 1 else dp[0], None)

    def constrain(obs):
        return jax.lax.with_sharding_constraint(obs, NamedSharding(mesh, spec))

    return constrain


def run_dryrun(args) -> dict:
    from repro.launch.mesh import make_production_mesh

    results = []
    for multi_pod in (False, True):
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.devices.size
        env = ChargaxEnv(EnvConfig(scenario=args.scenario, traffic=args.traffic))
        cfg = PPOConfig(
            num_envs=args.num_envs * n_dev,
            rollout_steps=args.rollout,
            total_timesteps=args.num_envs * n_dev * args.rollout,  # 1 update
            num_minibatches=4,
            hidden=(128, 128),
        )
        with sharding.set_mesh(mesh):
            train = make_train(cfg, env, shard_envs=make_shard_envs(mesh))
            t0 = time.perf_counter()
            lowered = jax.jit(train).lower(jax.random.key(0))
            compiled = lowered.compile()
            wall = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        rec = {
            "cell": "chargax-ppo-update",
            "mesh": "2x16x16" if multi_pod else "16x16",
            "num_envs": cfg.num_envs,
            "rollout_steps": cfg.rollout_steps,
            "compile_s": round(wall, 2),
            "bytes_per_device": int(
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            ),
            "hlo_flops": float(cost.get("flops", -1)),
            "hlo_bytes": float(cost.get("bytes accessed", -1)),
            "collectives": collective_stats(compiled.as_text()),
            "ok": True,
        }
        print(json.dumps(rec, indent=1))
        results.append(rec)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return results


def run_train(args):
    env = ChargaxEnv(EnvConfig(scenario=args.scenario, traffic=args.traffic))
    cfg = PPOConfig(
        total_timesteps=args.timesteps,
        num_envs=args.num_envs,
        rollout_steps=args.rollout,
    )
    train = jax.jit(make_train(cfg, env))
    t0 = time.perf_counter()
    out = train(jax.random.key(args.seed))
    jax.block_until_ready(out["metrics"]["rollout_reward"])
    wall = time.perf_counter() - t0
    rr = out["metrics"]["rollout_reward"]
    print(
        f"[ppo] {args.timesteps:,} steps in {wall:.1f}s "
        f"({args.timesteps/wall:,.0f} env-steps/s) | "
        f"reward first->last: {float(rr[0]):.1f} -> {float(rr[-1]):.1f}"
    )
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--scenario", default="shopping")
    ap.add_argument("--traffic", default="medium")
    ap.add_argument("--timesteps", type=int, default=300_000)
    ap.add_argument("--num-envs", type=int, default=12)
    ap.add_argument("--rollout", type=int, default=300)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/ppo_dryrun.json")
    args = ap.parse_args(argv)
    if args.dryrun:
        return run_dryrun(args)
    return run_train(args)


if __name__ == "__main__":
    main()
