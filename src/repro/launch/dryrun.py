import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

_DOC = """Multi-pod dry-run: AOT-compile every (arch x shape x mesh) cell.

For each cell this lowers the real train/prefill/serve step with
ShapeDtypeStruct stand-ins (no allocation), compiles it for the production
mesh built from 512 forced host devices, and records:

  * compiled.memory_analysis()  — proves the cell fits per-device HBM,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline,
  * collective bytes parsed from the optimized HLO (§Roofline third term).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_stats, cost_analysis_dict
from repro.configs.registry import ARCH_IDS, applicable_shapes, build_model, get_config
from repro.distributed import sharding as shd
from repro.distributed.train_step import (
    TrainState,
    TrainStepConfig,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.optim import AdamWState


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig, n_devices: int) -> int:
    """Grad-accumulation so per-microbatch activations fit HBM comfortably.

    With chunked CE (§Perf iteration 1) the logits no longer dominate; the
    bound is per-layer activation residuals: target <= 128k tokens per
    microbatch at d_model ~ 2-4k, scaled down for the 8k-wide archs.
    """
    if shape.kind != "train":
        return 1
    token_budget = max(int(131_072 * 4096 / max(cfg.d_model, 1024)), 16_384)
    mb = 1
    while shape.tokens / mb > token_budget and mb < shape.global_batch:
        mb *= 2
    while shape.global_batch % mb != 0:
        mb *= 2
    return min(mb, shape.global_batch)


def model_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, l = shape.global_batch, shape.seq_len
    tok_sharding = NamedSharding(mesh, P(*shd.batch_spec(mesh, b), None))
    sds = lambda s, d, sh: jax.ShapeDtypeStruct(s, d, sharding=sh)
    batch = {
        "tokens": sds((b, l), jnp.int32, tok_sharding),
        "labels": sds((b, l), jnp.int32, tok_sharding),
    }
    if cfg.family == "encdec":
        frame_sharding = NamedSharding(mesh, P(*shd.batch_spec(mesh, b), None, "model"))
        batch["frames"] = sds((b, cfg.enc_seq, cfg.d_model), jnp.float32, frame_sharding)
    return batch


def input_specs(arch: str, shape_name: str = "train_4k", multi_pod: bool = False):
    """Public helper (assignment step 2): stand-ins for every model input."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    return model_inputs(cfg, SHAPES[shape_name], mesh)


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, smoke: bool = False, strategy: str = "2d", microbatches: int | None = None) -> dict:
    """Lower + compile one cell; returns the §Dry-run/§Roofline record."""
    shd.set_strategy(strategy)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    n_dev = mesh.devices.size
    record: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
    }

    key = jax.random.key(0)
    params_abs = _abstract(model.init, key)
    params_sh = shd.param_shardings(params_abs, mesh)
    rep = NamedSharding(mesh, P())

    t0 = time.perf_counter()
    ctx = shd.set_mesh(mesh)  # ambient mesh for activation constraints
    ctx.__enter__()
    if shape.kind == "train":
        mb = microbatches or default_microbatches(cfg, shape, n_dev)
        record["num_microbatches"] = mb
        ts_cfg = TrainStepConfig(num_microbatches=mb)
        step = make_train_step(model, ts_cfg)

        opt_abs = _abstract(lambda p: AdamWState(
            step=jnp.int32(0),
            mu=jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
            nu=jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
        ), params_abs)
        state_abs = TrainState(params=params_abs, opt=opt_abs, error_feedback={})
        state_sh = TrainState(
            params=params_sh,
            opt=AdamWState(step=rep, mu=params_sh, nu=params_sh),
            error_feedback={},
        )
        batch = model_inputs(cfg, shape, mesh)
        batch_sh = jax.tree_util.tree_map(lambda s: s.sharding, batch)
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state_abs, batch)
    elif shape.kind == "prefill":
        step = make_prefill_step(model)
        batch = model_inputs(cfg, shape, mesh)
        jitted = jax.jit(step, in_shardings=(params_sh, jax.tree_util.tree_map(lambda s: s.sharding, batch)))
        lowered = jitted.lower(params_abs, batch)
    else:  # decode
        b, l = shape.global_batch, shape.seq_len
        step = make_serve_step(model)
        if cfg.family == "encdec":
            enc_abs = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), jnp.float32)
            cache_abs = _abstract(
                lambda p, e: model.init_cache(p, b, l, e), params_abs, enc_abs
            )
        else:
            cache_abs = _abstract(lambda: model.init_cache(b, l))
        cache_sh = shd.cache_shardings(cache_abs, mesh, b)
        tok_sh = NamedSharding(mesh, P(*shd.batch_spec(mesh, b), None))
        tok_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(
            step,
            in_shardings=(params_sh, cache_sh, tok_sh, rep),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_abs, cache_abs, tok_abs, pos_abs)

    record["lower_s"] = round(time.perf_counter() - t0, 2)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    ctx.__exit__(None, None, None)
    record["compile_s"] = round(time.perf_counter() - t1, 2)

    # --- memory analysis (proves it fits) -----------------------------------
    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            v = getattr(mem, attr, None)
            if v is not None:
                record[attr] = int(v)
        # memory_analysis sizes are per-device (SPMD program) — verified
        # against the sharded KV-cache size of the decode cells
        args_b = record.get("argument_size_in_bytes", 0)
        temp_b = record.get("temp_size_in_bytes", 0)
        record["bytes_per_device"] = int(args_b + temp_b)
        record["fits_16g_hbm"] = bool(args_b + temp_b <= 16 * 2**30)

    # --- cost analysis (FLOPs / bytes for §Roofline) -------------------------
    cost = cost_analysis_dict(compiled)
    if cost:
        record["hlo_flops"] = float(cost.get("flops", -1))
        record["hlo_bytes"] = float(cost.get("bytes accessed", -1))

    # --- collective bytes from the optimized HLO -----------------------------
    record["collectives"] = collective_stats(compiled.as_text())
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--strategy", default="2d", choices=["2d", "fsdp", "dp"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    for arch in archs:
        shapes = (
            [s.name for s in applicable_shapes(arch)]
            if (args.all or args.shape is None)
            else [args.shape]
        )
        for shape in shapes:
            meshes = [False, True] if args.mesh == "both" else [args.mesh == "multipod"]
            for mp in meshes:
                cells.append((arch, shape, mp))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results if r.get("ok")}

    for arch, shape, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        if (arch, shape, mesh_name) in done:
            print(f"[skip] {arch} {shape} {mesh_name} (cached)")
            continue
        print(f"[cell] {arch} {shape} {mesh_name} ...", flush=True)
        t0 = time.perf_counter()
        try:
            rec = lower_cell(arch, shape, mp, smoke=args.smoke, strategy=args.strategy, microbatches=args.microbatches)
            rec["ok"] = True
            print(
                f"   ok: compile {rec['compile_s']}s, "
                f"{rec.get('bytes_per_device', 0)/2**30:.2f} GiB/dev, "
                f"{rec.get('hlo_flops', 0):.3e} flops",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001 — record and continue
            rec = {
                "arch": arch,
                "shape": shape,
                "mesh": mesh_name,
                "ok": False,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
            }
            print(f"   FAIL: {rec['error'][:200]}", flush=True)
        rec["wall_s"] = round(time.perf_counter() - t0, 2)
        results = [
            r for r in results
            if not (r["arch"] == arch and r["shape"] == shape and r["mesh"] == mesh_name)
        ] + [rec]
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()
