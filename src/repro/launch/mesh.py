"""Production meshes (assignment spec).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then builds these meshes from host placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU integration tests (8 forced host devices)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_single_device_mesh():
    return jax.make_mesh(
        (1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2
    )
