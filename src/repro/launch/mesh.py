"""Production meshes (assignment spec).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then builds these meshes from host placeholder devices.

``_make_mesh`` wraps ``jax.make_mesh`` across JAX versions: the
``axis_types`` kwarg only exists on newer releases, and very old ones lack
``jax.make_mesh`` entirely (fall back to ``Mesh`` over reshaped devices).
"""
from __future__ import annotations

import jax
import numpy as np


def _make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    make = getattr(jax, "make_mesh", None)
    if make is not None:
        axis_type = getattr(jax.sharding, "AxisType", None)
        if axis_type is not None:
            return make(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
        return make(shape, axes)
    n = int(np.prod(shape))
    return jax.sharding.Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU integration tests (8 forced host devices)."""
    return _make_mesh(shape, axes)


def make_single_device_mesh():
    return _make_mesh((1, 1), ("data", "model"))


def make_data_mesh(n_model: int = 1):
    """Host-count-aware mesh over ALL visible devices: data axis = device
    count // n_model.  This is the mesh env-batch sharding wants — fleet
    stations / PPO envs over 'data', nothing over 'model' — and it adapts to
    however many devices the process sees (1 CPU, N forced host devices,
    a real multi-chip slice).
    """
    n_dev = jax.device_count()
    if n_dev % n_model:
        raise ValueError(f"device count {n_dev} not divisible by n_model={n_model}")
    return _make_mesh((n_dev // n_model, n_model), ("data", "model"))
