"""Chargax environment — the canonical ``repro.envs.Environment`` implementation.

    env = ChargaxEnv(EnvConfig(scenario="shopping"))
    obs, state = env.reset(key)
    ts = env.step(key, state, action)            # ts: repro.envs.TimeStep
    obs, state, reward, done, info = ts          # ...which unpacks as before

``reset``/``step`` are pure and jit/vmap/scan-compatible; all configuration
that changes array *shapes* or python control flow lives in the static
``EnvConfig``, everything numeric lives in the ``EnvParams`` pytree so sweeps
(alpha weights, price years, traffic levels) never recompile.  Shapes and
bounds are typed: ``env.observation_space`` / ``env.action_space``
(:mod:`repro.envs.spaces`); batching, auto-reset and fleet composition come
from the wrapper stack in :mod:`repro.envs.wrappers`.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import datasets, station
from repro.core.rewards import compute_reward, step_energies
from repro.core.state import EnvParams, EnvState, RewardWeights
from repro.core.transition import (
    apply_actions,
    arrive_cars,
    charge_cars,
    charge_rate,
    decode_action,
    depart_cars,
)
from repro.envs import spaces
from repro.envs.base import Environment, TimeStep
from repro.obs import annotate
from repro.utils import replace, steps_per_day


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    """Static environment configuration (hashable; part of the jit cache key)."""

    # scenario selection (paper Table 1)
    scenario: str = "shopping"  # user profile: highway|residential|work|shopping
    traffic: str = "medium"  # low|medium|high
    price_region: str = "NL"  # NL|FR|DE
    price_year: int = 2021
    car_region: str = "EU"  # EU|US|World
    architecture: str = "paper_16"  # key into station.ARCHITECTURES
    # timing
    dt_minutes: float = 5.0
    episode_hours: float = 24.0
    # action space
    discretization: int = 10  # paper Table 3
    allow_v2g: bool = False  # car discharging
    action_mode: str = "direct"  # "direct" | "delta"
    # battery
    battery: bool = True
    # observation
    obs_price_horizon_hours: float = 4.0
    # fleet padding: pad the station to this many EVSEs/nodes (0 = no padding)
    # so heterogeneous stations share one array shape and one jit cache entry
    pad_evse: int = 0
    pad_nodes: int = 0

    @property
    def steps_per_day(self) -> int:
        return steps_per_day(self.dt_minutes)

    @property
    def episode_steps(self) -> int:
        return int(round(self.episode_hours * 60.0 / self.dt_minutes))

    @property
    def dt_hours(self) -> float:
        return self.dt_minutes / 60.0


class ChargaxEnv(Environment):
    """Paper's environment. Instances are cheap; arrays live in ``default_params``."""

    def __init__(self, config: EnvConfig | None = None):
        self.config = config or EnvConfig()
        layout = station.ARCHITECTURES[self.config.architecture]()
        # the env config is authoritative about battery presence
        if layout.battery.enabled != self.config.battery:
            layout = dataclasses.replace(
                layout,
                battery=dataclasses.replace(
                    layout.battery, enabled=self.config.battery
                ),
            )
        if self.config.pad_evse or self.config.pad_nodes:
            layout = station.pad_layout(
                layout,
                max(self.config.pad_evse, layout.n_evse),
                max(self.config.pad_nodes, layout.n_nodes),
            )
        self.layout = layout
        self.n_evse = layout.n_evse

    # ------------------------------------------------------------------
    # Parameters
    # ------------------------------------------------------------------
    @cached_property
    def default_params(self) -> EnvParams:
        return self.make_params()

    def make_params(
        self,
        weights: RewardWeights | None = None,
        price_year: int | None = None,
        traffic: str | float | None = None,
        profile: str | None = None,
        price_region: str | None = None,
        car_region: str | None = None,
    ) -> EnvParams:
        """Build the numeric parameter pytree.

        The keyword overrides select different bundled datasets without a new
        env (all results share one shape, so sweeps never recompile); the
        scenario subsystem (:mod:`repro.scenarios`) layers PV/tariff/seasonal
        arrays on top of the result with plain ``replace``.
        """
        cfg, lay = self.config, self.layout
        profile = profile or cfg.scenario
        prices = datasets.price_profile(
            price_region or cfg.price_region, price_year or cfg.price_year, cfg.dt_minutes
        )
        arrivals = datasets.arrival_rate_curve(
            profile, traffic if traffic is not None else cfg.traffic, cfg.dt_minutes
        )
        cars = datasets.car_table(car_region or cfg.car_region)
        user = datasets.user_profile_params(profile)
        stay_mean, stay_sigma = user["stay"]
        # lognormal: E[X] = exp(mu + sigma^2/2) -> mu = log(mean) - sigma^2/2
        stay_mu_log = float(np.log(stay_mean) - 0.5 * stay_sigma**2)

        # battery column participates in the root constraint only
        batt_col = np.zeros((lay.n_nodes, 1), dtype=np.float32)
        if lay.battery.enabled:
            batt_col[0, 0] = 1.0
        member = np.concatenate([lay.member, batt_col], axis=1)

        b = lay.battery
        benabled = float(b.enabled)
        return EnvParams(
            member=jnp.asarray(member),
            node_budget=jnp.asarray(lay.node_limit * lay.node_eff),
            evse_voltage=jnp.asarray(lay.evse_voltage),
            evse_max_current=jnp.asarray(lay.evse_max_current),
            evse_path_eff=jnp.asarray(lay.evse_path_eff),
            evse_is_dc=jnp.asarray(lay.evse_is_dc),
            evse_mask=jnp.asarray(lay.mask),
            evse_v2g_mask=jnp.asarray(lay.mask),  # default: every real lane
            #   is bidirectional hardware; scenarios lower a fraction instead
            batt_voltage=jnp.float32(b.voltage),
            batt_max_current=jnp.float32(b.max_current * benabled),
            batt_capacity=jnp.float32(b.capacity_kwh),
            batt_eff=jnp.float32(b.efficiency),
            batt_tau=jnp.float32(b.tau),
            batt_init_soc=jnp.float32(b.init_soc * benabled),
            price_buy_table=jnp.asarray(prices),
            arrival_rate=jnp.asarray(arrivals),
            arrival_day_scale=jnp.ones((datasets.DAYS_PER_YEAR,), jnp.float32),
            pv_kw_table=jnp.zeros(
                (datasets.DAYS_PER_YEAR, cfg.steps_per_day), jnp.float32
            ),
            car_probs=jnp.asarray(cars[:, 0]),
            car_capacity=jnp.asarray(cars[:, 1]),
            car_ac_kw=jnp.asarray(cars[:, 2]),
            car_dc_kw=jnp.asarray(cars[:, 3]),
            car_tau=jnp.asarray(cars[:, 4]),
            stay_mu_log=jnp.float32(stay_mu_log),
            stay_sigma=jnp.float32(stay_sigma),
            target_soc_mu=jnp.float32(user["target"][0]),
            target_soc_std=jnp.float32(user["target"][1]),
            soc0_a=jnp.float32(user["soc0"][0]),
            soc0_b=jnp.float32(user["soc0"][1]),
            p_time_sensitive=jnp.float32(user["p_time_sensitive"]),
            p_sell=jnp.float32(0.75),  # Table 3
            p_v2g_comp=jnp.float32(0.75),  # = p_sell: V2G spread off by default
            grid_sell_discount=jnp.float32(0.9),
            facility_cost=jnp.float32(3.0),  # EUR per hour (0.25 / 5-min step)
            demand_charge_rate=jnp.float32(0.0),  # flat tariff by default
            demand_contract_kw=jnp.float32(0.0),
            moer_scale=jnp.float32(0.4),
            grid_demand_amp=jnp.float32(20.0),
            weights=weights or RewardWeights(),
        )

    # ------------------------------------------------------------------
    # Spaces (the typed source of truth; the integer properties below are
    # thin aliases kept for existing call sites)
    # ------------------------------------------------------------------
    @cached_property
    def action_space(self) -> spaces.MultiDiscrete:
        """N EVSE heads + 1 battery head (paper: battery = (N+1)-th pole),
        each with ``2 * discretization + 1`` levels."""
        return spaces.MultiDiscrete(
            np.full((self.n_evse + 1,), 2 * self.config.discretization + 1)
        )

    @cached_property
    def observation_space(self) -> spaces.Box:
        """Flat float32 observation.

        Layout (8 features per port since the V2G debt feature): ``8 * n_evse``
        port features [occupied, current/imax, soc, e_remain/cap, v2g_debt/cap,
        t_remain/spd, rhat/imax, user_type], 2 battery features, 4 time
        features, 3 price features — see :meth:`observe`.
        """
        n = self.n_evse
        return spaces.Box(-np.inf, np.inf, (8 * n + 2 + 4 + 3,))

    @property
    def num_action_heads(self) -> int:
        return self.action_space.shape[0]

    @property
    def num_actions_per_head(self) -> int:
        return self.action_space.num_categories

    @property
    def obs_dim(self) -> int:
        return self.observation_space.shape[0]

    # ------------------------------------------------------------------
    # Reset / step
    # ------------------------------------------------------------------
    def reset(
        self, key: jax.Array, params: EnvParams | None = None
    ) -> tuple[jnp.ndarray, EnvState]:
        params = params if params is not None else self.default_params
        n = self.n_evse
        k_day, _ = jax.random.split(key)
        # exploring-starts over the price dataset (paper App. B.1): pick a day
        day = jax.random.randint(k_day, (), 0, params.price_buy_table.shape[0])
        zf = jnp.zeros((n,), jnp.float32)
        zi = jnp.zeros((n,), jnp.int32)
        state = EnvState(
            evse_current=zf,
            occupied=zf,
            soc=zf,
            e_remain=zf,
            v2g_debt=zf,
            batt_current=jnp.float32(0.0),
            batt_soc=params.batt_init_soc,
            t_remain=zi,
            rhat=zf,
            cap=zf,
            rbar=zf,
            tau=zf,
            user_type=zf,
            t=jnp.int32(0),
            day=day,
            price_buy=params.price_buy_table[day],
            profit_cum=jnp.float32(0.0),
            energy_delivered=jnp.float32(0.0),
            energy_discharged=jnp.float32(0.0),
            cars_served=jnp.float32(0.0),
            cars_rejected=jnp.float32(0.0),
            missing_kwh_cum=jnp.float32(0.0),
            overtime_steps_cum=jnp.float32(0.0),
        )
        return self.observe(state, params), state

    def step(
        self,
        key: jax.Array,
        state: EnvState,
        action: jnp.ndarray,
        params: EnvParams | None = None,
    ) -> TimeStep:
        params = params if params is not None else self.default_params
        cfg = self.config
        dt = cfg.dt_hours

        # -- decode action ------------------------------------------------
        with annotate("env/decode"):
            if cfg.action_mode == "direct":
                tgt_evse, tgt_batt = decode_action(
                    action,
                    cfg.discretization,
                    cfg.allow_v2g,
                    params.evse_max_current,
                    params.batt_max_current,
                    v2g_mask=params.evse_v2g_mask,
                )
            elif cfg.action_mode == "delta":  # paper's additive form
                d_evse, d_batt = decode_action(
                    action,
                    cfg.discretization,
                    True,  # deltas may be negative even without v2g...
                    params.evse_max_current,
                    params.batt_max_current,
                )
                tgt_evse = state.evse_current + d_evse
                if not cfg.allow_v2g:
                    tgt_evse = jnp.maximum(tgt_evse, 0.0)  # ...but targets may not
                else:  # charge-only hardware never targets negative amps
                    tgt_evse = jnp.where(
                        params.evse_v2g_mask > 0.5, tgt_evse, jnp.maximum(tgt_evse, 0.0)
                    )
                tgt_batt = state.batt_current + d_batt
            else:
                raise ValueError(f"unknown action_mode {cfg.action_mode!r}")

        # -- 4-stage transition (paper App. A.2) ---------------------------
        with annotate("env/apply_actions"):
            applied = apply_actions(params, state, tgt_evse, tgt_batt, dt)
        with annotate("env/charge_cars"):
            charged = charge_cars(params, state, applied, dt)
        with annotate("env/depart_arrive"):
            departed = depart_cars(charged.state)
            key, k_arr = jax.random.split(key)
            arrived = arrive_cars(params, departed.state, k_arr)

        # -- reward ---------------------------------------------------------
        with annotate("env/reward"):
            spd = state.price_buy.shape[0]
            e_pv = (
                params.pv_kw_table[
                    jnp.mod(state.day, params.pv_kw_table.shape[0]),
                    jnp.mod(state.t, spd),
                ]
                * dt
            )
            energies = step_energies(
                params, charged.e_car, charged.e_batt_net, e_pv, charged.e_repaid
            )
            p_buy = state.price_buy[jnp.mod(state.t, spd)]
            reward, pi, pen = compute_reward(
                params,
                energies,
                p_buy,
                applied.constraint_excess,
                departed.missing_kwh,
                departed.overtime_steps,
                departed.early_steps,
                arrived.n_rejected,
                charged.e_car,
                state.t,
                state.price_buy,
                dt,
            )

        # -- calendar rollover: at midnight advance the day (mod table length)
        # and reload the price row, so multi-day episodes see day-1+ prices,
        # PV, arrival-day-scale and the weekday feature instead of replaying
        # day 0 forever
        t_next = state.t + 1
        n_days = params.price_buy_table.shape[0]
        midnight = jnp.mod(t_next, spd) == 0
        day_next = jnp.where(midnight, jnp.mod(state.day + 1, n_days), state.day)
        price_next = jnp.where(
            midnight, params.price_buy_table[day_next], state.price_buy
        )
        new_state = replace(
            arrived.state,
            t=t_next,
            day=day_next,
            price_buy=price_next,
            profit_cum=state.profit_cum + pi,
        )
        done = new_state.t >= cfg.episode_steps
        info = {
            "profit": pi,
            "reward": reward,
            "e_net": energies.e_net,
            "e_grid_net": energies.e_grid_net,
            "e_pv": energies.e_pv,
            "constraint_excess": pen.constraint,
            "missing_kwh": pen.satisfaction_time,
            "overtime_steps": departed.overtime_steps,
            "rejected": pen.rejected,
            "arrived": arrived.n_arrived.astype(jnp.float32),
            "price_buy": p_buy,
            # per-step KPI scalars for the obs metrics accumulators (unused
            # outputs are DCE'd by XLA, so consumers that ignore them pay
            # nothing): kWh into / out of cars this step, open V2G debt
            "energy_delivered": jnp.sum(jnp.maximum(charged.e_car, 0.0)),
            "energy_discharged": jnp.sum(jnp.maximum(-charged.e_car, 0.0)),
            "v2g_debt": jnp.sum(new_state.v2g_debt),
        }
        with annotate("env/observe"):
            obs = self.observe(new_state, params)
        return TimeStep(obs, new_state, reward, done, info)

    # ------------------------------------------------------------------
    # Observation
    # ------------------------------------------------------------------
    def observe(self, state: EnvState, params: EnvParams) -> jnp.ndarray:
        cfg = self.config
        spd = cfg.steps_per_day
        imax = params.evse_max_current
        port_feats = jnp.stack(
            [
                state.occupied,
                state.evse_current / imax,
                state.soc,
                state.e_remain / jnp.maximum(state.cap, 1.0),
                # V2G debt: how much of the remaining request is energy the
                # station borrowed (repaid at p_v2g_comp, not billed) — the
                # agent needs this to price discharge decisions correctly
                state.v2g_debt / jnp.maximum(state.cap, 1.0),
                jnp.clip(state.t_remain.astype(jnp.float32) / spd, -1.0, 1.0),
                state.rhat / imax,
                state.user_type,
            ],
            axis=-1,
        ).reshape(-1)
        batt_feats = jnp.stack(
            [state.batt_soc, state.batt_current / jnp.maximum(params.batt_max_current, 1.0)]
        )
        tf = state.t.astype(jnp.float32)
        phase = 2.0 * jnp.pi * tf / spd
        weekday = ((state.day % 7) < 5).astype(jnp.float32)
        time_feats = jnp.stack(
            [jnp.sin(phase), jnp.cos(phase), weekday, state.day.astype(jnp.float32) / 365.0]
        )
        idx = jnp.mod(state.t, spd)
        horizon = max(int(cfg.obs_price_horizon_hours * spd / 24), 1)
        ahead = state.price_buy[jnp.mod(idx + jnp.arange(horizon), spd)]
        near = max(int(spd / 24), 1)
        price_feats = jnp.stack(
            [state.price_buy[idx], jnp.mean(ahead[:near]), jnp.mean(ahead)]
        )
        return jnp.concatenate([port_feats, batt_feats, time_feats, price_feats])


def make_baseline_max_action(env: ChargaxEnv):
    """Paper's baseline as a policy: 'always charge to maximum potential'.

    Max level on every EVSE head; battery idle (centre level).  Returns a
    ``policy(params, key, obs) -> action`` callable like every other
    baseline (``repro.rl.baselines``) — the historical version returned a
    bare action array, the odd one out.  ``obs``'s leading axes set the
    batch shape; ``params``/``key`` are ignored (the policy is constant).
    """
    d = env.config.discretization
    space = env.action_space
    a = jnp.full(space.shape, 2 * d, dtype=space.dtype)
    a = a.at[..., -1].set(d)  # battery: 0 amps

    def policy(params, key, obs):
        return jnp.broadcast_to(a, jnp.shape(obs)[:-1] + a.shape)

    return policy
