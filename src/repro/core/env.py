"""Chargax environment — the canonical ``repro.envs.Environment`` implementation.

    env = ChargaxEnv(EnvConfig(scenario="shopping"))
    obs, state = env.reset(key)
    ts = env.step(key, state, action)            # ts: repro.envs.TimeStep
    obs, state, reward, done, info = ts          # ...which unpacks as before

``reset``/``step`` are pure and jit/vmap/scan-compatible; all configuration
that changes array *shapes* or python control flow lives in the static
``EnvConfig``, everything numeric lives in the ``EnvParams`` pytree so sweeps
(alpha weights, price years, traffic levels) never recompile.  Shapes and
bounds are typed: ``env.observation_space`` / ``env.action_space``
(:mod:`repro.envs.spaces`); batching, auto-reset and fleet composition come
from the wrapper stack in :mod:`repro.envs.wrappers`.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import datasets, station, transition
from repro.core.state import EnvParams, EnvState, RewardWeights
from repro.core.transition import GRID_CAP_UNLIMITED, AllocationResult
from repro.envs import spaces
from repro.envs.base import Environment, TimeStep
from repro.obs import annotate
from repro.utils import steps_per_day


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    """Static environment configuration (hashable; part of the jit cache key)."""

    # scenario selection (paper Table 1)
    scenario: str = "shopping"  # user profile: highway|residential|work|shopping
    traffic: str = "medium"  # low|medium|high
    price_region: str = "NL"  # NL|FR|DE
    price_year: int = 2021
    car_region: str = "EU"  # EU|US|World
    architecture: str = "paper_16"  # key into station.ARCHITECTURES
    # timing
    dt_minutes: float = 5.0
    episode_hours: float = 24.0
    # action space
    discretization: int = 10  # paper Table 3
    allow_v2g: bool = False  # car discharging
    action_mode: str = "direct"  # "direct" | "delta"
    # battery
    battery: bool = True
    # observation
    obs_price_horizon_hours: float = 4.0
    # fleet padding: pad the station to this many EVSEs/nodes (0 = no padding)
    # so heterogeneous stations share one array shape and one jit cache entry
    pad_evse: int = 0
    pad_nodes: int = 0
    # hot path: route request/allocate/deliver through the fused step kernel
    # (kernels/chargax_step) — Pallas on TPU/GPU, bit-exact jnp ref on CPU;
    # see docs/kernels.md.  Off by default: flag-off params and HLO are
    # identical to builds that predate the flag.
    fused_step: bool = False

    @property
    def steps_per_day(self) -> int:
        return steps_per_day(self.dt_minutes)

    @property
    def episode_steps(self) -> int:
        return int(round(self.episode_hours * 60.0 / self.dt_minutes))

    @property
    def dt_hours(self) -> float:
        return self.dt_minutes / 60.0


class ChargaxEnv(Environment):
    """Paper's environment. Instances are cheap; arrays live in ``default_params``."""

    def __init__(self, config: EnvConfig | None = None):
        self.config = config or EnvConfig()
        layout = station.ARCHITECTURES[self.config.architecture]()
        # the env config is authoritative about battery presence
        if layout.battery.enabled != self.config.battery:
            layout = dataclasses.replace(
                layout,
                battery=dataclasses.replace(
                    layout.battery, enabled=self.config.battery
                ),
            )
        if self.config.pad_evse or self.config.pad_nodes:
            layout = station.pad_layout(
                layout,
                max(self.config.pad_evse, layout.n_evse),
                max(self.config.pad_nodes, layout.n_nodes),
            )
        self.layout = layout
        self.n_evse = layout.n_evse

    # ------------------------------------------------------------------
    # Parameters
    # ------------------------------------------------------------------
    @cached_property
    def default_params(self) -> EnvParams:
        return self.make_params()

    def make_params(
        self,
        weights: RewardWeights | None = None,
        price_year: int | None = None,
        traffic: str | float | None = None,
        profile: str | None = None,
        price_region: str | None = None,
        car_region: str | None = None,
    ) -> EnvParams:
        """Build the numeric parameter pytree.

        The keyword overrides select different bundled datasets without a new
        env (all results share one shape, so sweeps never recompile); the
        scenario subsystem (:mod:`repro.scenarios`) layers PV/tariff/seasonal
        arrays on top of the result with plain ``replace``.
        """
        cfg, lay = self.config, self.layout
        profile = profile or cfg.scenario
        prices = datasets.price_profile(
            price_region or cfg.price_region, price_year or cfg.price_year, cfg.dt_minutes
        )
        arrivals = datasets.arrival_rate_curve(
            profile, traffic if traffic is not None else cfg.traffic, cfg.dt_minutes
        )
        cars = datasets.car_table(car_region or cfg.car_region)
        user = datasets.user_profile_params(profile)
        stay_mean, stay_sigma = user["stay"]
        # lognormal: E[X] = exp(mu + sigma^2/2) -> mu = log(mean) - sigma^2/2
        stay_mu_log = float(np.log(stay_mean) - 0.5 * stay_sigma**2)

        # battery column participates in the root constraint only
        batt_col = np.zeros((lay.n_nodes, 1), dtype=np.float32)
        if lay.battery.enabled:
            batt_col[0, 0] = 1.0
        member = np.concatenate([lay.member, batt_col], axis=1)

        b = lay.battery
        benabled = float(b.enabled)
        p = EnvParams(
            member=jnp.asarray(member),
            node_budget=jnp.asarray(lay.node_limit * lay.node_eff),
            evse_voltage=jnp.asarray(lay.evse_voltage),
            evse_max_current=jnp.asarray(lay.evse_max_current),
            evse_path_eff=jnp.asarray(lay.evse_path_eff),
            evse_is_dc=jnp.asarray(lay.evse_is_dc),
            evse_mask=jnp.asarray(lay.mask),
            evse_v2g_mask=jnp.asarray(lay.mask),  # default: every real lane
            #   is bidirectional hardware; scenarios lower a fraction instead
            batt_voltage=jnp.float32(b.voltage),
            batt_max_current=jnp.float32(b.max_current * benabled),
            batt_capacity=jnp.float32(b.capacity_kwh),
            batt_eff=jnp.float32(b.efficiency),
            batt_tau=jnp.float32(b.tau),
            batt_init_soc=jnp.float32(b.init_soc * benabled),
            price_buy_table=jnp.asarray(prices),
            arrival_rate=jnp.asarray(arrivals),
            arrival_day_scale=jnp.ones((datasets.DAYS_PER_YEAR,), jnp.float32),
            pv_kw_table=jnp.zeros(
                (datasets.DAYS_PER_YEAR, cfg.steps_per_day), jnp.float32
            ),
            grid_cap_kw_table=jnp.full(
                (datasets.DAYS_PER_YEAR, cfg.steps_per_day),
                GRID_CAP_UNLIMITED,
                jnp.float32,
            ),
            grid_setpoint_kw_table=jnp.zeros(
                (datasets.DAYS_PER_YEAR, cfg.steps_per_day), jnp.float32
            ),
            car_probs=jnp.asarray(cars[:, 0]),
            car_capacity=jnp.asarray(cars[:, 1]),
            car_ac_kw=jnp.asarray(cars[:, 2]),
            car_dc_kw=jnp.asarray(cars[:, 3]),
            car_tau=jnp.asarray(cars[:, 4]),
            stay_mu_log=jnp.float32(stay_mu_log),
            stay_sigma=jnp.float32(stay_sigma),
            target_soc_mu=jnp.float32(user["target"][0]),
            target_soc_std=jnp.float32(user["target"][1]),
            soc0_a=jnp.float32(user["soc0"][0]),
            soc0_b=jnp.float32(user["soc0"][1]),
            p_time_sensitive=jnp.float32(user["p_time_sensitive"]),
            p_sell=jnp.float32(0.75),  # Table 3
            p_v2g_comp=jnp.float32(0.75),  # = p_sell: V2G spread off by default
            grid_sell_discount=jnp.float32(0.9),
            facility_cost=jnp.float32(3.0),  # EUR per hour (0.25 / 5-min step)
            demand_charge_rate=jnp.float32(0.0),  # flat tariff by default
            demand_contract_kw=jnp.float32(0.0),
            moer_scale=jnp.float32(0.4),
            grid_demand_amp=jnp.float32(20.0),
            weights=weights or RewardWeights(),
        )
        if cfg.fused_step:
            # hoist the kernel's lane-padded pole pack out of the per-step
            # path: built once here, carried through scenario lowering (which
            # only swaps tables/economics, never the electrical fields below)
            from repro.kernels.chargax_step import ops as fused_ops

            p = dataclasses.replace(p, pole=fused_ops.build_pole_params(p))
        return p

    # ------------------------------------------------------------------
    # Spaces (the typed source of truth; the integer properties below are
    # thin aliases kept for existing call sites)
    # ------------------------------------------------------------------
    @cached_property
    def action_space(self) -> spaces.MultiDiscrete:
        """N EVSE heads + 1 battery head (paper: battery = (N+1)-th pole),
        each with ``2 * discretization + 1`` levels."""
        return spaces.MultiDiscrete(
            np.full((self.n_evse + 1,), 2 * self.config.discretization + 1)
        )

    @cached_property
    def observation_space(self) -> spaces.Box:
        """Flat float32 observation.

        Layout (8 features per port since the V2G debt feature): ``8 * n_evse``
        port features [occupied, current/imax, soc, e_remain/cap, v2g_debt/cap,
        t_remain/spd, rhat/imax, user_type], 2 battery features, 4 time
        features, 3 price features — see :meth:`observe`.
        """
        n = self.n_evse
        return spaces.Box(-np.inf, np.inf, (8 * n + 2 + 4 + 3,))

    @property
    def num_action_heads(self) -> int:
        return self.action_space.shape[0]

    @property
    def num_actions_per_head(self) -> int:
        return self.action_space.num_categories

    @property
    def obs_dim(self) -> int:
        return self.observation_space.shape[0]

    # ------------------------------------------------------------------
    # Reset / step
    # ------------------------------------------------------------------
    def reset(
        self, key: jax.Array, params: EnvParams | None = None
    ) -> tuple[jnp.ndarray, EnvState]:
        params = params if params is not None else self.default_params
        n = self.n_evse
        k_day, _ = jax.random.split(key)
        # exploring-starts over the price dataset (paper App. B.1): pick a day
        day = jax.random.randint(k_day, (), 0, params.price_buy_table.shape[0])
        zf = jnp.zeros((n,), jnp.float32)
        zi = jnp.zeros((n,), jnp.int32)
        state = EnvState(
            evse_current=zf,
            occupied=zf,
            soc=zf,
            e_remain=zf,
            v2g_debt=zf,
            batt_current=jnp.float32(0.0),
            batt_soc=params.batt_init_soc,
            t_remain=zi,
            rhat=zf,
            cap=zf,
            rbar=zf,
            tau=zf,
            user_type=zf,
            t=jnp.int32(0),
            day=day,
            price_buy=params.price_buy_table[day],
            profit_cum=jnp.float32(0.0),
            energy_delivered=jnp.float32(0.0),
            energy_discharged=jnp.float32(0.0),
            cars_served=jnp.float32(0.0),
            cars_rejected=jnp.float32(0.0),
            missing_kwh_cum=jnp.float32(0.0),
            overtime_steps_cum=jnp.float32(0.0),
        )
        return self.observe(state, params), state

    def step(
        self,
        key: jax.Array,
        state: EnvState,
        action: jnp.ndarray,
        params: EnvParams | None = None,
    ) -> TimeStep:
        """One transition = pure composition of the staged pipeline
        (:mod:`repro.core.transition`)::

            decode -> request -> allocate -> deliver -> depart_arrive
                   -> settle -> advance_time -> observe

        The ``request_stage`` / ``allocate`` / ``finish_step`` seams are
        public so :class:`repro.core.fleet.FleetEnv` can interpose a shared
        feeder-cap curtailment between the vmapped halves.

        With ``EnvConfig.fused_step`` on, the request/allocate/deliver
        stages route through the fused kernel package instead
        (:func:`repro.kernels.chargax_step.ops.fused_transition`); the
        settle tail is shared.
        """
        params = params if params is not None else self.default_params
        cfg = self.config
        if cfg.fused_step:
            from repro.kernels.chargax_step import ops as fused_ops

            with annotate("env/decode"):
                tgt_evse, tgt_batt = transition.decode(
                    params,
                    state,
                    action,
                    discretization=cfg.discretization,
                    allow_v2g=cfg.allow_v2g,
                    action_mode=cfg.action_mode,
                )
            with annotate("env/fused_transition"):
                alloc, charged = fused_ops.fused_transition(
                    params, state, tgt_evse, tgt_batt, cfg.dt_hours
                )
            return self.settle_tail(key, state, alloc, charged, params)
        applied = self.request_stage(state, action, params)
        with annotate("env/allocate"):
            alloc = transition.allocate(params, state, applied)
        return self.finish_step(key, state, alloc, params)

    def with_fused_step(self, fused: bool) -> "ChargaxEnv":
        """This env with the fused hot path on/off (self if already so)."""
        if self.config.fused_step == bool(fused):
            return self
        return ChargaxEnv(dataclasses.replace(self.config, fused_step=bool(fused)))

    def request_stage(
        self,
        state: EnvState,
        action: jnp.ndarray,
        params: EnvParams | None = None,
    ) -> transition.AppliedActions:
        """Pipeline stages decode + request: action -> constrained currents."""
        params = params if params is not None else self.default_params
        cfg = self.config
        with annotate("env/decode"):
            tgt_evse, tgt_batt = transition.decode(
                params,
                state,
                action,
                discretization=cfg.discretization,
                allow_v2g=cfg.allow_v2g,
                action_mode=cfg.action_mode,
            )
        with annotate("env/apply_actions"):
            return transition.request(params, state, tgt_evse, tgt_batt, cfg.dt_hours)

    def finish_step(
        self,
        key: jax.Array,
        state: EnvState,
        alloc: AllocationResult,
        params: EnvParams | None = None,
        arrival_rate_extra: jnp.ndarray | None = None,
    ) -> TimeStep:
        """Pipeline stages deliver -> depart_arrive -> settle -> advance_time
        -> observe, from an :class:`AllocationResult` (``state`` is the
        pre-step state the allocation was computed against).

        ``arrival_rate_extra`` (scalar, cars/step) adds to the Poisson arrival
        rate this step — the seam through which the city demand-allocation
        layer (:mod:`repro.city`) turns arrival rates into a per-station input
        computed from the population stream instead of a fixed table.
        """
        params = params if params is not None else self.default_params
        with annotate("env/charge_cars"):
            charged = transition.deliver(
                params, state, alloc.applied, self.config.dt_hours
            )
        return self.settle_tail(key, state, alloc, charged, params, arrival_rate_extra)

    def settle_tail(
        self,
        key: jax.Array,
        state: EnvState,
        alloc: AllocationResult,
        charged: transition.ChargeResult,
        params: EnvParams | None = None,
        arrival_rate_extra: jnp.ndarray | None = None,
    ) -> TimeStep:
        """Pipeline tail shared by the staged and fused routes:
        depart_arrive -> settle -> advance_time -> observe, from an already
        delivered :class:`ChargeResult`."""
        params = params if params is not None else self.default_params
        cfg = self.config
        dt = cfg.dt_hours
        with annotate("env/depart_arrive"):
            moved = transition.depart_arrive(
                params, charged.state, key, arrival_rate_extra
            )
        with annotate("env/reward"):
            settled = transition.settle(params, state, alloc, charged, moved, dt)
        new_state = transition.advance_time(params, moved.state, settled.profit)
        done = new_state.t >= cfg.episode_steps
        pen = settled.penalties
        info = {
            "profit": settled.profit,
            "reward": settled.reward,
            "e_net": settled.energies.e_net,
            "e_grid_net": settled.energies.e_grid_net,
            "e_pv": settled.energies.e_pv,
            "constraint_excess": pen.constraint,
            "missing_kwh": pen.satisfaction_time,
            "overtime_steps": moved.overtime_steps,
            "rejected": pen.rejected,
            "arrived": moved.n_arrived.astype(jnp.float32),
            "price_buy": settled.p_buy,
            # per-step KPI scalars for the obs metrics accumulators (unused
            # outputs are DCE'd by XLA, so consumers that ignore them pay
            # nothing): kWh into / out of cars this step, open V2G debt
            "energy_delivered": jnp.sum(jnp.maximum(charged.e_car, 0.0)),
            "energy_discharged": jnp.sum(jnp.maximum(-charged.e_car, 0.0)),
            "v2g_debt": jnp.sum(new_state.v2g_debt),
            # grid-coupling KPIs (kW): station draw vs the feeder envelope
            "grid/power_drawn": alloc.power_kw,
            "grid/cap": alloc.cap_kw,
            "grid/violation": alloc.violation_kw,
            "grid/setpoint_dev": settled.setpoint_dev_kw,
        }
        with annotate("env/observe"):
            obs = self.observe(new_state, params)
        return TimeStep(obs, new_state, settled.reward, done, info)

    # ------------------------------------------------------------------
    # Observation
    # ------------------------------------------------------------------
    def observe(self, state: EnvState, params: EnvParams) -> jnp.ndarray:
        cfg = self.config
        spd = cfg.steps_per_day
        return transition.observe(
            params,
            state,
            steps_per_day=spd,
            horizon_steps=max(int(cfg.obs_price_horizon_hours * spd / 24), 1),
            near_steps=max(int(spd / 24), 1),
        )


def make_baseline_max_action(env: ChargaxEnv):
    """Deprecated alias — moved to :func:`repro.rl.baselines.make_baseline_max_action`.

    Policy code does not belong in the physics module; import from
    ``repro.rl.baselines`` (or use ``BASELINES['max_charge']``).
    """
    import warnings

    warnings.warn(
        "repro.core.make_baseline_max_action is deprecated; import it from "
        "repro.rl.baselines (or use rl.baselines.BASELINES['max_charge'])",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.rl.baselines import make_baseline_max_action as _impl

    return _impl(env)
