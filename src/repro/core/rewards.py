"""Reward algebra (paper §4 "Reward Function", Appendix A.3).

``profit`` implements Eq. 1/2; ``compute_reward`` implements Eq. 3/7:
``r(t) = Pi(t) - sum_c alpha_c * c(t)`` with the paper's bundled penalty terms.
Every term is always computed and returned in ``info`` (they are cheap), so
evaluation can report satisfaction/sustainability metrics even when their
alpha weight is zero.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.state import EnvParams


class StepEnergies(NamedTuple):
    """Grid-side energy bookkeeping for one step (all kWh, signed)."""

    e_net: jnp.ndarray  # sum_i V_i I_i dt — energy billed to customers
    e_grid_in: jnp.ndarray  # bought from grid (>0), efficiency-inflated
    e_grid_out: jnp.ndarray  # sold to grid (<0), efficiency-deflated
    e_batt_net: jnp.ndarray  # battery grid-side energy (signed)
    e_grid_net: jnp.ndarray  # Eq. 1 total (net of on-site PV)
    e_pv: jnp.ndarray  # on-site PV generation this step (>= 0)
    e_car_in: jnp.ndarray  # kWh delivered INTO cars (>= 0), billed at p_sell
    e_car_out: jnp.ndarray  # kWh drawn OUT of cars (>= 0), paid at p_v2g_comp
    e_car_repaid: jnp.ndarray  # kWh of e_car_in repaying V2G debt: settled at
    #     p_v2g_comp instead of p_sell so cycling a pack nets zero revenue


def step_energies(
    params: EnvParams,
    e_car: jnp.ndarray,
    e_batt: jnp.ndarray,
    e_pv: jnp.ndarray | float = 0.0,
    e_repaid: jnp.ndarray | float = 0.0,
) -> StepEnergies:
    """Aggregate per-port car energies (kWh, signed) into Eq. 1 terms.

    ``e_pv`` (scenario subsystem) is generation behind the meter: it offsets
    grid purchases one-for-one and any surplus is exported through the same
    net-metering term as V2G/battery discharge.
    """
    e_net = jnp.sum(e_car)
    eff = params.evse_path_eff
    e_grid_in = jnp.sum(jnp.where(e_car > 0, e_car / eff, 0.0))
    e_grid_out = jnp.sum(jnp.where(e_car < 0, e_car * eff, 0.0))
    e_car_in = jnp.sum(jnp.maximum(e_car, 0.0))
    e_car_out = jnp.sum(jnp.maximum(-e_car, 0.0))
    e_car_repaid = jnp.sum(jnp.asarray(e_repaid, jnp.float32))
    e_pv = jnp.asarray(e_pv, jnp.float32)
    e_grid_net = e_grid_in + e_grid_out + e_batt - e_pv
    return StepEnergies(
        e_net, e_grid_in, e_grid_out, e_batt, e_grid_net, e_pv,
        e_car_in, e_car_out, e_car_repaid,
    )


def profit(
    params: EnvParams,
    energies: StepEnergies,
    p_buy: jnp.ndarray,  # () EUR/kWh this step
    dt_hours: float,
) -> jnp.ndarray:
    """Eq. 2.  p_sell,grid is a discounted buy price (net sellback).

    Customer revenue splits over the V2G spread: energy into cars is billed
    at ``p_sell``; energy drawn back out (V2G) compensates the owner at
    ``p_v2g_comp`` (defaults to ``p_sell``, which recovers the paper's
    single-price Eq. 2 exactly).  Refills that repay earlier discharge
    (``e_car_repaid``) also settle at ``p_v2g_comp`` — both legs of a
    borrow/return cycle net to zero, so the station cannot mint revenue by
    churning a pack; profit from V2G comes only from the grid-side
    buy-low/sell-high spread.  Scenario tariffs add a demand charge: grid
    draw above the contracted power (``demand_contract_kw``) is billed at
    ``demand_charge_rate`` EUR per kW per step — the per-step decomposition
    of a monthly peak fee.  ``facility_cost`` is EUR per hour, scaled by
    ``dt_hours`` so the effective cost per simulated hour is dt-invariant.
    """
    p_sell_grid = params.grid_sell_discount * p_buy
    grid_cost = jnp.where(
        energies.e_grid_net > 0,
        p_buy * energies.e_grid_net,
        p_sell_grid * energies.e_grid_net,
    )
    demand_kw = jnp.maximum(energies.e_grid_net, 0.0) / dt_hours
    demand_cost = params.demand_charge_rate * jnp.maximum(
        demand_kw - params.demand_contract_kw, 0.0
    )
    revenue = (
        params.p_sell * (energies.e_car_in - energies.e_car_repaid)
        + params.p_v2g_comp * energies.e_car_repaid
        - params.p_v2g_comp * energies.e_car_out
    )
    return revenue - grid_cost - demand_cost - params.facility_cost * dt_hours


class PenaltyTerms(NamedTuple):
    constraint: jnp.ndarray
    satisfaction_time: jnp.ndarray
    satisfaction_charge: jnp.ndarray
    sustainability: jnp.ndarray
    rejected: jnp.ndarray
    degradation: jnp.ndarray
    grid_stability: jnp.ndarray


def moer(params: EnvParams, t: jnp.ndarray, price_buy: jnp.ndarray) -> jnp.ndarray:
    """Synthetic marginal-operating-emissions-rate curve, kgCO2/kWh.

    Correlated with the (scarcity-driven) price curve — the standard stand-in
    when real MOER feeds (WattTime) are unavailable offline.
    """
    spd = price_buy.shape[0]
    p = price_buy[jnp.mod(t, spd)]
    pm = jnp.mean(price_buy)
    return params.moer_scale * jnp.clip(p / jnp.maximum(pm, 1e-6), 0.2, 3.0)


def grid_demand(params: EnvParams, t: jnp.ndarray, spd: int) -> jnp.ndarray:
    """Synthetic exogenous grid-demand signal d_grid(t) [kWh per step]."""
    phase = 2.0 * jnp.pi * (t.astype(jnp.float32) / spd)
    return params.grid_demand_amp * (0.6 + 0.4 * jnp.sin(phase - 0.5 * jnp.pi))


def compute_reward(
    params: EnvParams,
    energies: StepEnergies,
    p_buy: jnp.ndarray,
    constraint_excess: jnp.ndarray,
    missing_kwh: jnp.ndarray,
    overtime_steps: jnp.ndarray,
    early_steps: jnp.ndarray,
    n_rejected: jnp.ndarray,
    e_car: jnp.ndarray,
    t: jnp.ndarray,
    price_buy_day: jnp.ndarray,
    dt_hours: float,
) -> tuple[jnp.ndarray, jnp.ndarray, PenaltyTerms]:
    """Returns (reward, profit, penalties) for one step."""
    w = params.weights
    pi = profit(params, energies, p_buy, dt_hours)

    pen = PenaltyTerms(
        constraint=constraint_excess,
        satisfaction_time=missing_kwh,
        satisfaction_charge=overtime_steps - w.early_finish_beta * early_steps,
        sustainability=moer(params, t, price_buy_day)
        * jnp.maximum(energies.e_grid_net, 0.0),
        rejected=n_rejected.astype(jnp.float32),
        degradation=jnp.abs(jnp.minimum(energies.e_batt_net, 0.0))
        + jnp.sum(jnp.abs(jnp.minimum(e_car, 0.0))),
        grid_stability=jnp.abs(
            energies.e_net - grid_demand(params, t, price_buy_day.shape[0])
        ),
    )
    reward = (
        pi
        - w.constraint * pen.constraint
        - w.satisfaction_time * pen.satisfaction_time
        - w.satisfaction_charge * pen.satisfaction_charge
        - w.sustainability * pen.sustainability
        - w.rejected * pen.rejected
        - w.degradation * pen.degradation
        - w.grid_stability * pen.grid_stability
    )
    return reward, pi, pen
