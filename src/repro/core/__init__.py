"""Chargax core: the paper's contribution as a composable JAX module."""
from repro.core.env import ChargaxEnv, EnvConfig, make_baseline_max_action
from repro.core.fleet import FleetEnv, stack_params
from repro.core.state import EnvParams, EnvState, RewardWeights
from repro.core import station, datasets, transition, rewards

__all__ = [
    "ChargaxEnv",
    "EnvConfig",
    "FleetEnv",
    "stack_params",
    "EnvParams",
    "EnvState",
    "RewardWeights",
    "make_baseline_max_action",
    "station",
    "datasets",
    "transition",
    "rewards",
]
