"""Charging-station electrical architecture (paper §4 "EV Station Layout", Fig. 3).

The station is a tree: the root is the grid connection, internal nodes are
splitter/transformer/cable assemblies with a maximum current ``I_H`` and an
efficiency ``eta_H``, and leaves are EVSEs (charging ports).

TPU adaptation (DESIGN.md §3): the pointer tree is flattened at construction
time into dense arrays —

  * ``member``       (n_nodes, n_evse) 0/1 — leaf j lies in the subtree of node i
  * ``node_limit``   (n_nodes,)  max current I_H [A]
  * ``node_eff``     (n_nodes,)  efficiency eta_H in (0, 1]
  * per-EVSE vectors (voltage, I_max, efficiency, is_dc)

so that the Eq. 5 constraint check becomes two matmuls and a min-reduce.
All arrays are materialised as numpy at build time; the environment converts
them to ``jnp`` constants.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# Effective voltages (paper: the voltage "already encodes the phases",
# i.e. it stands for V * sqrt(phi)).  AC: 3-phase 400V line-to-line at 16A
# -> sqrt(3)*400*16 ~= 11.1 kW.  DC fast charger: 500 V at 300 A -> 150 kW.
AC_VOLTAGE = float(np.sqrt(3) * 400.0)  # ~692.8 "effective" volts
DC_VOLTAGE = 500.0
AC_MAX_CURRENT = 16.0
DC_MAX_CURRENT = 300.0


@dataclasses.dataclass
class EVSE:
    """A charging port (leaf of the station tree)."""

    voltage: float = AC_VOLTAGE  # effective volts (encodes phases)
    max_current: float = AC_MAX_CURRENT  # amps
    efficiency: float = 0.95
    is_dc: bool = False

    @property
    def max_power_kw(self) -> float:
        return self.voltage * self.max_current / 1000.0


def ac_evse(efficiency: float = 0.95) -> EVSE:
    return EVSE(AC_VOLTAGE, AC_MAX_CURRENT, efficiency, is_dc=False)


def dc_evse(efficiency: float = 0.95) -> EVSE:
    return EVSE(DC_VOLTAGE, DC_MAX_CURRENT, efficiency, is_dc=True)


@dataclasses.dataclass
class Node:
    """Internal node: splitter/transformer/cable assembly with a current cap."""

    max_current: float
    efficiency: float = 1.0
    children: Sequence["Node | EVSE"] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class BatteryConfig:
    """Optional station battery (modelled like an EVSE; paper §4)."""

    enabled: bool = True
    voltage: float = 800.0
    max_current: float = 250.0  # -> 200 kW
    capacity_kwh: float = 400.0
    efficiency: float = 0.97
    tau: float = 0.8  # bulk->absorption transition point of the charge curve
    init_soc: float = 0.5


@dataclasses.dataclass(frozen=True)
class StationLayout:
    """Flattened station architecture (static arrays, see module docstring)."""

    n_evse: int
    n_nodes: int
    member: np.ndarray  # (n_nodes, n_evse) float32 0/1
    node_limit: np.ndarray  # (n_nodes,) amps
    node_eff: np.ndarray  # (n_nodes,)
    evse_voltage: np.ndarray  # (n_evse,) effective volts
    evse_max_current: np.ndarray  # (n_evse,) amps
    evse_eff: np.ndarray  # (n_evse,) port efficiency
    evse_path_eff: np.ndarray  # (n_evse,) product of efficiencies root->leaf
    evse_is_dc: np.ndarray  # (n_evse,) float32 0/1
    battery: BatteryConfig
    # 0/1 per EVSE: 0 marks a padding lane added by :func:`pad_layout` so
    # heterogeneous stations can share one array shape (FleetEnv).  ``None``
    # means "all real" (the common single-station case).
    evse_mask: np.ndarray | None = None

    @property
    def evse_max_power_kw(self) -> np.ndarray:
        return self.evse_voltage * self.evse_max_current / 1000.0

    @property
    def mask(self) -> np.ndarray:
        """(n_evse,) float32 0/1 validity mask (ones when unpadded)."""
        if self.evse_mask is None:
            return np.ones(self.n_evse, dtype=np.float32)
        return self.evse_mask


def flatten_tree(root: Node, battery: BatteryConfig | None = None) -> StationLayout:
    """Flatten a station tree into the dense arrays used by the simulator."""
    leaves: list[EVSE] = []
    nodes: list[Node] = []
    # (node_index, leaf_indices) accumulated during DFS
    node_members: list[list[int]] = []
    leaf_path_eff: list[float] = []

    def dfs(n: Node | EVSE, path_eff: float) -> list[int]:
        if isinstance(n, EVSE):
            leaves.append(n)
            leaf_path_eff.append(path_eff * n.efficiency)
            return [len(leaves) - 1]
        nodes.append(n)
        my_idx = len(nodes) - 1
        node_members.append([])  # placeholder, filled after children
        mine: list[int] = []
        for c in n.children:
            mine.extend(dfs(c, path_eff * n.efficiency))
        node_members[my_idx] = mine
        return mine

    dfs(root, 1.0)
    n_evse, n_nodes = len(leaves), len(nodes)
    if n_evse == 0:
        raise ValueError("station tree has no EVSE leaves")

    member = np.zeros((n_nodes, n_evse), dtype=np.float32)
    for i, mem in enumerate(node_members):
        member[i, mem] = 1.0

    return StationLayout(
        n_evse=n_evse,
        n_nodes=n_nodes,
        member=member,
        node_limit=np.array([n.max_current for n in nodes], dtype=np.float32),
        node_eff=np.array([n.efficiency for n in nodes], dtype=np.float32),
        evse_voltage=np.array([l.voltage for l in leaves], dtype=np.float32),
        evse_max_current=np.array([l.max_current for l in leaves], dtype=np.float32),
        evse_eff=np.array([l.efficiency for l in leaves], dtype=np.float32),
        evse_path_eff=np.array(leaf_path_eff, dtype=np.float32),
        evse_is_dc=np.array([float(l.is_dc) for l in leaves], dtype=np.float32),
        battery=battery or BatteryConfig(enabled=False),
    )


# ---------------------------------------------------------------------------
# Padding to a common shape (FleetEnv: heterogeneous stations in one vmap)
# ---------------------------------------------------------------------------
# Padding a station must be a *no-op* for the dynamics of its real lanes:
#   * padded EVSE columns are all-zero in ``member`` so they never load a node,
#   * padded lanes carry ``evse_mask == 0`` so arrivals skip them — they stay
#     unoccupied forever and their current is forced to 0 by the occupancy
#     gate in ``apply_actions``,
#   * padded nodes get an effectively-infinite budget so ``constraint_scale``
#     treats them as unconstrained,
#   * electrical constants are padded with 1.0 (not 0.0) so normalisations
#     like ``current / I_max`` in the observation stay finite.
_PAD_NODE_BUDGET = 1e9


def pad_layout(layout: StationLayout, n_evse: int, n_nodes: int) -> StationLayout:
    """Pad ``layout`` to ``(n_nodes, n_evse)`` with inert lanes/nodes."""
    if n_evse < layout.n_evse or n_nodes < layout.n_nodes:
        raise ValueError(
            f"cannot pad {layout.n_nodes}x{layout.n_evse} down to {n_nodes}x{n_evse}"
        )
    if n_evse == layout.n_evse and n_nodes == layout.n_nodes:
        return layout
    pe, pn = n_evse - layout.n_evse, n_nodes - layout.n_nodes

    def pad1(x: np.ndarray, k: int, value: float) -> np.ndarray:
        return np.concatenate([x, np.full(k, value, dtype=x.dtype)])

    member = np.zeros((n_nodes, n_evse), dtype=np.float32)
    member[: layout.n_nodes, : layout.n_evse] = layout.member
    return dataclasses.replace(
        layout,
        n_evse=n_evse,
        n_nodes=n_nodes,
        member=member,
        node_limit=pad1(layout.node_limit, pn, _PAD_NODE_BUDGET),
        node_eff=pad1(layout.node_eff, pn, 1.0),
        evse_voltage=pad1(layout.evse_voltage, pe, 1.0),
        evse_max_current=pad1(layout.evse_max_current, pe, 1.0),
        evse_eff=pad1(layout.evse_eff, pe, 1.0),
        evse_path_eff=pad1(layout.evse_path_eff, pe, 1.0),
        evse_is_dc=pad1(layout.evse_is_dc, pe, 0.0),
        evse_mask=pad1(layout.mask, pe, 0.0),
    )


# ---------------------------------------------------------------------------
# Bundled architectures (Table 1: "Simple: Single Charger Type",
# "Simple: Multiple Charger Types", custom trees per Fig. 3)
# ---------------------------------------------------------------------------
def single_charger_type(
    n_chargers: int = 16,
    dc: bool = False,
    grid_limit_frac: float = 0.7,
    battery: BatteryConfig | None = None,
) -> StationLayout:
    """Fig. 3a: one splitter, one charger type.

    ``grid_limit_frac`` sets the root current cap as a fraction of the sum of
    the port maxima (i.e. the grid connection is deliberately undersized, which
    is what makes current scheduling a non-trivial problem).
    """
    mk = dc_evse if dc else ac_evse
    ports = [mk() for _ in range(n_chargers)]
    limit = grid_limit_frac * sum(p.max_current for p in ports)
    root = Node(max_current=limit, efficiency=0.98, children=ports)
    return flatten_tree(root, battery)


def multi_charger_type(
    n_dc: int = 10,
    n_ac: int = 6,
    grid_limit_frac: float = 0.7,
    type_limit_frac: float = 0.85,
    battery: BatteryConfig | None = None,
) -> StationLayout:
    """Fig. 3b: one splitter per charger type under a shared grid connection.

    Default (10 DC, 6 AC) matches the paper's 16-charger experimental station.
    """
    dcs = [dc_evse() for _ in range(n_dc)]
    acs = [ac_evse() for _ in range(n_ac)]
    dc_node = Node(
        max_current=type_limit_frac * sum(p.max_current for p in dcs),
        efficiency=0.99,
        children=dcs,
    )
    ac_node = Node(
        max_current=type_limit_frac * sum(p.max_current for p in acs),
        efficiency=0.99,
        children=acs,
    )
    total = dc_node.max_current + ac_node.max_current
    root = Node(
        max_current=grid_limit_frac * total, efficiency=0.98, children=[dc_node, ac_node]
    )
    return flatten_tree(root, battery)


def deep_split(
    n_groups: int = 4,
    chargers_per_group: int = 4,
    dc: bool = True,
    grid_limit_frac: float = 0.6,
    group_limit_frac: float = 0.8,
    battery: BatteryConfig | None = None,
) -> StationLayout:
    """Fig. 3c: multiple splitters per type, imposing nested current limits."""
    mk = dc_evse if dc else ac_evse
    groups = []
    for _ in range(n_groups):
        ports = [mk() for _ in range(chargers_per_group)]
        groups.append(
            Node(
                max_current=group_limit_frac * sum(p.max_current for p in ports),
                efficiency=0.99,
                children=ports,
            )
        )
    total = sum(g.max_current for g in groups)
    root = Node(max_current=grid_limit_frac * total, efficiency=0.98, children=groups)
    return flatten_tree(root, battery)


ARCHITECTURES = {
    "single_ac_16": lambda **kw: single_charger_type(16, dc=False, **kw),
    "single_dc_16": lambda **kw: single_charger_type(16, dc=True, **kw),
    "paper_16": lambda **kw: multi_charger_type(10, 6, **kw),
    "mixed_8_8": lambda **kw: multi_charger_type(8, 8, **kw),
    "deep_4x4": lambda **kw: deep_split(4, 4, **kw),
    # smaller sites: varying n_evse/n_nodes exercises FleetEnv shape padding
    "single_dc_8": lambda **kw: single_charger_type(8, dc=True, **kw),
    "kiosk_ac_4": lambda **kw: single_charger_type(4, dc=False, **kw),
    "deep_2x4": lambda **kw: deep_split(2, 4, **kw),
}
