"""State & parameter pytrees for Chargax (paper §4, Appendix A.1, Table 4).

The state is split *explicitly* into endogenous fields (evolved by
``transition.py`` as a function of the action) and exogenous fields (sampled
from bundled time-series data at reset, evolving independently of actions) —
the paper's Eq. 4 factorisation.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.utils import pytree_dataclass


@pytree_dataclass
class RewardWeights:
    """alpha_c coefficients of Eq. 3 (all default 0, matching Table 3)."""

    constraint: jnp.ndarray | float = 0.0
    satisfaction_time: jnp.ndarray | float = 0.0  # c_sat,0: missing kWh at deadline
    satisfaction_charge: jnp.ndarray | float = 0.0  # c_sat,1: overtime steps
    sustainability: jnp.ndarray | float = 0.0  # MOER-weighted grid energy
    rejected: jnp.ndarray | float = 0.0  # declined cars
    degradation: jnp.ndarray | float = 0.0  # battery + car discharge wear
    grid_stability: jnp.ndarray | float = 0.0  # |E_net - d_grid|
    early_finish_beta: jnp.ndarray | float = 0.0  # beta inside c_sat,1
    grid_violation: jnp.ndarray | float = 0.0  # kW of feeder-cap overshoot
    grid_setpoint: jnp.ndarray | float = 0.0  # |drawn - setpoint| tracking error


@pytree_dataclass
class EnvParams:
    """Everything the transition reads that is *not* per-step state.

    Station arrays come from :class:`repro.core.station.StationLayout`; data
    tables from :mod:`repro.core.datasets`.  All are jnp arrays so scenario
    sweeps (e.g. alpha sweeps, price-year sweeps) do not recompile.
    """

    # --- station architecture (flattened tree; battery = extra leaf column) ---
    member: jnp.ndarray  # (n_nodes, n_evse + 1)
    node_budget: jnp.ndarray  # (n_nodes,)  eta_H * I_H  [A]
    evse_voltage: jnp.ndarray  # (n_evse,)
    evse_max_current: jnp.ndarray  # (n_evse,)
    evse_path_eff: jnp.ndarray  # (n_evse,)
    evse_is_dc: jnp.ndarray  # (n_evse,)
    evse_mask: jnp.ndarray  # (n_evse,) 1=real lane, 0=fleet padding
    evse_v2g_mask: jnp.ndarray  # (n_evse,) 1=bidirectional port (discharge OK
    #     when EnvConfig.allow_v2g); 0=charge-only hardware
    # --- station battery ---
    batt_voltage: jnp.ndarray | float
    batt_max_current: jnp.ndarray | float
    batt_capacity: jnp.ndarray | float
    batt_eff: jnp.ndarray | float
    batt_tau: jnp.ndarray | float
    batt_init_soc: jnp.ndarray | float
    # --- exogenous data tables ---
    price_buy_table: jnp.ndarray  # (365, steps_per_day) EUR/kWh
    arrival_rate: jnp.ndarray  # (steps_per_day,) expected cars / step
    arrival_day_scale: jnp.ndarray  # (365,) seasonal/weekend arrival modulation
    pv_kw_table: jnp.ndarray  # (365, steps_per_day) on-site PV generation [kW]
    grid_cap_kw_table: jnp.ndarray  # (365, steps_per_day) feeder power cap [kW]
    #     (GRID_CAP_UNLIMITED when the scenario declares no grid axis, which
    #     makes the allocate stage an exact bitwise no-op)
    grid_setpoint_kw_table: jnp.ndarray  # (365, steps_per_day) DSO setpoint [kW]
    car_probs: jnp.ndarray  # (n_models,) or (365, n_models) under fleet drift
    car_capacity: jnp.ndarray  # (n_models,) kWh
    car_ac_kw: jnp.ndarray  # (n_models,)
    car_dc_kw: jnp.ndarray  # (n_models,)
    car_tau: jnp.ndarray  # (n_models,)
    # --- user profile ---
    stay_mu_log: jnp.ndarray | float  # lognormal params of stay duration [h]
    stay_sigma: jnp.ndarray | float
    target_soc_mu: jnp.ndarray | float
    target_soc_std: jnp.ndarray | float
    soc0_a: jnp.ndarray | float
    soc0_b: jnp.ndarray | float
    p_time_sensitive: jnp.ndarray | float
    # --- economics ---
    p_sell: jnp.ndarray | float  # EUR/kWh charged to customers (Table 3: 0.75)
    p_v2g_comp: jnp.ndarray | float  # EUR/kWh paid to owners for V2G discharge
    grid_sell_discount: jnp.ndarray | float  # p_sell,grid = discount * p_buy
    facility_cost: jnp.ndarray | float  # c_dt, EUR per HOUR (scaled by dt)
    demand_charge_rate: jnp.ndarray | float  # EUR per kW·step above the contract
    demand_contract_kw: jnp.ndarray | float  # contracted grid power [kW]
    moer_scale: jnp.ndarray | float  # kgCO2/kWh scale of the synthetic MOER curve
    grid_demand_amp: jnp.ndarray | float  # amplitude of synthetic d_grid
    # --- reward ---
    weights: RewardWeights
    # --- fused-step kernel pack (None unless EnvConfig.fused_step) ---
    # A kernels.chargax_step PoleParams NamedTuple with lane-padded voltage/
    # imax/eff/power rows and the (node, lane) membership matrix, hoisted out
    # of the per-step path at make_params time.  Left None with the flag off
    # so flag-off params stay structurally identical to pre-fused builds.
    pole: Any = None


@pytree_dataclass
class EnvState:
    """Per-environment dynamic state (Appendix A.1 / Table 4)."""

    # ---- endogenous: EVSE ports ----
    evse_current: jnp.ndarray  # (N,) signed amps, I_drawn
    occupied: jnp.ndarray  # (N,) {0,1}
    soc: jnp.ndarray  # (N,) state of charge of plugged car
    e_remain: jnp.ndarray  # (N,) kWh still requested
    v2g_debt: jnp.ndarray  # (N,) kWh the station discharged from this pack
    #     and still owes back; refills up to the debt settle at p_v2g_comp
    #     instead of p_sell, so discharge+recharge churn nets zero revenue
    # ---- endogenous: station battery ----
    batt_current: jnp.ndarray  # () signed amps
    batt_soc: jnp.ndarray  # ()
    # ---- exogenous per plugged car (fixed until departure) ----
    t_remain: jnp.ndarray  # (N,) int32 steps until user deadline (may go <0)
    rhat: jnp.ndarray  # (N,) amps, car max current at current SoC
    cap: jnp.ndarray  # (N,) kWh car battery capacity
    rbar: jnp.ndarray  # (N,) amps, car max current at this port's voltage
    tau: jnp.ndarray  # (N,) charge-curve knee
    user_type: jnp.ndarray  # (N,) 0 = time-sensitive, 1 = charge-sensitive
    # ---- exogenous: episode-level ----
    t: jnp.ndarray  # () int32 step within episode
    day: jnp.ndarray  # () int32 day-of-year used for price row
    price_buy: jnp.ndarray  # (steps_per_day,) this episode's buy price
    # ---- bookkeeping (for info/eval; not observed) ----
    profit_cum: jnp.ndarray  # ()
    energy_delivered: jnp.ndarray  # () kWh into cars
    energy_discharged: jnp.ndarray  # () kWh drawn OUT of cars (V2G)
    cars_served: jnp.ndarray  # ()
    cars_rejected: jnp.ndarray  # ()
    missing_kwh_cum: jnp.ndarray  # () unmet charge at forced departures
    overtime_steps_cum: jnp.ndarray  # () overtime of charge-sensitive users
