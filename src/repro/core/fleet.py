"""FleetEnv — heterogeneous multi-station fleets under one vmap (ROADMAP:
"as many scenarios as you can imagine", "as fast as the hardware allows").

A fleet is a set of stations with *different* electrical architectures
(varying ``n_evse``/``n_nodes``) and possibly different scenarios.  Each
station's :class:`StationLayout` is padded to the fleet-wide maximum shape
(:func:`repro.core.station.pad_layout`) so every station's parameter pytree
has identical array shapes; the stacked parameters then run under a single
``jax.vmap`` of the ordinary :meth:`ChargaxEnv.step` — one compiled program
for the whole fleet, one jit cache entry regardless of fleet composition.

Padding is inert by construction: padded lanes are masked out of arrivals
(``EnvParams.evse_mask``), contribute zero current/energy, and — because
arrival randomness is folded per port index — each fleet lane is bit-for-bit
the single-station ``ChargaxEnv`` run at the same padded shape, and matches
an *unpadded* run exactly on discrete fields / to last-ulp float tolerance
on continuous ones (different compiled programs may round the Eq. 5 load
reduction differently; see ``tests/core/test_fleet.py``).

When a mesh is active (``repro.distributed.sharding.set_mesh``) the station
axis of ``reset``/``step`` outputs is constrained onto the mesh's data axes
(``repro.distributed.env_sharding``), so a fleet rollout shards across
devices with zero changes at the call site; without a mesh the constraint is
the identity and all single-device tests run unmodified.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import station, transition
from repro.core.env import ChargaxEnv, EnvConfig
from repro.core.state import EnvParams, EnvState, RewardWeights
from repro.distributed import env_sharding
from repro.utils import stack_pytrees

# the one shared pytree-stacking helper (repro.utils.stack_pytrees): fleets
# stack a station axis, the scenario subsystem stacks a scenario axis —
# both names resolve to the same function
stack_params = stack_pytrees


class FleetEnv:
    """A fleet of heterogeneous charging stations stepped as one batch.

    Args:
        architectures: station architecture names (keys of
            ``station.ARCHITECTURES``), one per fleet member.
        config: shared static configuration (timing, action space, ...).
            Its ``architecture`` field is ignored; per-station architectures
            come from ``architectures``.
        scenarios: optional per-station scenarios — each entry is ``None``
            (use ``config``'s datasets), a scenario name, or a
            ``repro.scenarios.Scenario``.  Applied as pure array swaps on the
            padded per-station params.
        weights: reward weights shared by the fleet.
        couple_grid: step the fleet through the staged-pipeline seams with a
            *shared feeder power envelope*: after the vmapped
            decode/request/allocate stages, the stations' post-allocation
            grid draws are summed and proportionally curtailed against the
            fleet cap — station 0's ``grid_cap_kw_table`` read at station 0's
            clock (the fleet-level grid axis; give every station the same
            table via a shared scenario) — before the vmapped deliver/settle
            stages resume.  Pure array ops between two vmapped halves, so the
            one-jit-entry invariant survives; with the default unlimited cap
            the coupled step is bit-identical to the uncoupled vmap.
            Fleet-excess kW are attributed to stations pro-rata by draw on
            top of their local ``grid/violation``.
        city: couple the fleet to a city-scale arrival stream — a
            :class:`repro.city.CityParams` (or a scenario/name whose
            ``city_*`` axis builds one): each step, the population stream at
            the fleet clock is split across stations by the gravity/queue
            choice model (:mod:`repro.city.demand`) and fed into the vmapped
            finish as a per-station arrival-rate input on top of each
            station's own table.  ``info`` gains ``city/arrival_rate`` (S,),
            plus broadcast ``city/overflow``/``city/stream``.  A zero
            population adds exactly zero rate, leaving the coupled fleet
            bit-identical to the uncoupled one.

    ``reset``/``step`` mirror the single-station API with a leading station
    axis: obs ``(S, obs_dim)``, reward ``(S,)``, action ``(S, heads)``.
    ``info`` carries per-station entries plus fleet-aggregated
    ``fleet_reward``/``fleet_profit``; every info leaf is uniformly ``(S,)``
    (aggregates are broadcast), so ``tree_map``-based auto-reset/stacking
    works when the fleet is nested under an outer vmap or scan.

    ``shard=True`` (default) constrains the station axis of all outputs onto
    the ambient mesh's data axes — a no-op on a single device.
    """

    def __init__(
        self,
        architectures: Sequence[str],
        config: EnvConfig | None = None,
        scenarios: Sequence[Any] | None = None,
        weights: RewardWeights | None = None,
        shard: bool = True,
        couple_grid: bool = False,
        city: Any | None = None,
    ):
        if not architectures:
            raise ValueError("fleet needs at least one station")
        if scenarios is not None and len(scenarios) != len(architectures):
            raise ValueError("need one scenario entry per station")
        base = config or EnvConfig()
        if city is not None:
            from repro.city.params import CityParams, make_city

            if not isinstance(city, CityParams):
                # scenario name / Scenario: build its city axis for this fleet
                city = make_city(
                    city, n_stations=len(architectures), dt_minutes=base.dt_minutes
                )
            if city.n_stations != len(architectures):
                raise ValueError(
                    f"city has {city.n_stations} stations, fleet has "
                    f"{len(architectures)}"
                )
        self.city = city
        self.architectures = tuple(architectures)
        self.scenarios = tuple(scenarios) if scenarios is not None else None

        # probe the unpadded layouts to find the fleet-wide padded shape
        layouts = [station.ARCHITECTURES[a]() for a in architectures]
        self.max_evse = max(l.n_evse for l in layouts)
        self.max_nodes = max(l.n_nodes for l in layouts)
        self.envs = [
            ChargaxEnv(
                dataclasses.replace(
                    base,
                    architecture=a,
                    pad_evse=self.max_evse,
                    pad_nodes=self.max_nodes,
                )
            )
            for a in architectures
        ]
        # all stations share one padded template: the first env's pure
        # reset/step close over only static config, shared fleet-wide
        self.template = self.envs[0]
        self.config = self.template.config
        self.weights = weights
        self.shard = shard
        self.couple_grid = couple_grid
        self._v_reset = jax.vmap(self.template.reset, in_axes=(0, 0))
        self._v_step = jax.vmap(self.template.step, in_axes=(0, 0, 0, 0))
        # staged-pipeline seams for the grid-coupled step
        self._v_request = jax.vmap(self.template.request_stage, in_axes=(0, 0, 0))
        self._v_allocate = jax.vmap(transition.allocate, in_axes=(0, 0, 0))
        self._v_finish = jax.vmap(self.template.finish_step, in_axes=(0, 0, 0, 0))
        # city coupling: finish_step with a per-station arrival-rate input —
        # the fixed arrival table becomes one component of a dynamic rate
        self._v_finish_rate = jax.vmap(
            lambda k, s, a, p, r: self.template.finish_step(
                k, s, a, p, arrival_rate_extra=r
            ),
            in_axes=(0, 0, 0, 0, 0),
        )

    def with_fused_step(self, fused: bool) -> "FleetEnv":
        """This fleet with the fused hot path toggled on every station.

        The uncoupled vmapped step routes through the fused kernel wholesale;
        the grid-/city-coupled step keeps its staged seams (the shared-feeder
        curtailment interposes between vmapped halves) — see docs/kernels.md.
        """
        if self.config.fused_step == bool(fused):
            return self
        return FleetEnv(
            self.architectures,
            dataclasses.replace(self.config, fused_step=bool(fused)),
            self.scenarios,
            self.weights,
            self.shard,
            self.couple_grid,
            self.city,
        )

    def _constrain(self, tree):
        """Pin the station axis to the ambient mesh's data axes (no-op when
        no mesh is active or ``shard=False``)."""
        if not self.shard:
            return tree
        return env_sharding.constrain_env_batch(tree)

    # ------------------------------------------------------------------
    @property
    def n_stations(self) -> int:
        return len(self.envs)

    @property
    def num_action_heads(self) -> int:
        return self.template.num_action_heads

    @property
    def num_actions_per_head(self) -> int:
        return self.template.num_actions_per_head

    @property
    def obs_dim(self) -> int:
        return self.template.obs_dim

    @cached_property
    def default_params(self) -> EnvParams:
        """Stacked (S, ...) parameter pytree, one slice per station."""
        if self.scenarios is None:
            return stack_params(
                [env.make_params(weights=self.weights) for env in self.envs]
            )
        # any scenario in the fleet -> lower EVERY station through the
        # scenario path (None becomes the config's own world) so all slices
        # share the scenario-normalised array shapes (padded car tables,
        # drift tables) and stack cleanly
        from repro import scenarios as _scen

        cfg = self.config
        baseline = _scen.Scenario(
            name="__config__",
            profile=cfg.scenario,
            traffic=cfg.traffic,
            price_region=cfg.price_region,
            price_year=cfg.price_year,
            car_region=cfg.car_region,
        )
        params = []
        for i, env in enumerate(self.envs):
            sc = self.scenarios[i]
            if sc is None:
                sc = baseline
            elif isinstance(sc, str):
                sc = _scen.make(sc)
            params.append(sc.make_params(env, weights=self.weights))
        return stack_params(params)

    def station_params(self, i: int, params: EnvParams | None = None) -> EnvParams:
        """Slice station ``i``'s (unstacked) params back out of the fleet."""
        params = params if params is not None else self.default_params
        return jax.tree_util.tree_map(lambda x: x[i], params)

    def sample_action(self, key: jax.Array) -> jnp.ndarray:
        return jax.random.randint(
            key,
            (self.n_stations, self.num_action_heads),
            0,
            self.num_actions_per_head,
        )

    # ------------------------------------------------------------------
    def reset(
        self, key: jax.Array, params: EnvParams | None = None
    ) -> tuple[jnp.ndarray, EnvState]:
        params = params if params is not None else self.default_params
        keys = jax.random.split(key, self.n_stations)
        obs, state = self._v_reset(keys, params)
        return self._constrain(obs), self._constrain(state)

    def step(
        self,
        key: jax.Array,
        state: EnvState,
        action: jnp.ndarray,  # (S, heads) int32
        params: EnvParams | None = None,
    ) -> tuple[jnp.ndarray, EnvState, jnp.ndarray, jnp.ndarray, dict]:
        return self.step_with_city(key, state, action, params, self.city)

    def step_with_city(
        self,
        key: jax.Array,
        state: EnvState,
        action: jnp.ndarray,  # (S, heads) int32
        params: EnvParams | None = None,
        city=None,
    ) -> tuple[jnp.ndarray, EnvState, jnp.ndarray, jnp.ndarray, dict]:
        """``step`` with the city passed as a *traced argument* — the seam the
        placement sweep (:func:`repro.city.sweep_layouts`) vmaps over to score
        a stack of candidate ``CityParams`` under one compiled program."""
        params = params if params is not None else self.default_params
        keys = jax.random.split(key, self.n_stations)
        if self.couple_grid or city is not None:
            obs, state, reward, done, info = self._staged_step(
                keys, state, action, params, city
            )
        else:
            obs, state, reward, done, info = self._v_step(keys, state, action, params)
        info = dict(info)
        # fleet aggregates broadcast to (S,) so every info leaf has a uniform
        # leading station axis — tree_map stacking under an outer vmap/scan
        # would otherwise see mixed () / (S,) shapes and fail
        info["fleet_reward"] = jnp.broadcast_to(jnp.sum(reward), reward.shape)
        info["fleet_profit"] = jnp.broadcast_to(jnp.sum(info["profit"]), reward.shape)
        obs, state, reward, done, info = self._constrain(
            (obs, state, reward, done, info)
        )
        return obs, state, reward, done, info

    def _staged_step(self, keys, state, action, params, city=None):
        """Fleet-coupled step through the staged-pipeline seams.

        Grid coupling: shared feeder curtailment between the vmapped
        request/allocate and deliver/settle halves.  City coupling: the
        population arrival stream is allocated across stations
        (:mod:`repro.city.demand`) from the pre-step state and fed into the
        vmapped finish as a per-station arrival-rate input; a zero population
        contributes exactly zero rate, so the coupled fleet stays
        bit-identical to the uncoupled one (``tests/city/``)."""
        applied = self._v_request(state, action, params)
        alloc = self._v_allocate(params, state, applied)  # per-station caps
        if self.couple_grid:
            # fleet feeder cap: station 0's grid table at station 0's clock
            # (all stations share the episode clock; days differ only across
            # resets)
            cap_table = params.grid_cap_kw_table[0]
            fleet_cap = cap_table[
                jnp.mod(state.day[0], cap_table.shape[0]),
                jnp.mod(state.t[0], cap_table.shape[1]),
            ]
            p = alloc.power_kw  # (S,) post-local-allocation draws
            total = jnp.sum(p)
            scale = jnp.minimum(1.0, fleet_cap / jnp.maximum(total, 1e-9))
            fleet_excess = jnp.maximum(total - fleet_cap, 0.0)
            share = p / jnp.maximum(total, 1e-9)  # pro-rata attribution
            alloc = transition.AllocationResult(
                applied=jax.vmap(transition.curtail, in_axes=(0, None))(
                    alloc.applied, scale
                ),
                power_req_kw=alloc.power_req_kw,
                power_kw=p * scale,
                cap_kw=jnp.minimum(alloc.cap_kw, fleet_cap),
                violation_kw=alloc.violation_kw + fleet_excess * share,
            )
        if city is None:
            return self._v_finish(keys, state, alloc, params)

        from repro.city import demand

        calloc, stream = demand.city_rates(city, params, state)
        # the stream split respects the station-axis sharding: rates carry a
        # leading (S,) axis, constrained onto the mesh's data axes like every
        # other per-station tensor (no-op on a single device)
        rates = self._constrain(calloc.rates)
        obs, new_state, reward, done, info = self._v_finish_rate(
            keys, state, alloc, params, rates
        )
        info = dict(info)
        info["city/arrival_rate"] = calloc.rates
        info["city/overflow"] = jnp.broadcast_to(calloc.overflow, reward.shape)
        info["city/stream"] = jnp.broadcast_to(stream, reward.shape)
        return obs, new_state, reward, done, info
