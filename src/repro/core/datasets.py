"""Bundled exogenous datasets (paper Table 1).

The paper ships real ENTSO-E day-ahead prices (NL/FR/DE, 2021-2023), regional
car-fleet distributions (Europe/US/World), arrival-frequency curves and user
profiles (Highway/Residential/Work/Shopping).  Offline we regenerate each as a
*deterministic synthetic* series with the same structure (daily + weekly
seasonality, 2022 energy-crisis regime, fleet statistics from public specs) —
see DESIGN.md §7.  All tables are plain numpy; the environment lifts them to
jnp constants.

Everything is cached per (name, year, dt) so repeated env construction is free.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.utils import steps_per_day

DAYS_PER_YEAR = 365


# ---------------------------------------------------------------------------
# Grid price profiles (EUR/kWh), shape (365, steps_per_day)
# ---------------------------------------------------------------------------
# (base level EUR/kWh, morning peak, evening peak, noise scale, seed)
_PRICE_PARAMS = {
    "NL": dict(base=0.105, morning=0.035, evening=0.055, noise=0.012, seed=11),
    "FR": dict(base=0.090, morning=0.030, evening=0.045, noise=0.010, seed=13),
    "DE": dict(base=0.115, morning=0.040, evening=0.060, noise=0.014, seed=17),
}
# Regime multipliers per year: 2022 = European energy crisis (paper Fig. 5).
_YEAR_REGIME = {2021: (1.0, 0.0), 2022: (2.6, 0.35), 2023: (1.4, 0.12)}


@functools.lru_cache(maxsize=None)
def price_profile(region: str = "NL", year: int = 2021, dt_minutes: float = 5.0) -> np.ndarray:
    """Day-ahead electricity price, EUR/kWh, shape (365, steps_per_day)."""
    if region not in _PRICE_PARAMS:
        raise KeyError(f"unknown price region {region!r}; have {list(_PRICE_PARAMS)}")
    p = _PRICE_PARAMS[region]
    scale, spike = _YEAR_REGIME.get(year, (1.0, 0.0))
    spd = steps_per_day(dt_minutes)
    rng = np.random.default_rng(p["seed"] * 1000 + year)

    h = np.arange(spd) * (24.0 / spd)  # hour of day
    daily = (
        p["base"]
        + p["morning"] * np.exp(-0.5 * ((h - 8.5) / 1.8) ** 2)
        + p["evening"] * np.exp(-0.5 * ((h - 19.0) / 2.2) ** 2)
        - 0.020 * np.exp(-0.5 * ((h - 14.0) / 2.5) ** 2)  # solar dip
    )
    day = np.arange(DAYS_PER_YEAR)
    weekly = 1.0 - 0.08 * np.isin(day % 7, [5, 6]).astype(np.float64)  # weekend dip
    seasonal = 1.0 + 0.15 * np.cos(2 * np.pi * (day - 15) / DAYS_PER_YEAR)  # winter high

    # smooth day-to-day random walk + occasional spikes (crisis years)
    walk = np.cumsum(rng.normal(0, p["noise"], DAYS_PER_YEAR))
    walk -= np.linspace(walk[0], walk[-1], DAYS_PER_YEAR)  # detrend, keep wiggle
    spikes = spike * rng.gamma(1.5, 1.0, DAYS_PER_YEAR) * (rng.random(DAYS_PER_YEAR) < 0.08)

    prices = (daily[None, :] * weekly[:, None] * seasonal[:, None]) * scale
    prices = prices + walk[:, None] * 0.5 + spikes[:, None] * p["base"]
    noise = rng.normal(0, p["noise"] * 0.3, (DAYS_PER_YEAR, spd))
    return np.maximum(prices + noise, 0.005).astype(np.float32)


PRICE_REGIONS = tuple(_PRICE_PARAMS)


# ---------------------------------------------------------------------------
# Car distributions (paper Table 1: Europe / US / World)
# columns: probability, battery capacity kWh, max AC kW, max DC kW, tau
# ---------------------------------------------------------------------------
_CAR_TABLES = {
    # capacity / charge specs from public manufacturer data sheets
    "EU": np.array(
        [  # prob   cap    ac     dc     tau
            [0.22, 52.0, 11.0, 100.0, 0.78],  # Renault Zoe / compact class
            [0.20, 58.0, 11.0, 170.0, 0.80],  # VW ID.3
            [0.18, 57.5, 11.0, 170.0, 0.80],  # Tesla Model 3 SR
            [0.12, 75.0, 11.0, 250.0, 0.82],  # Tesla Model Y LR
            [0.10, 64.0, 11.0, 77.0, 0.75],  # Hyundai Kona
            [0.08, 77.0, 11.0, 135.0, 0.78],  # VW ID.4
            [0.06, 39.0, 6.6, 50.0, 0.70],  # Nissan Leaf 40
            [0.04, 93.4, 11.0, 270.0, 0.85],  # Audi e-tron GT
        ],
        dtype=np.float32,
    ),
    "US": np.array(
        [
            [0.28, 75.0, 11.5, 250.0, 0.82],  # Model Y LR
            [0.22, 57.5, 11.5, 170.0, 0.80],  # Model 3 SR
            [0.14, 131.0, 19.2, 155.0, 0.80],  # F-150 Lightning ER
            [0.12, 65.0, 11.5, 150.0, 0.78],  # Mustang Mach-E
            [0.10, 65.0, 11.5, 55.0, 0.72],  # Chevy Bolt EUV
            [0.08, 77.4, 10.9, 235.0, 0.82],  # Ioniq 5 LR
            [0.06, 105.0, 19.2, 190.0, 0.80],  # Rivian R1T
        ],
        dtype=np.float32,
    ),
    "World": np.array(
        [
            [0.30, 50.0, 7.0, 120.0, 0.76],  # BYD-class compact
            [0.20, 57.5, 11.0, 170.0, 0.80],
            [0.15, 75.0, 11.0, 250.0, 0.82],
            [0.12, 44.9, 6.6, 60.0, 0.72],
            [0.10, 64.0, 11.0, 77.0, 0.75],
            [0.08, 85.0, 11.0, 200.0, 0.82],
            [0.05, 28.5, 3.3, 40.0, 0.65],  # city micro-EV
        ],
        dtype=np.float32,
    ),
}

CAR_REGIONS = tuple(_CAR_TABLES)


def car_table(region: str = "EU") -> np.ndarray:
    """(n_models, 5) float32: prob, capacity kWh, max AC kW, max DC kW, tau."""
    t = _CAR_TABLES[region].copy()
    t[:, 0] = t[:, 0] / t[:, 0].sum()
    return t


# ---------------------------------------------------------------------------
# User profiles (paper Table 1: Highway / Residential / Work / Shopping)
# ---------------------------------------------------------------------------
# arrival_shape: relative arrival intensity over the day (normalised to mean 1)
# stay:   lognormal (mean, sigma) of stay duration in hours
# target: desired state of charge at departure (mean, std)
# soc0:   arrival SoC beta distribution (a, b)
# p_time_sensitive: probability the user leaves at their deadline regardless
_USER_PROFILES = {
    "highway": dict(
        peaks=[(11.0, 3.0, 1.0), (16.5, 3.0, 1.1)], floor=0.25,
        stay=(0.5, 0.35), target=(0.85, 0.08), soc0=(2.0, 4.5),
        p_time_sensitive=0.85,
    ),
    "residential": dict(
        peaks=[(19.0, 2.5, 1.6)], floor=0.15,
        stay=(9.0, 0.35), target=(0.95, 0.05), soc0=(2.5, 3.0),
        p_time_sensitive=0.55,
    ),
    "work": dict(
        peaks=[(8.5, 1.5, 1.8)], floor=0.05,
        stay=(7.5, 0.25), target=(0.90, 0.06), soc0=(2.5, 3.0),
        p_time_sensitive=0.75,
    ),
    "shopping": dict(
        peaks=[(13.5, 3.5, 1.4), (18.0, 2.0, 0.9)], floor=0.10,
        stay=(1.4, 0.40), target=(0.80, 0.10), soc0=(2.2, 3.5),
        p_time_sensitive=0.90,
    ),
}

USER_PROFILES = tuple(_USER_PROFILES)

# Mean total arrivals per day for a 16-charger station (paper: low/medium/high)
TRAFFIC_LEVELS = {"low": 60.0, "medium": 120.0, "high": 220.0}


@functools.lru_cache(maxsize=None)
def arrival_rate_curve(
    profile: str = "shopping", traffic: str = "medium", dt_minutes: float = 5.0
) -> np.ndarray:
    """Expected arrivals per timestep, shape (steps_per_day,)."""
    p = _USER_PROFILES[profile]
    spd = steps_per_day(dt_minutes)
    h = np.arange(spd) * (24.0 / spd)
    shape = np.full(spd, p["floor"], dtype=np.float64)
    for mu, sig, amp in p["peaks"]:
        shape += amp * np.exp(-0.5 * ((h - mu) / sig) ** 2)
    shape /= shape.mean()
    per_day = TRAFFIC_LEVELS[traffic] if isinstance(traffic, str) else float(traffic)
    return (shape * per_day / spd).astype(np.float32)


def user_profile_params(profile: str = "shopping") -> dict:
    return dict(_USER_PROFILES[profile])
