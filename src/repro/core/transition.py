"""Chargax transition function (paper §4 "Transition Function", Appendix A.2).

Four sequential stages, all pure jnp (jit/vmap/scan-able):

  1. apply_actions   — set port/battery currents, clip by car curve & port
                       limits, enforce the tree constraints of Eq. 5,
  2. charge          — integrate energy over dt (constant-rate assumption),
  3. departures      — time-sensitive (u=0) leave at deadline, charge-
                       sensitive (u=1) leave when the request is met,
  4. arrivals        — Poisson arrivals, first-come-first-served onto the
                       first free ports, profiles sampled from bundled data.

The per-stage functions are exposed separately because the fused Pallas kernel
(`repro/kernels/chargax_step`) implements stages 1-2 and must match them
bit-for-bit in the interpret-mode tests.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.state import EnvParams, EnvState
from repro.utils import replace


# ---------------------------------------------------------------------------
# Charging curve (Appendix A: piece-wise linear; discharge = vertical flip
# of the charge curve at SoC = 0.5)
# ---------------------------------------------------------------------------
def charge_rate(soc: jnp.ndarray, rbar: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """r_hat_{tau, rbar}(SoC): max charge current at the given state of charge."""
    return jnp.where(soc <= tau, rbar, rbar * (1.0 - soc) / jnp.maximum(1.0 - tau, 1e-6))


def discharge_rate(soc: jnp.ndarray, rbar: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """Discharge limit: the charge curve flipped at SoC=0.5 (paper App. A.1)."""
    return charge_rate(1.0 - soc, rbar, tau)


# ---------------------------------------------------------------------------
# Stage 1: apply actions + Eq. 5 constraint enforcement
# ---------------------------------------------------------------------------
class AppliedActions(NamedTuple):
    evse_current: jnp.ndarray  # (N,) post-constraint signed amps
    batt_current: jnp.ndarray  # ()
    constraint_excess: jnp.ndarray  # () max pre-rescale node violation [A]


def decode_action(
    action: jnp.ndarray,
    discretization: int,
    allow_v2g: bool,
    evse_max_current: jnp.ndarray,
    batt_max_current: jnp.ndarray,
    v2g_mask: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Map a discrete factorized action (N+1,) int32 in [0, 2D] to target amps.

    Level k maps to ((k - D)/D) * I_max: the paper's "10%, 20%, ... up to 100%"
    discretisation, extended symmetrically for discharging.  Ports without V2G
    clip negative targets to 0 (the battery head always may discharge).  When
    V2G is on, ``v2g_mask`` (``EnvParams.evse_v2g_mask``) marks which ports
    have bidirectional hardware — the rest stay charge-only, so a scenario can
    lower any port fraction without a new compilation.
    """
    d = float(discretization)
    frac = (action.astype(jnp.float32) - d) / d  # [-1, 1]
    port_frac, batt_frac = frac[:-1], frac[-1]
    if not allow_v2g:
        port_frac = jnp.maximum(port_frac, 0.0)
    elif v2g_mask is not None:
        port_frac = jnp.where(
            v2g_mask > 0.5, port_frac, jnp.maximum(port_frac, 0.0)
        )
    return port_frac * evse_max_current, batt_frac * batt_max_current


def constraint_scale(
    currents: jnp.ndarray,  # (n_leaves,) signed amps (EVSEs + battery column)
    member: jnp.ndarray,  # (n_nodes, n_leaves)
    node_budget: jnp.ndarray,  # (n_nodes,) eta_H * I_H
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-leaf multiplicative scale enforcing Eq. 5 on every subtree.

    We use the conservative cable-thermal reading of Eq. 5 — each node carries
    the sum of *magnitudes* of its subtree currents (DESIGN.md §7).  With
    ``scale_j = min_{H ∋ j} s_H`` and ``s_H = budget_H / load_H`` the invariant
    ``sum_j |I_j * scale_j| <= budget_H`` holds for every node H, which the
    hypothesis tests assert.

    Returns (per-leaf scale in (0, 1], max pre-rescale node excess in amps).
    """
    load = member @ jnp.abs(currents)  # (n_nodes,)
    s_node = jnp.minimum(1.0, node_budget / jnp.maximum(load, 1e-9))
    excess = jnp.max(jnp.maximum(load - node_budget, 0.0))
    # min over ancestors; a leaf with no constrained ancestor is unscaled
    per_leaf = jnp.where(member > 0, s_node[:, None], jnp.inf)
    scale = jnp.min(per_leaf, axis=0)
    return jnp.where(jnp.isfinite(scale), scale, 1.0), excess


def apply_actions(
    params: EnvParams,
    state: EnvState,
    target_evse: jnp.ndarray,  # (N,) requested amps (signed)
    target_batt: jnp.ndarray,  # () requested amps (signed)
    dt_hours: float,
) -> AppliedActions:
    # --- per-port physical clips -------------------------------------------
    rhat_chg = charge_rate(state.soc, state.rbar, state.tau)
    rhat_dis = discharge_rate(state.soc, state.rbar, state.tau)
    # energy-headroom clips: never overshoot the request nor the pack bounds
    v = params.evse_voltage
    max_chg_amp_req = state.e_remain * 1000.0 / jnp.maximum(v * dt_hours, 1e-9)
    max_chg_amp_soc = (
        (1.0 - state.soc) * state.cap * 1000.0 / jnp.maximum(v * dt_hours, 1e-9)
    )
    max_dis_amp_soc = state.soc * state.cap * 1000.0 / jnp.maximum(v * dt_hours, 1e-9)

    up = jnp.minimum(
        jnp.minimum(rhat_chg, params.evse_max_current),
        jnp.minimum(max_chg_amp_req, max_chg_amp_soc),
    )
    down = -jnp.minimum(jnp.minimum(rhat_dis, params.evse_max_current), max_dis_amp_soc)
    i_evse = jnp.clip(target_evse, down, jnp.maximum(up, 0.0))
    i_evse = i_evse * state.occupied  # empty ports draw nothing

    # --- battery clips ------------------------------------------------------
    bv = params.batt_voltage
    b_chg = charge_rate(state.batt_soc, params.batt_max_current, params.batt_tau)
    b_dis = discharge_rate(state.batt_soc, params.batt_max_current, params.batt_tau)
    # efficiency: charging stores eta*E, discharging drains E/eta
    b_up_soc = (
        (1.0 - state.batt_soc)
        * params.batt_capacity
        * 1000.0
        / jnp.maximum(bv * dt_hours * params.batt_eff, 1e-9)
    )
    b_dn_soc = (
        state.batt_soc
        * params.batt_capacity
        * params.batt_eff
        * 1000.0
        / jnp.maximum(bv * dt_hours, 1e-9)
    )
    i_batt = jnp.clip(target_batt, -jnp.minimum(b_dis, b_dn_soc), jnp.minimum(b_chg, b_up_soc))

    # --- Eq. 5 tree constraints (battery = extra leaf on the root) ----------
    leaf_currents = jnp.concatenate([i_evse, i_batt[None]])
    scale, excess = constraint_scale(leaf_currents, params.member, params.node_budget)
    leaf_currents = leaf_currents * scale
    return AppliedActions(leaf_currents[:-1], leaf_currents[-1], excess)


# ---------------------------------------------------------------------------
# Stage 2: charge stationed cars (constant rate over dt)
# ---------------------------------------------------------------------------
class ChargeResult(NamedTuple):
    state: EnvState
    e_car: jnp.ndarray  # (N,) kWh delivered into each car this step (signed)
    e_batt_net: jnp.ndarray  # () kWh grid-side battery energy (signed)
    e_repaid: jnp.ndarray  # (N,) kWh of this step's charge that repays
    #     earlier V2G discharge (settled at p_v2g_comp, not billed at p_sell)


def charge_cars(
    params: EnvParams, state: EnvState, applied: AppliedActions, dt_hours: float
) -> ChargeResult:
    e_car = params.evse_voltage * applied.evse_current * dt_hours / 1000.0  # kWh
    soc = jnp.clip(state.soc + e_car / jnp.maximum(state.cap, 1e-6), 0.0, 1.0)
    # remaining request grows when a car is discharged (V2G) but never past
    # the pack headroom (1 - SoC) * cap — an uncapped request would be
    # unfillable energy poisoning the missing_kwh satisfaction penalty
    e_remain = jnp.minimum(
        jnp.maximum(state.e_remain - e_car, 0.0), (1.0 - soc) * state.cap
    )
    rhat = charge_rate(soc, state.rbar, state.tau) * state.occupied
    # deadlines tick only on occupied ports; padded/idle lanes hold at 0
    # instead of drifting negative without bound
    t_remain = jnp.where(state.occupied > 0.5, state.t_remain - 1, state.t_remain)

    # V2G settlement bookkeeping: discharged energy becomes debt the station
    # owes the pack; subsequent charge repays debt first (settled at
    # p_v2g_comp in the reward, not billed at p_sell) so a discharge/recharge
    # cycle earns nothing beyond a genuine buy/sell price spread
    e_repaid = jnp.minimum(jnp.maximum(e_car, 0.0), state.v2g_debt)
    v2g_debt = state.v2g_debt - e_repaid + jnp.maximum(-e_car, 0.0)

    # battery: store eta*E when charging, deliver E*eta grid-side when discharging
    e_b = params.batt_voltage * applied.batt_current * dt_hours / 1000.0
    batt_soc = jnp.clip(
        state.batt_soc
        + jnp.where(e_b >= 0, e_b * params.batt_eff, e_b / params.batt_eff)
        / jnp.maximum(params.batt_capacity, 1e-6),
        0.0,
        1.0,
    )

    new_state = replace(
        state,
        evse_current=applied.evse_current,
        soc=soc,
        e_remain=e_remain,
        v2g_debt=v2g_debt,
        rhat=rhat,
        t_remain=t_remain,
        batt_current=applied.batt_current,
        batt_soc=batt_soc,
        energy_delivered=state.energy_delivered + jnp.sum(jnp.maximum(e_car, 0.0)),
        energy_discharged=state.energy_discharged
        + jnp.sum(jnp.maximum(-e_car, 0.0)),
    )
    return ChargeResult(new_state, e_car, e_b, e_repaid)


# ---------------------------------------------------------------------------
# Stage 3: departures
# ---------------------------------------------------------------------------
class DepartResult(NamedTuple):
    state: EnvState
    missing_kwh: jnp.ndarray  # () c_sat,0 numerator: unmet charge of u=0 leavers
    overtime_steps: jnp.ndarray  # () overtime of u=1 leavers (steps)
    early_steps: jnp.ndarray  # () early-finish steps of u=1 leavers


def depart_cars(state: EnvState) -> DepartResult:
    occ = state.occupied > 0.5
    leave_time = occ & (state.user_type < 0.5) & (state.t_remain <= 0)
    leave_charge = occ & (state.user_type >= 0.5) & (state.e_remain <= 1e-6)
    leaving = leave_time | leave_charge

    missing = jnp.sum(jnp.where(leave_time, jnp.maximum(state.e_remain, 0.0), 0.0))
    over = jnp.sum(
        jnp.where(leave_charge, jnp.maximum(-state.t_remain, 0).astype(jnp.float32), 0.0)
    )
    early = jnp.sum(
        jnp.where(leave_charge, jnp.maximum(state.t_remain, 0).astype(jnp.float32), 0.0)
    )

    keep = (~leaving).astype(jnp.float32)
    zi = jnp.zeros_like(state.soc)
    new_state = replace(
        state,
        evse_current=state.evse_current * keep,
        occupied=state.occupied * keep,
        soc=state.soc * keep,
        e_remain=state.e_remain * keep,
        v2g_debt=state.v2g_debt * keep,
        t_remain=state.t_remain * keep.astype(state.t_remain.dtype),
        rhat=state.rhat * keep,
        cap=state.cap * keep,
        rbar=state.rbar * keep,
        tau=jnp.where(leaving, zi, state.tau),
        user_type=state.user_type * keep,
        missing_kwh_cum=state.missing_kwh_cum + missing,
        overtime_steps_cum=state.overtime_steps_cum + over,
    )
    return DepartResult(new_state, missing, over, early)


# ---------------------------------------------------------------------------
# Stage 4: arrivals
# ---------------------------------------------------------------------------
class ArriveResult(NamedTuple):
    state: EnvState
    n_arrived: jnp.ndarray  # ()
    n_rejected: jnp.ndarray  # ()


def arrive_cars(params: EnvParams, state: EnvState, key: jax.Array) -> ArriveResult:
    n = state.occupied.shape[0]
    k_m, k_port = jax.random.split(key)

    spd = params.arrival_rate.shape[0]
    n_days = params.arrival_day_scale.shape[0]
    rate = params.arrival_rate[jnp.mod(state.t, spd)] * params.arrival_day_scale[
        jnp.mod(state.day, n_days)
    ]
    m = jax.random.poisson(k_m, rate).astype(jnp.int32)

    # padded fleet lanes (evse_mask == 0) never accept cars
    free = (state.occupied < 0.5) & (params.evse_mask > 0.5)
    n_free = jnp.sum(free.astype(jnp.int32))
    n_arrive = jnp.minimum(m, n_free)
    n_reject = jnp.maximum(m - n_free, 0)

    # first-come-first-served: fill free ports in index order
    rank = jnp.cumsum(free.astype(jnp.int32))  # 1-based among free ports
    assign = free & (rank <= n_arrive)
    a = assign.astype(jnp.float32)

    # fleet-mix drift: a (365, n_models) table selects the day's distribution
    probs = (
        params.car_probs
        if params.car_probs.ndim == 1
        else params.car_probs[jnp.mod(state.day, params.car_probs.shape[0])]
    )

    # --- per-port profile draws (one draw per port; only assigned ports
    # consume it).  Keys are folded per port index so the draw on port i is
    # independent of n — padding a station with extra lanes leaves the real
    # lanes' trajectories bit-for-bit unchanged (FleetEnv regression tests).
    def draw_port(i):
        k_model, k_stay, k_soc0, k_tgt, k_u = jax.random.split(
            jax.random.fold_in(k_port, i), 5
        )
        model = jax.random.choice(k_model, probs.shape[0], p=probs)
        z_stay = jax.random.normal(k_stay, ())
        soc0 = jax.random.beta(k_soc0, params.soc0_a, params.soc0_b)
        z_tgt = jax.random.normal(k_tgt, ())
        bern = jax.random.bernoulli(k_u, params.p_time_sensitive)
        return model, z_stay, soc0, z_tgt, bern

    model, z_stay, soc0_raw, z_tgt, bern = jax.vmap(draw_port)(jnp.arange(n))

    # --- car profiles --------------------------------------------------------
    cap = params.car_capacity[model]
    tau = params.car_tau[model]
    car_kw = jnp.where(
        params.evse_is_dc > 0.5, params.car_dc_kw[model], params.car_ac_kw[model]
    )
    rbar = car_kw * 1000.0 / params.evse_voltage  # car-side current limit [A]

    # --- user profiles -------------------------------------------------------
    stay_h = jnp.exp(params.stay_mu_log + params.stay_sigma * z_stay)
    steps_per_hour = spd / 24.0
    stay_steps = jnp.maximum((stay_h * steps_per_hour).astype(jnp.int32), 1)
    soc0 = jnp.clip(soc0_raw, 0.02, 0.95)
    target = jnp.clip(
        params.target_soc_mu + params.target_soc_std * z_tgt, soc0 + 0.05, 1.0
    )
    e_req = (target - soc0) * cap
    # u: 0 = time-sensitive (leaves at deadline), 1 = charge-sensitive
    u = 1.0 - bern.astype(jnp.float32)

    new_state = replace(
        state,
        occupied=state.occupied * (1 - a) + a,
        soc=state.soc * (1 - a) + a * soc0,
        e_remain=state.e_remain * (1 - a) + a * e_req,
        v2g_debt=state.v2g_debt * (1 - a),  # fresh arrivals carry no debt
        t_remain=jnp.where(assign, stay_steps, state.t_remain),
        rhat=state.rhat * (1 - a) + a * charge_rate(soc0, rbar, tau),
        cap=state.cap * (1 - a) + a * cap,
        rbar=state.rbar * (1 - a) + a * rbar,
        tau=jnp.where(assign, tau, state.tau),
        user_type=state.user_type * (1 - a) + a * u,
        cars_served=state.cars_served + n_arrive.astype(jnp.float32),
        cars_rejected=state.cars_rejected + n_reject.astype(jnp.float32),
    )
    return ArriveResult(new_state, n_arrive, n_reject)
