"""Chargax staged transition pipeline (paper §4 "Transition Function", App. A.2).

The step is a sequence of individually-jittable pure stages::

    decode -> request -> allocate -> deliver -> depart_arrive -> settle
           -> advance_time -> observe

  decode        — map the discrete factorized action to target amps
                  (direct and the paper's additive/delta form),
  request       — clip targets by car curve, port limits and pack headroom,
                  then enforce the Eq. 5 tree constraints (``apply_actions``),
  allocate      — curtail the station's *grid-side* charging power against
                  the feeder/transformer envelope (``grid_cap_kw_table``);
                  with the default unlimited cap this stage is an exact
                  bitwise no-op, so non-grid scenarios are unchanged,
  deliver       — integrate energy over dt (``charge_cars``),
  depart_arrive — deadline / request-met departures, Poisson arrivals,
  settle        — energy bookkeeping, Eq. 1-3 reward, V2G debt settlement,
                  plus the grid-axis penalties (cap violation, setpoint
                  tracking error),
  advance_time  — clock tick + midnight calendar rollover,
  observe       — flat observation vector.

``ChargaxEnv.step`` is pure composition of these stages, and the fused
Pallas oracle (``repro/kernels/chargax_step/ref.py``) calls the *same*
per-pole physics helpers (``pole_bounds`` / ``pole_clip`` /
``pole_integrate``) — kernel/core parity is structural, not duplicated.
The helpers treat the station battery as the paper's (N+1)-th pole: a lane
with ``eff = eta_b`` and an unbounded energy request (``BIG`` sentinel).

Fleet grid coupling reuses the same seam: ``FleetEnv`` with
``couple_grid=True`` runs the vmapped ``request`` stage, applies one shared
proportional ``curtail`` against the fleet feeder cap, and resumes with the
vmapped ``deliver``-onward stages — all pure array ops, so the one-jit-entry
invariant over the whole scenario catalog survives.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.rewards import PenaltyTerms, StepEnergies, compute_reward, step_energies
from repro.core.state import EnvParams, EnvState
from repro.utils import replace

# Energy-request sentinel for poles with no finite request (the station
# battery): large enough that the request never binds, small enough that
# `BIG * 1000 / (V dt)` stays finite in fp32.
BIG = 1e30

# Default feeder cap [kW]: far above any station's worst-case draw, so the
# allocate stage lowers to `scale == 1.0` exactly and curtailment is a
# bitwise no-op (x * 1.0 is exact in IEEE-754).
GRID_CAP_UNLIMITED = 1e9


# ---------------------------------------------------------------------------
# Charging curve (Appendix A: piece-wise linear; discharge = vertical flip
# of the charge curve at SoC = 0.5)
# ---------------------------------------------------------------------------
def charge_rate(soc: jnp.ndarray, rbar: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """r_hat_{tau, rbar}(SoC): max charge current at the given state of charge."""
    return jnp.where(soc <= tau, rbar, rbar * (1.0 - soc) / jnp.maximum(1.0 - tau, 1e-6))


def discharge_rate(soc: jnp.ndarray, rbar: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """Discharge limit: the charge curve flipped at SoC=0.5 (paper App. A.1)."""
    return charge_rate(1.0 - soc, rbar, tau)


# ---------------------------------------------------------------------------
# Shared per-pole physics (cars AND the battery pole; also the fused-kernel
# oracle) — `eff` is the pole's storage efficiency: 1.0 for cars (port losses
# live in path_eff), eta_b for the battery (charging stores eta*E,
# discharging drains E/eta).
# ---------------------------------------------------------------------------
def pole_bounds(
    soc: jnp.ndarray,
    e_remain: jnp.ndarray,
    cap: jnp.ndarray,
    rbar: jnp.ndarray,
    tau: jnp.ndarray,
    voltage: jnp.ndarray,
    imax: jnp.ndarray,
    eff: jnp.ndarray | float,
    dt_hours: float,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-pole current bounds [A]: (up >= 0 charge limit, down <= 0 discharge).

    Charge is limited by the car curve, the port, the remaining request and
    the pack headroom; discharge by the flipped curve and the pack content.
    ``e_remain = BIG`` disables the request bound (battery pole).
    """
    rhat_chg = charge_rate(soc, rbar, tau)
    rhat_dis = discharge_rate(soc, rbar, tau)
    max_chg_amp_req = e_remain * 1000.0 / jnp.maximum(voltage * dt_hours, 1e-9)
    max_chg_amp_soc = (
        (1.0 - soc) * cap * 1000.0 / jnp.maximum(voltage * dt_hours * eff, 1e-9)
    )
    max_dis_amp_soc = soc * cap * eff * 1000.0 / jnp.maximum(voltage * dt_hours, 1e-9)
    up = jnp.minimum(
        jnp.minimum(rhat_chg, imax),
        jnp.minimum(max_chg_amp_req, max_chg_amp_soc),
    )
    down = -jnp.minimum(jnp.minimum(rhat_dis, imax), max_dis_amp_soc)
    return up, down


def pole_clip(
    target: jnp.ndarray,
    up: jnp.ndarray,
    down: jnp.ndarray,
    occupied: jnp.ndarray | float,
) -> jnp.ndarray:
    """Clip a target current into [down, max(up, 0)]; empty poles draw nothing."""
    return jnp.clip(target, down, jnp.maximum(up, 0.0)) * occupied


def pole_integrate(
    soc: jnp.ndarray,
    e_remain: jnp.ndarray,
    cap: jnp.ndarray,
    rbar: jnp.ndarray,
    tau: jnp.ndarray,
    occupied: jnp.ndarray | float,
    voltage: jnp.ndarray,
    current: jnp.ndarray,
    eff: jnp.ndarray | float,
    dt_hours: float,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Integrate one pole over dt: (e_kwh, soc', e_remain', rhat').

    The remaining request grows when a pole is discharged (V2G) but never
    past the pack headroom ``(1 - SoC') * cap`` — an uncapped request would
    be unfillable energy poisoning the missing_kwh satisfaction penalty.
    Poles carrying the ``BIG`` request sentinel (battery) keep it.
    """
    e = voltage * current * dt_hours / 1000.0  # kWh, pole-side
    soc_delta = jnp.where(e >= 0, e * eff, e / eff)
    soc_new = jnp.clip(soc + soc_delta / jnp.maximum(cap, 1e-6), 0.0, 1.0)
    headroom = jnp.where(e_remain >= 0.5 * BIG, BIG, (1.0 - soc_new) * cap)
    e_remain_new = jnp.minimum(jnp.maximum(e_remain - e, 0.0), headroom)
    rhat_new = charge_rate(soc_new, rbar, tau) * occupied
    return e, soc_new, e_remain_new, rhat_new


# ---------------------------------------------------------------------------
# Stage: decode — discrete factorized action -> target amps
# ---------------------------------------------------------------------------
def decode_action(
    action: jnp.ndarray,
    discretization: int,
    allow_v2g: bool,
    evse_max_current: jnp.ndarray,
    batt_max_current: jnp.ndarray,
    v2g_mask: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Map a discrete factorized action (N+1,) int32 in [0, 2D] to target amps.

    Level k maps to ((k - D)/D) * I_max: the paper's "10%, 20%, ... up to 100%"
    discretisation, extended symmetrically for discharging.  Ports without V2G
    clip negative targets to 0 (the battery head always may discharge).  When
    V2G is on, ``v2g_mask`` (``EnvParams.evse_v2g_mask``) marks which ports
    have bidirectional hardware — the rest stay charge-only, so a scenario can
    lower any port fraction without a new compilation.
    """
    d = float(discretization)
    frac = (action.astype(jnp.float32) - d) / d  # [-1, 1]
    port_frac, batt_frac = frac[:-1], frac[-1]
    if not allow_v2g:
        port_frac = jnp.maximum(port_frac, 0.0)
    elif v2g_mask is not None:
        port_frac = jnp.where(
            v2g_mask > 0.5, port_frac, jnp.maximum(port_frac, 0.0)
        )
    return port_frac * evse_max_current, batt_frac * batt_max_current


def decode(
    params: EnvParams,
    state: EnvState,
    action: jnp.ndarray,
    *,
    discretization: int,
    allow_v2g: bool,
    action_mode: str = "direct",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Decode stage: both action modes, as target amps (tgt_evse, tgt_batt).

    ``direct`` maps levels straight to amps; ``delta`` (the paper's additive
    form) maps levels to signed current *changes* applied on top of the
    currents held last step.
    """
    if action_mode == "direct":
        return decode_action(
            action,
            discretization,
            allow_v2g,
            params.evse_max_current,
            params.batt_max_current,
            v2g_mask=params.evse_v2g_mask,
        )
    if action_mode == "delta":
        d_evse, d_batt = decode_action(
            action,
            discretization,
            True,  # deltas may be negative even without v2g...
            params.evse_max_current,
            params.batt_max_current,
        )
        tgt_evse = state.evse_current + d_evse
        if not allow_v2g:
            tgt_evse = jnp.maximum(tgt_evse, 0.0)  # ...but targets may not
        else:  # charge-only hardware never targets negative amps
            tgt_evse = jnp.where(
                params.evse_v2g_mask > 0.5, tgt_evse, jnp.maximum(tgt_evse, 0.0)
            )
        return tgt_evse, state.batt_current + d_batt
    raise ValueError(f"unknown action_mode {action_mode!r}")


# ---------------------------------------------------------------------------
# Stage: request — apply targets + Eq. 5 constraint enforcement
# ---------------------------------------------------------------------------
class AppliedActions(NamedTuple):
    evse_current: jnp.ndarray  # (N,) post-constraint signed amps
    batt_current: jnp.ndarray  # ()
    constraint_excess: jnp.ndarray  # () max pre-rescale node violation [A]


def constraint_scale(
    currents: jnp.ndarray,  # (n_leaves,) signed amps (EVSEs + battery column)
    member: jnp.ndarray,  # (n_nodes, n_leaves)
    node_budget: jnp.ndarray,  # (n_nodes,) eta_H * I_H
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-leaf multiplicative scale enforcing Eq. 5 on every subtree.

    We use the conservative cable-thermal reading of Eq. 5 — each node carries
    the sum of *magnitudes* of its subtree currents (DESIGN.md §7).  With
    ``scale_j = min_{H ∋ j} s_H`` and ``s_H = budget_H / load_H`` the invariant
    ``sum_j |I_j * scale_j| <= budget_H`` holds for every node H, which the
    hypothesis tests assert.

    Returns (per-leaf scale in (0, 1], max pre-rescale node excess in amps).
    """
    load = member @ jnp.abs(currents)  # (n_nodes,)
    s_node = jnp.minimum(1.0, node_budget / jnp.maximum(load, 1e-9))
    excess = jnp.max(jnp.maximum(load - node_budget, 0.0))
    # min over ancestors; a leaf with no constrained ancestor is unscaled
    per_leaf = jnp.where(member > 0, s_node[:, None], jnp.inf)
    scale = jnp.min(per_leaf, axis=0)
    return jnp.where(jnp.isfinite(scale), scale, 1.0), excess


def apply_actions(
    params: EnvParams,
    state: EnvState,
    target_evse: jnp.ndarray,  # (N,) requested amps (signed)
    target_batt: jnp.ndarray,  # () requested amps (signed)
    dt_hours: float,
) -> AppliedActions:
    # --- per-port physical clips (shared pole physics; eff=1 for cars) ------
    up, down = pole_bounds(
        state.soc,
        state.e_remain,
        state.cap,
        state.rbar,
        state.tau,
        params.evse_voltage,
        params.evse_max_current,
        1.0,
        dt_hours,
    )
    i_evse = pole_clip(target_evse, up, down, state.occupied)

    # --- battery clips: the (N+1)-th pole, eff=eta_b, unbounded request -----
    b_up, b_down = pole_bounds(
        state.batt_soc,
        jnp.float32(BIG),
        params.batt_capacity,
        params.batt_max_current,
        params.batt_tau,
        params.batt_voltage,
        params.batt_max_current,
        params.batt_eff,
        dt_hours,
    )
    i_batt = pole_clip(target_batt, b_up, b_down, 1.0)

    # --- Eq. 5 tree constraints (battery = extra leaf on the root) ----------
    leaf_currents = jnp.concatenate([i_evse, i_batt[None]])
    scale, excess = constraint_scale(leaf_currents, params.member, params.node_budget)
    leaf_currents = leaf_currents * scale
    return AppliedActions(leaf_currents[:-1], leaf_currents[-1], excess)


# `request` is the stage name in the pipeline; `apply_actions` the historical
# one — both resolve to the same function.
request = apply_actions


# ---------------------------------------------------------------------------
# Stage: allocate — grid power envelope (feeder/transformer coupling)
# ---------------------------------------------------------------------------
class AllocationResult(NamedTuple):
    applied: AppliedActions  # post-curtailment currents
    power_req_kw: jnp.ndarray  # () gross grid-side charging power requested
    power_kw: jnp.ndarray  # () post-curtailment grid draw
    cap_kw: jnp.ndarray  # () feeder cap in force this step
    violation_kw: jnp.ndarray  # () max(requested - cap, 0): the pre-curtail
    #     overshoot — the penalty the RL agent can drive to 0 by requesting
    #     less, and exactly the power the allocate stage had to shed


def requested_power_kw(params: EnvParams, applied: AppliedActions) -> jnp.ndarray:
    """Gross grid-side charging power [kW] of one station's applied currents.

    Conservative cable/transformer reading: charging draws count at the grid
    side (inflated by the port path efficiency); discharge (V2G / battery)
    does not offset them — a feeder is certified for gross draw, and netting
    would let simultaneous charge+discharge hide load behind the cap.
    """
    p_evse = jnp.sum(
        params.evse_voltage
        * jnp.maximum(applied.evse_current, 0.0)
        / params.evse_path_eff
    )
    p_batt = params.batt_voltage * jnp.maximum(applied.batt_current, 0.0)
    return (p_evse + p_batt) / 1000.0


def grid_cap_kw(params: EnvParams, state: EnvState) -> jnp.ndarray:
    """Feeder power cap [kW] in force at the state's (day, step)."""
    table = params.grid_cap_kw_table
    return table[jnp.mod(state.day, table.shape[0]), jnp.mod(state.t, table.shape[1])]


def curtail(applied: AppliedActions, scale: jnp.ndarray) -> AppliedActions:
    """Scale all *charging* currents by ``scale`` (discharge untouched).

    Scaling charging magnitudes down can only lower every Eq. 5 node load,
    so constrained currents stay feasible; ``scale == 1.0`` is a bitwise
    no-op (x * 1.0 is exact).
    """
    i_evse = jnp.where(
        applied.evse_current > 0.0, applied.evse_current * scale, applied.evse_current
    )
    i_batt = jnp.where(
        applied.batt_current > 0.0, applied.batt_current * scale, applied.batt_current
    )
    return AppliedActions(i_evse, i_batt, applied.constraint_excess)


def allocate(
    params: EnvParams,
    state: EnvState,
    applied: AppliedActions,
    cap_kw: jnp.ndarray | None = None,
) -> AllocationResult:
    """Proportionally curtail charging against the feeder power envelope.

    ``cap_kw`` overrides the per-station table lookup (the fleet coupled
    step passes the shared feeder cap).  With the default
    ``GRID_CAP_UNLIMITED`` table the scale is exactly 1.0 and the applied
    currents pass through bit-identically.
    """
    cap = grid_cap_kw(params, state) if cap_kw is None else cap_kw
    p_req = requested_power_kw(params, applied)
    scale = jnp.minimum(1.0, cap / jnp.maximum(p_req, 1e-9))
    return AllocationResult(
        applied=curtail(applied, scale),
        power_req_kw=p_req,
        power_kw=jnp.minimum(p_req, cap),
        cap_kw=cap,
        violation_kw=jnp.maximum(p_req - cap, 0.0),
    )


# ---------------------------------------------------------------------------
# Stage: deliver — charge stationed cars (constant rate over dt)
# ---------------------------------------------------------------------------
class ChargeResult(NamedTuple):
    state: EnvState
    e_car: jnp.ndarray  # (N,) kWh delivered into each car this step (signed)
    e_batt_net: jnp.ndarray  # () kWh grid-side battery energy (signed)
    e_repaid: jnp.ndarray  # (N,) kWh of this step's charge that repays
    #     earlier V2G discharge (settled at p_v2g_comp, not billed at p_sell)


def charge_bookkeeping(
    state: EnvState,
    applied: AppliedActions,
    e_car: jnp.ndarray,
    soc: jnp.ndarray,
    e_remain: jnp.ndarray,
    rhat: jnp.ndarray,
    e_batt: jnp.ndarray,
    batt_soc: jnp.ndarray,
) -> ChargeResult:
    """Deliver-stage state assembly from already-integrated pole physics.

    Shared by :func:`charge_cars` (staged lax path) and the fused-kernel hot
    path (``repro.kernels.chargax_step.ops``), which computes the pole
    integration in one slab pass and hands the results here — so the deadline
    tick, V2G debt settlement and energy counters exist exactly once.
    """
    # deadlines tick only on occupied ports; padded/idle lanes hold at 0
    # instead of drifting negative without bound
    t_remain = jnp.where(state.occupied > 0.5, state.t_remain - 1, state.t_remain)

    # V2G settlement bookkeeping: discharged energy becomes debt the station
    # owes the pack; subsequent charge repays debt first (settled at
    # p_v2g_comp in the reward, not billed at p_sell) so a discharge/recharge
    # cycle earns nothing beyond a genuine buy/sell price spread
    e_repaid = jnp.minimum(jnp.maximum(e_car, 0.0), state.v2g_debt)
    v2g_debt = state.v2g_debt - e_repaid + jnp.maximum(-e_car, 0.0)

    new_state = replace(
        state,
        evse_current=applied.evse_current,
        soc=soc,
        e_remain=e_remain,
        v2g_debt=v2g_debt,
        rhat=rhat,
        t_remain=t_remain,
        batt_current=applied.batt_current,
        batt_soc=batt_soc,
        energy_delivered=state.energy_delivered + jnp.sum(jnp.maximum(e_car, 0.0)),
        energy_discharged=state.energy_discharged
        + jnp.sum(jnp.maximum(-e_car, 0.0)),
    )
    return ChargeResult(new_state, e_car, e_batt, e_repaid)


def charge_cars(
    params: EnvParams, state: EnvState, applied: AppliedActions, dt_hours: float
) -> ChargeResult:
    e_car, soc, e_remain, rhat = pole_integrate(
        state.soc,
        state.e_remain,
        state.cap,
        state.rbar,
        state.tau,
        state.occupied,
        params.evse_voltage,
        applied.evse_current,
        1.0,
        dt_hours,
    )
    # battery pole: store eta*E charging, deliver E*eta grid-side discharging
    e_b, batt_soc, _, _ = pole_integrate(
        state.batt_soc,
        jnp.float32(BIG),
        params.batt_capacity,
        params.batt_max_current,
        params.batt_tau,
        1.0,
        params.batt_voltage,
        applied.batt_current,
        params.batt_eff,
        dt_hours,
    )
    return charge_bookkeeping(
        state, applied, e_car, soc, e_remain, rhat, e_b, batt_soc
    )


deliver = charge_cars


# ---------------------------------------------------------------------------
# Stage: depart_arrive
# ---------------------------------------------------------------------------
class DepartResult(NamedTuple):
    state: EnvState
    missing_kwh: jnp.ndarray  # () c_sat,0 numerator: unmet charge of u=0 leavers
    overtime_steps: jnp.ndarray  # () overtime of u=1 leavers (steps)
    early_steps: jnp.ndarray  # () early-finish steps of u=1 leavers


def depart_cars(state: EnvState) -> DepartResult:
    occ = state.occupied > 0.5
    leave_time = occ & (state.user_type < 0.5) & (state.t_remain <= 0)
    leave_charge = occ & (state.user_type >= 0.5) & (state.e_remain <= 1e-6)
    leaving = leave_time | leave_charge

    missing = jnp.sum(jnp.where(leave_time, jnp.maximum(state.e_remain, 0.0), 0.0))
    over = jnp.sum(
        jnp.where(leave_charge, jnp.maximum(-state.t_remain, 0).astype(jnp.float32), 0.0)
    )
    early = jnp.sum(
        jnp.where(leave_charge, jnp.maximum(state.t_remain, 0).astype(jnp.float32), 0.0)
    )

    keep = (~leaving).astype(jnp.float32)
    zi = jnp.zeros_like(state.soc)
    new_state = replace(
        state,
        evse_current=state.evse_current * keep,
        occupied=state.occupied * keep,
        soc=state.soc * keep,
        e_remain=state.e_remain * keep,
        v2g_debt=state.v2g_debt * keep,
        t_remain=state.t_remain * keep.astype(state.t_remain.dtype),
        rhat=state.rhat * keep,
        cap=state.cap * keep,
        rbar=state.rbar * keep,
        tau=jnp.where(leaving, zi, state.tau),
        user_type=state.user_type * keep,
        missing_kwh_cum=state.missing_kwh_cum + missing,
        overtime_steps_cum=state.overtime_steps_cum + over,
    )
    return DepartResult(new_state, missing, over, early)


class ArriveResult(NamedTuple):
    state: EnvState
    n_arrived: jnp.ndarray  # ()
    n_rejected: jnp.ndarray  # ()


def arrive_cars(
    params: EnvParams,
    state: EnvState,
    key: jax.Array,
    rate_extra: jnp.ndarray | None = None,
) -> ArriveResult:
    n = state.occupied.shape[0]
    k_m, k_port = jax.random.split(key)

    spd = params.arrival_rate.shape[0]
    n_days = params.arrival_day_scale.shape[0]
    rate = params.arrival_rate[jnp.mod(state.t, spd)] * params.arrival_day_scale[
        jnp.mod(state.day, n_days)
    ]
    if rate_extra is not None:
        # city coupling: the station's allocated share of the population-scale
        # arrival stream (repro.city) adds to its own walk-in table; a zero
        # share leaves the Poisson rate bit-identical to the uncoupled step
        rate = rate + rate_extra
    m = jax.random.poisson(k_m, rate).astype(jnp.int32)

    # padded fleet lanes (evse_mask == 0) never accept cars
    free = (state.occupied < 0.5) & (params.evse_mask > 0.5)
    n_free = jnp.sum(free.astype(jnp.int32))
    n_arrive = jnp.minimum(m, n_free)
    n_reject = jnp.maximum(m - n_free, 0)

    # first-come-first-served: fill free ports in index order
    rank = jnp.cumsum(free.astype(jnp.int32))  # 1-based among free ports
    assign = free & (rank <= n_arrive)
    a = assign.astype(jnp.float32)

    # fleet-mix drift: a (365, n_models) table selects the day's distribution
    probs = (
        params.car_probs
        if params.car_probs.ndim == 1
        else params.car_probs[jnp.mod(state.day, params.car_probs.shape[0])]
    )

    # --- per-port profile draws (one draw per port; only assigned ports
    # consume it).  Keys are folded per port index so the draw on port i is
    # independent of n — padding a station with extra lanes leaves the real
    # lanes' trajectories bit-for-bit unchanged (FleetEnv regression tests).
    def draw_port(i):
        k_model, k_stay, k_soc0, k_tgt, k_u = jax.random.split(
            jax.random.fold_in(k_port, i), 5
        )
        model = jax.random.choice(k_model, probs.shape[0], p=probs)
        z_stay = jax.random.normal(k_stay, ())
        soc0 = jax.random.beta(k_soc0, params.soc0_a, params.soc0_b)
        z_tgt = jax.random.normal(k_tgt, ())
        bern = jax.random.bernoulli(k_u, params.p_time_sensitive)
        return model, z_stay, soc0, z_tgt, bern

    model, z_stay, soc0_raw, z_tgt, bern = jax.vmap(draw_port)(jnp.arange(n))

    # --- car profiles --------------------------------------------------------
    cap = params.car_capacity[model]
    tau = params.car_tau[model]
    car_kw = jnp.where(
        params.evse_is_dc > 0.5, params.car_dc_kw[model], params.car_ac_kw[model]
    )
    rbar = car_kw * 1000.0 / params.evse_voltage  # car-side current limit [A]

    # --- user profiles -------------------------------------------------------
    stay_h = jnp.exp(params.stay_mu_log + params.stay_sigma * z_stay)
    steps_per_hour = spd / 24.0
    stay_steps = jnp.maximum((stay_h * steps_per_hour).astype(jnp.int32), 1)
    soc0 = jnp.clip(soc0_raw, 0.02, 0.95)
    target = jnp.clip(
        params.target_soc_mu + params.target_soc_std * z_tgt, soc0 + 0.05, 1.0
    )
    e_req = (target - soc0) * cap
    # u: 0 = time-sensitive (leaves at deadline), 1 = charge-sensitive
    u = 1.0 - bern.astype(jnp.float32)

    new_state = replace(
        state,
        occupied=state.occupied * (1 - a) + a,
        soc=state.soc * (1 - a) + a * soc0,
        e_remain=state.e_remain * (1 - a) + a * e_req,
        v2g_debt=state.v2g_debt * (1 - a),  # fresh arrivals carry no debt
        t_remain=jnp.where(assign, stay_steps, state.t_remain),
        rhat=state.rhat * (1 - a) + a * charge_rate(soc0, rbar, tau),
        cap=state.cap * (1 - a) + a * cap,
        rbar=state.rbar * (1 - a) + a * rbar,
        tau=jnp.where(assign, tau, state.tau),
        user_type=state.user_type * (1 - a) + a * u,
        cars_served=state.cars_served + n_arrive.astype(jnp.float32),
        cars_rejected=state.cars_rejected + n_reject.astype(jnp.float32),
    )
    return ArriveResult(new_state, n_arrive, n_reject)


class DepartArriveResult(NamedTuple):
    state: EnvState
    missing_kwh: jnp.ndarray  # ()
    overtime_steps: jnp.ndarray  # ()
    early_steps: jnp.ndarray  # ()
    n_arrived: jnp.ndarray  # ()
    n_rejected: jnp.ndarray  # ()


def depart_arrive(
    params: EnvParams,
    state: EnvState,
    key: jax.Array,
    rate_extra: jnp.ndarray | None = None,
) -> DepartArriveResult:
    """Departures then arrivals, splitting the step key for the Poisson draw.

    ``rate_extra`` (optional, scalar cars/step) feeds extra expected arrivals
    into the Poisson draw — the per-station input the city demand-allocation
    layer computes each step instead of a fixed table.
    """
    departed = depart_cars(state)
    key, k_arr = jax.random.split(key)
    arrived = arrive_cars(params, departed.state, k_arr, rate_extra)
    return DepartArriveResult(
        arrived.state,
        departed.missing_kwh,
        departed.overtime_steps,
        departed.early_steps,
        arrived.n_arrived,
        arrived.n_rejected,
    )


# ---------------------------------------------------------------------------
# Stage: settle — energies, Eq. 1-3 reward, grid-axis penalties
# ---------------------------------------------------------------------------
class SettleResult(NamedTuple):
    reward: jnp.ndarray  # () Eq. 3 reward incl. grid penalties
    profit: jnp.ndarray  # () Eq. 2 profit
    energies: StepEnergies
    penalties: PenaltyTerms
    p_buy: jnp.ndarray  # () buy price this step
    setpoint_kw: jnp.ndarray  # () DSO setpoint in force
    setpoint_dev_kw: jnp.ndarray  # () |power_drawn - setpoint|


def settle(
    params: EnvParams,
    state: EnvState,  # the PRE-step state (this step's clock / price row)
    alloc: AllocationResult,
    charged: ChargeResult,
    moved: DepartArriveResult,
    dt_hours: float,
) -> SettleResult:
    """Reward settlement for one step.

    The base Eq. 1-3 algebra is untouched; the grid axis adds two linear
    penalty terms on top — ``grid_violation`` (kW the request overshot the
    feeder cap, before curtailment) and ``grid_setpoint`` (absolute tracking
    error against the DSO setpoint).  Both weights default to 0.0, making
    the additions exact bitwise no-ops for non-grid scenarios.
    """
    spd = state.price_buy.shape[0]
    e_pv = (
        params.pv_kw_table[
            jnp.mod(state.day, params.pv_kw_table.shape[0]),
            jnp.mod(state.t, spd),
        ]
        * dt_hours
    )
    energies = step_energies(
        params, charged.e_car, charged.e_batt_net, e_pv, charged.e_repaid
    )
    p_buy = state.price_buy[jnp.mod(state.t, spd)]
    reward, pi, pen = compute_reward(
        params,
        energies,
        p_buy,
        alloc.applied.constraint_excess,
        moved.missing_kwh,
        moved.overtime_steps,
        moved.early_steps,
        moved.n_rejected,
        charged.e_car,
        state.t,
        state.price_buy,
        dt_hours,
    )
    sp_table = params.grid_setpoint_kw_table
    setpoint = sp_table[
        jnp.mod(state.day, sp_table.shape[0]), jnp.mod(state.t, sp_table.shape[1])
    ]
    setpoint_dev = jnp.abs(alloc.power_kw - setpoint)
    w = params.weights
    reward = (
        reward - w.grid_violation * alloc.violation_kw - w.grid_setpoint * setpoint_dev
    )
    return SettleResult(reward, pi, energies, pen, p_buy, setpoint, setpoint_dev)


# ---------------------------------------------------------------------------
# Stage: advance_time — clock tick + midnight calendar rollover
# ---------------------------------------------------------------------------
def advance_time(params: EnvParams, state: EnvState, profit: jnp.ndarray) -> EnvState:
    """At midnight advance the day (mod table length) and reload the price
    row, so multi-day episodes see day-1+ prices, PV, arrival-day-scale and
    the weekday feature instead of replaying day 0 forever."""
    spd = state.price_buy.shape[0]
    t_next = state.t + 1
    n_days = params.price_buy_table.shape[0]
    midnight = jnp.mod(t_next, spd) == 0
    day_next = jnp.where(midnight, jnp.mod(state.day + 1, n_days), state.day)
    price_next = jnp.where(
        midnight, params.price_buy_table[day_next], state.price_buy
    )
    return replace(
        state,
        t=t_next,
        day=day_next,
        price_buy=price_next,
        profit_cum=state.profit_cum + profit,
    )


# ---------------------------------------------------------------------------
# Stage: observe
# ---------------------------------------------------------------------------
def observe(
    params: EnvParams,
    state: EnvState,
    *,
    steps_per_day: int,
    horizon_steps: int,
    near_steps: int,
) -> jnp.ndarray:
    """Flat float32 observation (see ``ChargaxEnv.observation_space``)."""
    spd = steps_per_day
    imax = params.evse_max_current
    port_feats = jnp.stack(
        [
            state.occupied,
            state.evse_current / imax,
            state.soc,
            state.e_remain / jnp.maximum(state.cap, 1.0),
            # V2G debt: how much of the remaining request is energy the
            # station borrowed (repaid at p_v2g_comp, not billed) — the
            # agent needs this to price discharge decisions correctly
            state.v2g_debt / jnp.maximum(state.cap, 1.0),
            jnp.clip(state.t_remain.astype(jnp.float32) / spd, -1.0, 1.0),
            state.rhat / imax,
            state.user_type,
        ],
        axis=-1,
    ).reshape(-1)
    batt_feats = jnp.stack(
        [state.batt_soc, state.batt_current / jnp.maximum(params.batt_max_current, 1.0)]
    )
    tf = state.t.astype(jnp.float32)
    phase = 2.0 * jnp.pi * tf / spd
    weekday = ((state.day % 7) < 5).astype(jnp.float32)
    time_feats = jnp.stack(
        [jnp.sin(phase), jnp.cos(phase), weekday, state.day.astype(jnp.float32) / 365.0]
    )
    idx = jnp.mod(state.t, spd)
    ahead = state.price_buy[jnp.mod(idx + jnp.arange(horizon_steps), spd)]
    price_feats = jnp.stack(
        [state.price_buy[idx], jnp.mean(ahead[:near_steps]), jnp.mean(ahead)]
    )
    return jnp.concatenate([port_feats, batt_feats, time_feats, price_feats])
