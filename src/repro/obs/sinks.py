"""Unified run sinks: JSONL metrics writer + run manifests + BENCH JSON.

Every artifact this repo persists — ``BENCH_<name>.json`` from
``benchmarks.run``, training/eval metrics from ``rl_train``, machine-
readable benchmark stdout lines — goes through this module, so provenance
(git sha, backend, device count, ``schema_version``) is recorded once,
identically, everywhere.  Before this module each benchmark hand-rolled its
own ``json.dump`` with its own field set.

Schema (``SCHEMA_VERSION``):

* every record carries ``schema_version``;
* file-level artifacts embed the :func:`run_manifest` fields at top level
  (BENCH JSON) or as a leading ``{"kind": "manifest"}`` line (JSONL);
* JSONL records are one JSON object per line with a ``kind`` tag
  (``manifest`` / ``metrics`` / ``eval`` / ``bench``).
"""
from __future__ import annotations

import json
import os
import subprocess
import time
import warnings
from typing import Any, IO

import numpy as np

SCHEMA_VERSION = 1

# write_benchmark_json warns when it overwrites a BENCH file whose recorded
# git_sha is more than this many commits behind HEAD — stale root benchmarks
# (e.g. still carrying the seed sha) go loud instead of silently rotting
STALE_BENCH_COMMITS = 5

REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..", "..")
)


def git_sha(root: str | None = None) -> str:
    """HEAD sha of the repo (``"unknown"`` outside a checkout)."""
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=root or REPO_ROOT,
            text=True,
            stderr=subprocess.DEVNULL,
        ).strip()
    except Exception:  # noqa: BLE001
        return "unknown"


def commits_behind(sha: str | None, root: str | None = None) -> int | None:
    """How many commits HEAD is ahead of ``sha`` (``None`` when unknowable:
    no/invalid sha, shallow clone, outside a checkout)."""
    if not sha or sha == "unknown":
        return None
    try:
        out = subprocess.check_output(
            ["git", "rev-list", "--count", f"{sha}..HEAD"],
            cwd=root or REPO_ROOT,
            text=True,
            stderr=subprocess.DEVNULL,
        )
        return int(out.strip())
    except Exception:  # noqa: BLE001
        return None


def run_manifest(**extra: Any) -> dict:
    """Provenance every persisted artifact shares: schema version, git sha,
    jax/backend/device identity, wall-clock.  ``extra`` keys merge on top
    (callers add e.g. ``benchmark=...`` or the CLI args)."""
    import jax

    rec = {
        "schema_version": SCHEMA_VERSION,
        "git_sha": git_sha(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "process_count": jax.process_count(),
        "unix_time": int(time.time()),
    }
    rec.update(extra)
    return rec


def to_jsonable(obj: Any) -> Any:
    """Recursively convert numpy/jax scalars and arrays to plain python."""
    if isinstance(obj, dict):
        return {str(k): to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [to_jsonable(v) for v in obj]
    if isinstance(obj, (np.floating, np.integer, np.bool_)):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if hasattr(obj, "dtype") and hasattr(obj, "tolist"):  # jax arrays
        return np.asarray(obj).tolist()
    return obj


class MetricsWriter:
    """Append-only JSONL metrics sink.

    Opens (creating directories), writes one :func:`run_manifest` line, then
    one JSON object per :meth:`write` call — the shared persistence for
    ``rl_train`` metrics, eval results and benchmark summaries.  CI uploads
    the file as an artifact.

        with MetricsWriter("results/metrics.jsonl", run="ppo") as w:
            w.write({"update": 3, "kpi/profit": 12.5})
    """

    def __init__(self, path: str, mode: str = "a", **manifest_extra: Any):
        self.path = path
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self._f: IO[str] | None = open(path, mode)
        self.manifest = run_manifest(**manifest_extra)
        self._emit({"kind": "manifest", **self.manifest})

    def _emit(self, record: dict) -> None:
        if self._f is None:
            raise ValueError(f"MetricsWriter({self.path!r}) is closed")
        self._f.write(json.dumps(to_jsonable(record)) + "\n")
        self._f.flush()

    def write(self, record: dict, kind: str = "metrics") -> None:
        """Append one record (``kind`` tags it; ``schema_version`` stamped)."""
        self._emit({"kind": kind, "schema_version": SCHEMA_VERSION, **record})

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "MetricsWriter":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


def read_jsonl(path: str) -> list[dict]:
    """Load a JSONL file back into a list of records (tests, dashboards)."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def write_benchmark_json(
    name: str,
    rows: list[tuple[str, float, str]],
    summary: dict | None = None,
    quick: bool = True,
    root: str | None = None,
) -> str:
    """The ONE ``BENCH_<name>.json`` writer (used by ``benchmarks.run``).

    Layout matches the historical files — summary fields at top level so
    headline numbers (steps_per_sec, wrapper_overhead_frac, ...) stay
    greppable — plus the shared manifest fields and ``schema_version``.
    Provenance keys always win over summary keys.  Returns the path.

    Overwriting a file whose recorded ``git_sha`` trails HEAD by more than
    ``STALE_BENCH_COMMITS`` commits raises a ``UserWarning``: the committed
    numbers were stale, so diff the refresh before trusting perf deltas.
    """
    path = os.path.join(root or REPO_ROOT, f"BENCH_{name}.json")
    if os.path.exists(path):
        try:
            with open(path) as f:
                old_sha = json.load(f).get("git_sha")
        except Exception:  # noqa: BLE001 - corrupt old file: nothing to warn on
            old_sha = None
        behind = commits_behind(old_sha, root=root)
        if behind is not None and behind > STALE_BENCH_COMMITS:
            warnings.warn(
                f"BENCH_{name}.json was {behind} commits stale "
                f"(recorded git_sha {old_sha[:12]}); the numbers it held no "
                "longer described this tree — compare the refresh carefully",
                UserWarning,
                stacklevel=2,
            )
    rec = dict(summary or {})
    rec.update(
        run_manifest(benchmark=name, quick=quick),
        rows=[
            {"name": r, "us_per_call": round(float(v), 3), "derived": d}
            for r, v, d in rows
        ],
    )
    with open(path, "w") as f:
        json.dump(to_jsonable(rec), f, indent=1)
    return path


def emit_json_line(tag: str, obj: dict) -> str:
    """Print a machine-readable ``TAG {json}`` stdout line (the FLEET_JSON
    pattern, now shared) and return it."""
    line = f"{tag} " + json.dumps(to_jsonable(obj))
    print(line, flush=True)
    return line
