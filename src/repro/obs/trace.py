"""Trace annotations: named phases for profiles, zero-cost when disabled.

:func:`annotate` marks a region with ``jax.named_scope`` (names the XLA ops
traced inside it, so phases show up in a profile and in HLO metadata) plus
``jax.profiler.TraceAnnotation`` (marks the host thread, so host-side phases
show as spans).  Annotations are **disabled by default** and the disabled
path is a bare ``yield`` — the compiled program is byte-identical with the
subsystem off, which the speed benchmark relies on
(``benchmarks/speed_table.py`` proves raw-vs-wrapped HLO equality).

Enable them either with :func:`enable_trace_annotations` /
``REPRO_TRACE=1``, or implicitly via :func:`trace_session`, which wraps
``jax.profiler.start_trace``/``stop_trace`` and yields a perfetto-viewable
``*.trace.json.gz`` (open at https://ui.perfetto.dev).  ``rl_train
--profile DIR`` is the CLI surface.

Phase-name catalog (see ``docs/observability.md``):

=====================  ==================================================
``env/decode``          action decoding (direct / delta modes)
``env/apply_actions``   Eq. 5 constrained current allocation
``env/charge_cars``     battery/car energy integration + V2G debt
``env/depart_arrive``   departures, arrivals, rejections
``env/reward``          Eq. 2 revenue + penalty terms
``env/observe``         observation build
``wrap/<Wrapper>``      each wrapper layer's step (Vmap, AutoReset, Log…)
``ppo/rollout``         the rollout scan
``ppo/gae``             advantage estimation
``ppo/update``          minibatch epochs
``eval/rollout``        evaluation episodes
=====================  ==================================================
"""
from __future__ import annotations

import contextlib
import glob
import os
from typing import Iterator

import jax

_enabled: bool = os.environ.get("REPRO_TRACE", "0").lower() in ("1", "true", "yes")


def trace_annotations_enabled() -> bool:
    """Whether :func:`annotate` currently emits named scopes."""
    return _enabled


def enable_trace_annotations(on: bool = True) -> bool:
    """Toggle annotations globally; returns the previous setting.

    Enable *before* building/jitting the functions you want annotated:
    ``named_scope`` acts at trace time, so already-compiled programs keep
    their unannotated cache entries.
    """
    global _enabled
    prev, _enabled = _enabled, bool(on)
    return prev


@contextlib.contextmanager
def annotate(name: str) -> Iterator[None]:
    """Mark a phase: ``with annotate("env/charge_cars"): ...``.

    Inside jitted code this names the ops traced under it (visible in
    profiles and HLO metadata); on the host it opens a profiler span.
    Disabled (the default) it is a bare yield — no named_scope, no
    TraceAnnotation, no program change.
    """
    if not _enabled:
        yield
        return
    with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
        yield


def _start_trace(log_dir: str, python_tracer: bool) -> None:
    """``jax.profiler.start_trace``, optionally without the Python-call
    tracer.

    jax's default profiler options record EVERY python call (``isinstance``,
    ``len``, …) while tracing runs under the session — for a jit-heavy
    program that is ~100k events per second of trace time and dwarfs the
    phase spans we care about.  Level 0 keeps host ``TraceAnnotation`` spans
    and device/op events.  Falls back to the public API if jax's internals
    have moved.
    """
    if not python_tracer:
        try:
            from jax._src import profiler as _jprof
            from jax._src.lib import xla_client as _xc

            opts = _xc.profiler.ProfileOptions()
            opts.python_tracer_level = 0
            with _jprof._profile_state.lock:
                if _jprof._profile_state.profile_session is not None:
                    raise RuntimeError(
                        "Profile has already been started. "
                        "Only one profile may be run at a time."
                    )
                _jprof.xla_bridge.get_backend()
                _jprof._profile_state.profile_session = _xc.profiler.ProfilerSession(
                    opts
                )
                _jprof._profile_state.create_perfetto_link = False
                _jprof._profile_state.create_perfetto_trace = False
                _jprof._profile_state.log_dir = str(log_dir)
            return
        except (ImportError, AttributeError):  # pragma: no cover - jax drift
            pass
    jax.profiler.start_trace(log_dir)


@contextlib.contextmanager
def trace_session(
    log_dir: str,
    enable_annotations: bool = True,
    keep_xplane: bool = True,
    python_tracer: bool = False,
) -> Iterator[str]:
    """Profile a region: annotations on, ``jax.profiler`` tracing to
    ``log_dir``.  Yields ``log_dir``; on exit the trace is flushed and the
    annotation toggle restored.

    The session only annotates functions *traced inside it* (or after
    :func:`enable_trace_annotations`); pre-compiled cache entries keep
    their old names.  Find the trace with :func:`latest_trace`.

    Keep the traced region SMALL — one representative update / a handful of
    env steps.  The CPU tracer records every op execution, so tracing a full
    training run produces multi-GB event buffers and a multi-minute
    ``stop_trace``.  ``rl_train --profile`` therefore traces a one-update
    probe, not the real run.

    ``keep_xplane=False`` deletes the bulky ``*.xplane.pb`` sidecar after
    the trace is flushed, keeping only the perfetto ``*.trace.json.gz`` —
    use for CI artifacts (see :func:`check_trace_budget`).

    ``python_tracer=True`` additionally records every Python call (jax's
    upstream default) — an order of magnitude more events; only useful when
    hunting host-side python overhead.
    """
    os.makedirs(log_dir, exist_ok=True)
    prev = enable_trace_annotations(enable_annotations)
    _start_trace(log_dir, python_tracer)
    try:
        yield log_dir
    finally:
        jax.profiler.stop_trace()
        enable_trace_annotations(prev)
        if not keep_xplane:
            for p in glob.glob(
                os.path.join(log_dir, "**", "*.xplane.pb"), recursive=True
            ):
                os.remove(p)


def latest_trace(log_dir: str) -> str | None:
    """Newest perfetto trace file under ``log_dir`` (None if no trace)."""
    paths = glob.glob(
        os.path.join(log_dir, "**", "*.trace.json.gz"), recursive=True
    )
    return max(paths, key=os.path.getmtime) if paths else None


def trace_bytes(log_dir: str) -> int:
    """Total size of all profiler output under ``log_dir``."""
    total = 0
    for root, _, files in os.walk(log_dir):
        for f in files:
            total += os.path.getsize(os.path.join(root, f))
    return total


def check_trace_budget(log_dir: str, max_kb: int = 8192, verbose: bool = False) -> int:
    """Artifact-size guard for CI: profiler output must stay shippable.

    Raises ``RuntimeError`` if the trace directory exceeds ``max_kb``;
    returns the total size in bytes.  Mirrors the vendored-fixture budget
    guard (``repro.data.ingest.check_fixture_budget``) for trace output.
    """
    total = trace_bytes(log_dir)
    if verbose:
        print(f"[obs] trace artifacts under {log_dir}: {total/1024:.1f} KB "
              f"(budget {max_kb} KB)")
    if total > max_kb * 1024:
        raise RuntimeError(
            f"trace output in {log_dir} is {total/1024:.0f} KB, over the "
            f"{max_kb} KB artifact budget — lower the traced region size "
            "(fewer updates/steps under trace_session)"
        )
    return total
