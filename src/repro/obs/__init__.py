"""Telemetry subsystem: in-jit metrics, trace annotations, recompile
sentinel, and unified run sinks.

The paper's headline claim is raw speed; this package is how the repro
*explains* its own numbers.  Four pieces, each usable on its own:

``metrics``
    :class:`MetricsAccumulator` — a pure pytree of named scalar sums /
    maxes carried through jitted rollout scans (no host syncs) and flushed
    to plain numbers at the host boundary.  Exposed by
    ``repro.envs.LogWrapper(..., metrics=...)`` and consumed by PPO's
    per-update KPI report.

``trace``
    :func:`annotate` — ``jax.named_scope`` + ``jax.profiler.TraceAnnotation``
    phase markers over env.step stages, each wrapper layer and the PPO
    phases.  Off by default (zero-cost: the compiled program is proven
    byte-identical); enabled by ``rl_train --profile DIR`` /
    :func:`trace_session`, which emits a perfetto-viewable trace.

``guard``
    :func:`compile_guard` — the recompile sentinel.  Counts jit
    compilations across a region and raises :class:`RecompileError` with
    the offending function names and argument avals, turning the "one jit
    entry for the whole scenario catalog" invariant into a reusable
    runtime guard (tests, CI protocol-conformance, ``rl_train``
    preflight).

``sinks``
    :class:`MetricsWriter` (JSONL) + :func:`run_manifest` (git sha,
    backend, device count, ``schema_version``) + the shared
    ``BENCH_<name>.json`` persistence used by ``benchmarks.run``,
    ``rl_train`` and eval — one schema instead of per-module hand-rolled
    JSON.

See ``docs/observability.md`` for the metrics catalog, trace-phase names
and how to read a profile.
"""
from repro.obs.guard import (
    RecompileError,
    assert_one_compiled_step,
    cache_entries,
    compile_guard,
)
from repro.obs.metrics import MetricsAccumulator
from repro.obs.sinks import (
    SCHEMA_VERSION,
    MetricsWriter,
    commits_behind,
    emit_json_line,
    read_jsonl,
    run_manifest,
    write_benchmark_json,
)
from repro.obs.trace import (
    annotate,
    check_trace_budget,
    enable_trace_annotations,
    latest_trace,
    trace_annotations_enabled,
    trace_session,
)

__all__ = [
    "MetricsAccumulator",
    "MetricsWriter",
    "RecompileError",
    "SCHEMA_VERSION",
    "annotate",
    "assert_one_compiled_step",
    "cache_entries",
    "check_trace_budget",
    "commits_behind",
    "compile_guard",
    "emit_json_line",
    "enable_trace_annotations",
    "latest_trace",
    "read_jsonl",
    "run_manifest",
    "trace_annotations_enabled",
    "trace_session",
    "write_benchmark_json",
]
