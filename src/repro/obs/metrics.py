"""In-jit metrics: a pure pytree accumulator for named scalar KPIs.

:class:`MetricsAccumulator` is a NamedTuple of ``{name: array}`` dicts plus
an update counter, so it threads through ``jit``/``vmap``/``lax.scan``
unchanged — domain KPIs (energy delivered, v2g debt, episode return, ...)
accumulate *on device* during the rollout scan and cross to the host exactly
once, at :meth:`MetricsAccumulator.flush`.  No per-step device syncs, no
python-side accounting inside the hot loop.

Accumulation is plain elementwise ``+`` / ``maximum`` in update order, so a
scanned accumulator matches a sequential Python-loop reference bit-for-bit
(``tests/obs/test_metrics.py``), and per-env lanes under ``vmap`` are the
independent per-env loops.

Typical use (what ``repro.envs.LogWrapper(..., metrics=...)`` does)::

    acc = MetricsAccumulator.create(("profit", "energy_delivered"),
                                    batch_shape=(num_envs,))
    def body(acc, info):
        return acc.update({k: info[k] for k in acc.names}), None
    acc, _ = jax.lax.scan(body, acc, infos)
    print(acc.flush(means=("profit",)))    # host boundary: plain floats
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class MetricsAccumulator(NamedTuple):
    """Named scalar sums/maxes as a pytree (dict leaves are jit/vmap/scan
    compatible; the key sets are static structure)."""

    sums: dict[str, jnp.ndarray]
    maxes: dict[str, jnp.ndarray]
    count: jnp.ndarray  # number of update() calls (per batch lane)

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------
    @classmethod
    def create(
        cls,
        sum_names: tuple[str, ...] | list[str] = (),
        max_names: tuple[str, ...] | list[str] = (),
        batch_shape: tuple[int, ...] = (),
    ) -> "MetricsAccumulator":
        """Zero-initialised accumulator; ``batch_shape`` adds leading batch
        axes (one independent accumulator per env lane under ``vmap``)."""
        return cls(
            sums={n: jnp.zeros(batch_shape, jnp.float32) for n in sum_names},
            maxes={n: jnp.full(batch_shape, -jnp.inf, jnp.float32) for n in max_names},
            count=jnp.zeros(batch_shape, jnp.float32),
        )

    @property
    def names(self) -> tuple[str, ...]:
        """All tracked metric names (sums then maxes)."""
        return tuple(self.sums) + tuple(m for m in self.maxes if m not in self.sums)

    # ------------------------------------------------------------------
    # In-jit ops (pure; return a new accumulator)
    # ------------------------------------------------------------------
    def update(self, values: dict[str, Any]) -> "MetricsAccumulator":
        """One step's named scalars folded in: sums add, maxes max-merge.

        Every tracked name must be present in ``values`` (missing keys are a
        trace-time ``KeyError`` — silently skipping a KPI would report a
        wrong total); extra keys are ignored.
        """
        sums = {n: s + values[n] for n, s in self.sums.items()}
        maxes = {n: jnp.maximum(m, values[n]) for n, m in self.maxes.items()}
        return MetricsAccumulator(sums, maxes, self.count + 1.0)

    def merge(self, other: "MetricsAccumulator") -> "MetricsAccumulator":
        """Combine two accumulators over the same names (e.g. across hosts
        or shards): sums/counts add, maxes max-merge."""
        if self.names != other.names:
            raise ValueError(
                f"cannot merge accumulators over different metrics: "
                f"{self.names} vs {other.names}"
            )
        return MetricsAccumulator(
            sums={n: s + other.sums[n] for n, s in self.sums.items()},
            maxes={n: jnp.maximum(m, other.maxes[n]) for n, m in self.maxes.items()},
            count=self.count + other.count,
        )

    def since(self, earlier: "MetricsAccumulator") -> "MetricsAccumulator":
        """The delta accumulated after ``earlier`` (sums/count subtract —
        the per-update KPI window PPO reports; maxes stay absolute)."""
        return MetricsAccumulator(
            sums={n: s - earlier.sums[n] for n, s in self.sums.items()},
            maxes=dict(self.maxes),
            count=self.count - earlier.count,
        )

    # ------------------------------------------------------------------
    # Host boundary
    # ------------------------------------------------------------------
    def flush(
        self, means: tuple[str, ...] | list[str] = (), reduce_batch: bool = True
    ) -> dict[str, float]:
        """Cross to the host ONCE: return plain-float totals.

        ``{name}`` is the summed total, ``{name}_per_step`` (for names in
        ``means``) divides by the update count, ``{name}_max`` reports
        max-merged names, and ``steps`` is the mean update count.  With
        ``reduce_batch`` (default) batch lanes are averaged — per-lane
        arrays are returned otherwise.
        """
        red = (lambda x: np.asarray(x).mean()) if reduce_batch else np.asarray
        out: dict[str, Any] = {}
        count = np.maximum(np.asarray(self.count), 1.0)
        for n, s in self.sums.items():
            out[n] = float(red(s)) if reduce_batch else red(s)
            if n in means:
                per = np.asarray(s) / count
                out[f"{n}_per_step"] = float(per.mean()) if reduce_batch else per
        for n, m in self.maxes.items():
            v = np.asarray(m)
            out[f"{n}_max"] = float(v.max()) if reduce_batch else v
        out["steps"] = float(np.asarray(self.count).mean()) if reduce_batch else np.asarray(self.count)
        return out


def kpi_summary(acc: MetricsAccumulator, prefix: str = "kpi/") -> dict[str, jnp.ndarray]:
    """Batch-mean device scalars for every tracked sum (still traced — used
    by PPO to emit per-update KPI metrics without leaving the jit)."""
    out = {f"{prefix}{n}": s.mean() for n, s in acc.sums.items()}
    for n, m in acc.maxes.items():
        out[f"{prefix}{n}_max"] = m.max()
    return out


def _is_acc(x: Any) -> bool:
    return isinstance(x, MetricsAccumulator)


def tree_find_accumulators(tree: Any) -> list[MetricsAccumulator]:
    """Collect every :class:`MetricsAccumulator` inside an arbitrary pytree
    (e.g. a wrapper state) — how hosts locate the KPIs to flush."""
    found: list[MetricsAccumulator] = []
    jax.tree_util.tree_map(
        lambda x: found.append(x) if _is_acc(x) else None,
        tree,
        is_leaf=_is_acc,
    )
    return found
