"""Recompile sentinel: count jit compilations across a region, loudly.

``jax`` recompiles silently whenever a traced function sees a new static
signature — new array shapes/dtypes, a new pytree structure, a changed
static argument.  For this repro that is a correctness bug, not a perf
wobble: the whole scenario catalog must run under ONE compiled step (pure
array swaps), and a stray recompile on the training path can cost minutes.

:func:`compile_guard` turns that invariant into a runtime guard::

    step = jax.jit(wenv.step)
    step(key, state, action, params0)            # warm-up: compiles once
    with compile_guard("scenario catalog"):      # region must not compile
        for p in all_params[1:]:
            step(key, state, action, p)

On violation it raises :class:`RecompileError` naming each offending
function together with the argument avals that triggered the new cache
entry — the information you need to find the leaked python scalar / changed
shape.  Detection listens to jax's own compilation log (``jax.log_compiles``)
so it sees *every* compile in the region, including nested jits the caller
never wrapped.

Used by ``tests/envs/test_protocol.py`` (the CI protocol-conformance job),
``benchmarks/speed_table.py`` (real-data params must reuse the synthetic
entry) and the ``rl_train`` scenario preflight.
"""
from __future__ import annotations

import contextlib
import logging
import re
from typing import Any, Iterator, NamedTuple

import jax

# the logger jax emits "Compiling <name> with global shapes and types
# [avals...]" records on (at WARNING) while jax.log_compiles() is active
_COMPILE_LOGGER = "jax._src.interpreters.pxla"
_COMPILE_RE = re.compile(r"^Compiling (.+?) with global shapes and types (\[.*\])\. Argument")


class CompileEvent(NamedTuple):
    """One observed compilation: the jitted callable's name + its avals."""

    name: str
    avals: str
    message: str


class RecompileError(RuntimeError):
    """A guarded region compiled more than its allowance."""

    def __init__(self, label: str, events: list[CompileEvent], max_compiles: int):
        self.events = events
        lines = "\n".join(f"  - {e.name}: {e.avals}" for e in events)
        super().__init__(
            f"compile_guard({label!r}): {len(events)} compilation(s) in a "
            f"region allowing {max_compiles} — offending functions and "
            f"argument avals:\n{lines}\n"
            "Recompiles mean a static signature changed (new shape/dtype, "
            "new pytree structure, python-scalar leak). Scenario/params "
            "swaps must be pure array swaps."
        )


class _CaptureHandler(logging.Handler):
    def __init__(self, allow: tuple[str, ...]):
        super().__init__(level=logging.DEBUG)
        self.allow = allow
        self.events: list[CompileEvent] = []

    def emit(self, record: logging.LogRecord) -> None:  # noqa: D102
        msg = record.getMessage()
        m = _COMPILE_RE.match(msg)
        if not m:
            return
        name = m.group(1)
        if any(a in name for a in self.allow):
            return
        self.events.append(CompileEvent(name, m.group(2), msg))


class CompileGuard:
    """Handle yielded by :func:`compile_guard` — inspect ``.events`` /
    ``.count`` inside the region (e.g. to log rather than raise)."""

    def __init__(self, handler: _CaptureHandler):
        self._handler = handler

    @property
    def events(self) -> list[CompileEvent]:
        return list(self._handler.events)

    @property
    def count(self) -> int:
        return len(self._handler.events)


@contextlib.contextmanager
def compile_guard(
    label: str = "region",
    max_compiles: int = 0,
    allow: tuple[str, ...] = (),
    raise_on_violation: bool = True,
) -> Iterator[CompileGuard]:
    """Guard a region against jit recompilation.

    Args:
        label: human-readable region name for the error message.
        max_compiles: compilations the region is allowed (0 = the region
            must run entirely from cache; 1 = e.g. "first call compiles").
        allow: substrings of function names to ignore (e.g. tiny host
            utilities like ``convert_element_type`` during warm-up).
        raise_on_violation: raise :class:`RecompileError` on exit when the
            allowance is exceeded (set False to only collect ``.events``).
    """
    handler = _CaptureHandler(tuple(allow))
    logger = logging.getLogger(_COMPILE_LOGGER)
    # keep the sentinel's probe lines off stderr while the region runs (the
    # dispatch logger emits per-compile timing lines under log_compiles too)
    muted = [logger, logging.getLogger("jax._src.dispatch")]
    prev_propagate = [lg.propagate for lg in muted]
    logger.addHandler(handler)
    for lg in muted:
        lg.propagate = False
    try:
        with jax.log_compiles():
            yield CompileGuard(handler)
    finally:
        logger.removeHandler(handler)
        for lg, p in zip(muted, prev_propagate):
            lg.propagate = p
    if raise_on_violation and len(handler.events) > max_compiles:
        raise RecompileError(label, handler.events, max_compiles)


def cache_entries(fn: Any) -> int:
    """Number of compiled entries in a ``jax.jit`` function's cache (the
    per-function view; :func:`compile_guard` is the region-wide one)."""
    try:
        return int(fn._cache_size())
    except AttributeError as e:  # pragma: no cover - jax version drift
        raise TypeError(
            f"{fn!r} has no jit cache (pass the jax.jit-wrapped callable)"
        ) from e


def assert_one_compiled_step(
    env: Any,
    params_list: list[Any],
    num_envs: int = 2,
    key: jax.Array | None = None,
    label: str = "scenario catalog",
) -> int:
    """Prove a parameter catalog shares ONE compiled step for ``env``.

    Steps ``env`` (any ``repro.envs.Environment``) once per params pytree:
    the first call may compile, every later call must hit the cache.
    Raises :class:`RecompileError` otherwise; returns the number of params
    checked.  This is the preflight ``rl_train --scenarios`` runs before
    paying for a full training compile.
    """
    from repro.envs import VmapWrapper

    venv = VmapWrapper(env, num_envs)
    step = jax.jit(venv.step)
    key = key if key is not None else jax.random.key(0)
    obs, state = venv.reset(key, params_list[0])
    action = venv.sample_action(key)
    step(key, state, action, params_list[0])  # warm-up entry
    with compile_guard(label, max_compiles=0):
        for p in params_list[1:]:
            step(key, state, action, p)
    n = cache_entries(step)
    if n != 1:
        raise RecompileError(
            label,
            [CompileEvent("step", f"{n} cache entries", "cache-size check")],
            1,
        )
    return len(params_list)
