"""Small shared utilities: pytree dataclasses, unit constants, tree math."""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence, TypeVar

import jax

_T = TypeVar("_T")

# ---------------------------------------------------------------------------
# Units
# ---------------------------------------------------------------------------
MINUTES_PER_DAY = 24 * 60


def steps_per_day(dt_minutes: float) -> int:
    return int(round(MINUTES_PER_DAY / dt_minutes))


# ---------------------------------------------------------------------------
# Pytree dataclasses
# ---------------------------------------------------------------------------
def pytree_dataclass(cls: type[_T] | None = None, *, meta_fields: tuple[str, ...] = ()):
    """A frozen dataclass registered as a JAX pytree.

    ``meta_fields`` are static (hashable, not traced); everything else is data.
    """

    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)
        data_fields = tuple(
            f.name for f in dataclasses.fields(c) if f.name not in meta_fields
        )
        jax.tree_util.register_dataclass(
            c, data_fields=data_fields, meta_fields=tuple(meta_fields)
        )
        return c

    if cls is None:
        return wrap
    return wrap(cls)


def replace(obj: _T, **kwargs: Any) -> _T:
    """dataclasses.replace that reads nicely at call sites."""
    return dataclasses.replace(obj, **kwargs)


def stack_pytrees(trees: "Sequence[_T]") -> _T:
    """Stack same-shape pytrees along a new leading axis.

    The ONE stacking helper shared by fleets (station axis) and the scenario
    subsystem (scenario axis) — both ``repro.core.fleet.stack_params`` and
    ``repro.scenarios.stack_params`` are this function.  Structures and
    per-leaf shapes must match exactly; mismatches name the offending leaf.
    """
    import jax.numpy as jnp

    structures = {jax.tree_util.tree_structure(t) for t in trees}
    if len(structures) != 1:
        raise ValueError("pytrees have different structures")

    def stack(path, *xs):
        shapes = {jnp.shape(x) for x in xs}
        if len(shapes) != 1:
            raise ValueError(
                f"cannot stack pytrees: leaf {jax.tree_util.keystr(path)} has "
                f"per-entry shapes {[jnp.shape(x) for x in xs]}"
            )
        return jnp.stack([jnp.asarray(x) for x in xs])

    return jax.tree_util.tree_map_with_path(stack, *trees)


# ---------------------------------------------------------------------------
# Global scan-unroll context (FLOP-probe compiles unroll ALL internal scans so
# XLA cost analysis counts every iteration — see analysis/roofline.py)
# ---------------------------------------------------------------------------
import contextlib

_UNROLL_SCANS = False


def unroll_scans_enabled() -> bool:
    return _UNROLL_SCANS


@contextlib.contextmanager
def unroll_scans(enabled: bool = True):
    global _UNROLL_SCANS
    prev = _UNROLL_SCANS
    _UNROLL_SCANS = enabled
    try:
        yield
    finally:
        _UNROLL_SCANS = prev
