"""Policy evaluation harness: vectorised full-episode rollouts with metrics.

Episode batching goes through :class:`repro.envs.VmapWrapper` — the same
wrapper PPO trains through — so evaluation speaks the ``Environment``
protocol and needs no hand-rolled vmap axes.  Results can be persisted to
the shared JSONL sink (``writer=``, a :class:`repro.obs.MetricsWriter`) so
eval KPIs land in the same schema as training metrics and benchmarks.

Serving-shaped inference lives here too: :func:`serve` /
:func:`make_serve` run one jitted, donated-buffer batched-policy step over
an O(10^5)-observation batch — throughput measured the way a production
control plane would run it (``benchmarks/serve.py`` -> ``BENCH_serve.json``).
"""
from __future__ import annotations

import weakref

import jax
import jax.numpy as jnp

from repro.core.state import EnvParams
from repro.envs import Environment, VmapWrapper
from repro.obs import annotate


def evaluate(
    env: Environment,
    policy,  # (params, key, obs) -> action
    policy_params,
    key: jax.Array,
    num_episodes: int = 16,
    env_params: EnvParams | None = None,
    params_axis: int | None = None,
    writer=None,
    tag: str | None = None,
) -> dict:
    """Run ``num_episodes`` full episodes in parallel; return mean metrics.

    ``params_axis`` mirrors ``make_train``: ``None`` (default) broadcasts one
    parameter pytree to every episode; ``0`` maps a stacked ``(S, ...)``
    pytree (scenario catalog, fleet slices) per-episode, requiring
    ``num_episodes`` to equal the stack size S.

    ``writer``/``tag``: optionally append the result dict to a
    :class:`repro.obs.MetricsWriter` JSONL sink as a ``kind="eval"`` record.
    """
    env_params = env_params if env_params is not None else env.default_params
    if params_axis is not None:
        n_stacked = jax.tree_util.tree_leaves(env_params)[0].shape[params_axis]
        if num_episodes != n_stacked:
            raise ValueError(
                f"params_axis={params_axis} maps params per-episode, so "
                f"num_episodes={num_episodes} must equal the stacked "
                f"parameter count {n_stacked}"
            )
    venv = VmapWrapper(env, num_episodes, params_axis=params_axis)

    @jax.jit
    def run(key):
        obs, state = venv.reset(key, env_params)

        def step_fn(carry, _):
            obs, state, key, ep_reward = carry
            key, k_act, k_step = jax.random.split(key, 3)
            action = policy(policy_params, k_act, obs)
            ts = venv.step(k_step, state, action, env_params)
            return (ts.obs, ts.state, key, ep_reward + ts.reward), None

        with annotate("eval/rollout"):
            (obs, state, _, ep_reward), _ = jax.lax.scan(
                step_fn, (obs, state, key, jnp.zeros(num_episodes)), None,
                env.config.episode_steps,
            )
        delivered = state.energy_delivered.mean()
        discharged = state.energy_discharged.mean()
        return {
            "episode_reward": ep_reward.mean(),
            "episode_reward_std": ep_reward.std(),
            "daily_profit": state.profit_cum.mean(),
            "energy_delivered_kwh": delivered,
            # --- V2G / degradation metrics ---
            "energy_discharged_kwh": discharged,
            # discharge throughput relative to total port throughput: the
            # cycling-wear exposure of the plugged fleet (0 when V2G is off)
            "v2g_discharge_frac": discharged / jnp.maximum(delivered + discharged, 1e-9),
            "cars_served": state.cars_served.mean(),
            "cars_rejected": state.cars_rejected.mean(),
            "missing_kwh": state.missing_kwh_cum.mean(),
            "overtime_steps": state.overtime_steps_cum.mean(),
        }

    result = {k: float(v) for k, v in run(key).items()}
    if writer is not None:
        writer.write(
            {**({"tag": tag} if tag else {}), "num_episodes": num_episodes, **result},
            kind="eval",
        )
    return result


# ---------------------------------------------------------------------------
# Serving-shaped inference: one batched policy step, production-plane style
# ---------------------------------------------------------------------------
def make_serve(policy, donate: bool | None = None):
    """Compile ``policy`` into a serving step ``(params, key, obs_batch) -> action``.

    The returned callable is jitted once and reused for every request batch of
    the same shape — the shape a control plane serving thousands of stations
    actually runs: observations stream in as one ``(B, obs_dim)`` batch, one
    device step maps them to actions.

    ``donate`` donates the observation buffer to the computation so XLA can
    reuse its memory for the output (each serve step consumes its batch —
    exactly the serving access pattern).  Default (``None``): donation on
    accelerators, off on CPU where XLA ignores donation and warns.
    """
    if donate is None:
        donate = jax.default_backend() != "cpu"

    def serve_step(params, key, obs_batch):
        with annotate("eval/serve"):
            return policy(params, key, obs_batch)

    return jax.jit(serve_step, donate_argnums=(2,) if donate else ())


# one compiled serving step per policy callable (weak: dropping the policy
# drops its executable)
_SERVE_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def serve(policy, params, obs_batch, key: jax.Array | None = None):
    """One serving step: batched actions for ``obs_batch`` under ``policy``.

    Convenience wrapper over :func:`make_serve` that caches the compiled step
    per policy callable, so repeated ``serve(policy, ...)`` calls hit one jit
    entry.  ``obs_batch`` is ``(..., obs_dim)`` — any batch shape, typically
    O(10^5) concurrent station observations.  For tight loops (benchmarks,
    actual serving) hold the result of ``make_serve`` yourself.
    """
    try:
        fn = _SERVE_CACHE.get(policy)
    except TypeError:  # unhashable/unweakrefable policy object
        fn = None
    if fn is None:
        fn = make_serve(policy)
        try:
            _SERVE_CACHE[policy] = fn
        except TypeError:
            pass
    if key is None:
        key = jax.random.key(0)
    return fn(params, key, obs_batch)
