"""Policy evaluation harness: vectorised full-episode rollouts with metrics.

Episode batching goes through :class:`repro.envs.VmapWrapper` — the same
wrapper PPO trains through — so evaluation speaks the ``Environment``
protocol and needs no hand-rolled vmap axes.  Results can be persisted to
the shared JSONL sink (``writer=``, a :class:`repro.obs.MetricsWriter`) so
eval KPIs land in the same schema as training metrics and benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.state import EnvParams
from repro.envs import Environment, VmapWrapper
from repro.obs import annotate


def evaluate(
    env: Environment,
    policy,  # (params, key, obs) -> action
    policy_params,
    key: jax.Array,
    num_episodes: int = 16,
    env_params: EnvParams | None = None,
    params_axis: int | None = None,
    writer=None,
    tag: str | None = None,
) -> dict:
    """Run ``num_episodes`` full episodes in parallel; return mean metrics.

    ``params_axis`` mirrors ``make_train``: ``None`` (default) broadcasts one
    parameter pytree to every episode; ``0`` maps a stacked ``(S, ...)``
    pytree (scenario catalog, fleet slices) per-episode, requiring
    ``num_episodes`` to equal the stack size S.

    ``writer``/``tag``: optionally append the result dict to a
    :class:`repro.obs.MetricsWriter` JSONL sink as a ``kind="eval"`` record.
    """
    env_params = env_params if env_params is not None else env.default_params
    if params_axis is not None:
        n_stacked = jax.tree_util.tree_leaves(env_params)[0].shape[params_axis]
        if num_episodes != n_stacked:
            raise ValueError(
                f"params_axis={params_axis} maps params per-episode, so "
                f"num_episodes={num_episodes} must equal the stacked "
                f"parameter count {n_stacked}"
            )
    venv = VmapWrapper(env, num_episodes, params_axis=params_axis)

    @jax.jit
    def run(key):
        obs, state = venv.reset(key, env_params)

        def step_fn(carry, _):
            obs, state, key, ep_reward = carry
            key, k_act, k_step = jax.random.split(key, 3)
            action = policy(policy_params, k_act, obs)
            ts = venv.step(k_step, state, action, env_params)
            return (ts.obs, ts.state, key, ep_reward + ts.reward), None

        with annotate("eval/rollout"):
            (obs, state, _, ep_reward), _ = jax.lax.scan(
                step_fn, (obs, state, key, jnp.zeros(num_episodes)), None,
                env.config.episode_steps,
            )
        delivered = state.energy_delivered.mean()
        discharged = state.energy_discharged.mean()
        return {
            "episode_reward": ep_reward.mean(),
            "episode_reward_std": ep_reward.std(),
            "daily_profit": state.profit_cum.mean(),
            "energy_delivered_kwh": delivered,
            # --- V2G / degradation metrics ---
            "energy_discharged_kwh": discharged,
            # discharge throughput relative to total port throughput: the
            # cycling-wear exposure of the plugged fleet (0 when V2G is off)
            "v2g_discharge_frac": discharged / jnp.maximum(delivered + discharged, 1e-9),
            "cars_served": state.cars_served.mean(),
            "cars_rejected": state.cars_rejected.mean(),
            "missing_kwh": state.missing_kwh_cum.mean(),
            "overtime_steps": state.overtime_steps_cum.mean(),
        }

    result = {k: float(v) for k, v in run(key).items()}
    if writer is not None:
        writer.write(
            {**({"tag": tag} if tag else {}), "num_episodes": num_episodes, **result},
            kind="eval",
        )
    return result
