"""Actor-critic networks for Chargax PPO (paper App. B: standard PureJaxRL MLP).

Functional, flax-free: parameters are nested dicts of jnp arrays.  The policy
head is a *factorized categorical* — one (2D+1)-way categorical per charging
pole plus one for the battery (paper: discretisation level 10 per port).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def orthogonal(key: jax.Array, shape: tuple[int, int], scale: float) -> jnp.ndarray:
    """Orthogonal init (the PPO-standard initialisation)."""
    n_rows, n_cols = shape
    big = max(n_rows, n_cols)
    a = jax.random.normal(key, (big, big), jnp.float32)
    q, r = jnp.linalg.qr(a)
    q = q * jnp.sign(jnp.diag(r))[None, :]
    return scale * q[:n_rows, :n_cols]


def dense_init(key, in_dim, out_dim, scale=jnp.sqrt(2.0)):
    return {
        "w": orthogonal(key, (in_dim, out_dim), scale),
        "b": jnp.zeros((out_dim,), jnp.float32),
    }


def dense(p, x):
    return x @ p["w"] + p["b"]


class PolicyOutput(NamedTuple):
    logits: jnp.ndarray  # (..., n_heads, n_actions)
    value: jnp.ndarray  # (...,)


def init_actor_critic(
    key: jax.Array,
    obs_dim: int,
    n_heads: int,
    n_actions: int,
    hidden: tuple[int, ...] = (128, 128),
) -> dict:
    keys = jax.random.split(key, 2 * len(hidden) + 2)
    params: dict = {"actor": {}, "critic": {}}
    d = obs_dim
    for i, h in enumerate(hidden):
        params["actor"][f"h{i}"] = dense_init(keys[2 * i], d, h)
        params["critic"][f"h{i}"] = dense_init(keys[2 * i + 1], d, h)
        d = h
    params["actor"]["out"] = dense_init(keys[-2], d, n_heads * n_actions, scale=0.01)
    params["critic"]["out"] = dense_init(keys[-1], d, 1, scale=1.0)
    return params


def apply_actor_critic(
    params: dict, obs: jnp.ndarray, n_heads: int, n_actions: int
) -> PolicyOutput:
    n_hidden = sum(1 for k in params["actor"] if k.startswith("h"))
    xa = xc = obs
    for i in range(n_hidden):
        xa = jnp.tanh(dense(params["actor"][f"h{i}"], xa))
        xc = jnp.tanh(dense(params["critic"][f"h{i}"], xc))
    flat_logits = dense(params["actor"]["out"], xa)
    logits = flat_logits.reshape(*obs.shape[:-1], n_heads, n_actions)
    value = dense(params["critic"]["out"], xc)[..., 0]
    return PolicyOutput(logits, value)


# ---------------------------------------------------------------------------
# Factorized categorical distribution helpers
# ---------------------------------------------------------------------------
def sample_action(key: jax.Array, logits: jnp.ndarray) -> jnp.ndarray:
    """(..., H, K) logits -> (..., H) int32 actions."""
    return jax.random.categorical(key, logits, axis=-1)


def log_prob(logits: jnp.ndarray, action: jnp.ndarray) -> jnp.ndarray:
    """Joint log-probability, summed over heads."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, action[..., None], axis=-1)[..., 0]
    return picked.sum(axis=-1)


def entropy(logits: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -(jnp.exp(logp) * logp).sum(axis=-1).sum(axis=-1)
