"""RL substrate: PureJaxRL-style PPO, baselines, evaluation (paper §5)."""
from repro.rl.ppo import PPOConfig, make_train, make_ppo_policy
from repro.rl.baselines import (
    BASELINES,
    max_charge_policy,
    price_threshold_policy,
    random_policy,
    v2g_arbitrage_policy,
)
from repro.rl.eval import evaluate, make_serve, serve
from repro.rl import networks

__all__ = [
    "make_serve",
    "serve",
    "PPOConfig",
    "make_train",
    "make_ppo_policy",
    "BASELINES",
    "max_charge_policy",
    "price_threshold_policy",
    "random_policy",
    "v2g_arbitrage_policy",
    "evaluate",
    "networks",
]
