"""Rule-based baselines (paper §5: 'always charge to maximum potential')."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.env import ChargaxEnv


def max_charge_policy(env: ChargaxEnv):
    """Paper's baseline: max level at every EVSE, battery idle."""
    d = env.config.discretization
    a = jnp.full((env.num_action_heads,), 2 * d, dtype=jnp.int32).at[-1].set(d)

    def policy(params, key, obs):
        return jnp.broadcast_to(a, obs.shape[:-1] + a.shape)

    return policy


def random_policy(env: ChargaxEnv):
    def policy(params, key, obs):
        return jax.random.randint(
            key, obs.shape[:-1] + (env.num_action_heads,), 0, env.num_actions_per_head
        )

    return policy


def price_threshold_policy(env: ChargaxEnv, low_frac: float = 0.4):
    """Heuristic: full charge when the current price is in the cheap band,
    half rate otherwise; battery charges when cheap, discharges when expensive.
    Uses only observation features (current price vs 4h-ahead mean)."""
    d = env.config.discretization

    def policy(params, key, obs):
        p_now = obs[..., -3]
        p_mean4 = obs[..., -1]
        cheap = p_now < (1.0 - low_frac * 0.5) * p_mean4
        port_level = jnp.where(cheap, 2 * d, int(1.5 * d))
        batt_level = jnp.where(cheap, 2 * d, 0)
        ports = jnp.broadcast_to(
            port_level[..., None], obs.shape[:-1] + (env.n_evse,)
        )
        batt = batt_level[..., None]
        return jnp.concatenate([ports, batt], axis=-1).astype(jnp.int32)

    return policy


BASELINES = {
    "max_charge": max_charge_policy,
    "random": random_policy,
    "price_threshold": price_threshold_policy,
}
