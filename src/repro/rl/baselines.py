"""Rule-based baselines (paper §5: 'always charge to maximum potential').

Every baseline is a factory ``make(env, ...) -> policy`` where ``policy`` is
a ``(params, key, obs) -> action`` callable typed against the env's
``action_space`` (:mod:`repro.envs.spaces`): actions have the space's shape
appended to ``obs``'s batch shape, with values in ``[0, num_categories)``.
Constant policies ignore ``params``/``key``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import EnvParams
from repro.envs import Environment


def make_baseline_max_action(env: Environment):
    """Paper's baseline as a policy: 'always charge to maximum potential'.

    Max level on every EVSE head; battery idle (centre level).  Returns a
    ``policy(params, key, obs) -> action`` callable like every other
    baseline — the historical version returned a bare action array, the odd
    one out.  ``obs``'s leading axes set the batch shape; ``params``/``key``
    are ignored (the policy is constant).  (Moved here from
    ``repro.core.env``, which keeps a deprecation alias.)
    """
    d = env.config.discretization
    space = env.action_space
    a = jnp.full(space.shape, 2 * d, dtype=space.dtype)
    a = a.at[..., -1].set(d)  # battery: 0 amps

    def policy(params, key, obs):
        return jnp.broadcast_to(a, jnp.shape(obs)[:-1] + a.shape)

    return policy


def max_charge_policy(env: Environment):
    """Paper's baseline: max level at every EVSE, battery idle."""
    return make_baseline_max_action(env)


def random_policy(env: Environment):
    space = env.action_space

    def policy(params, key, obs):
        return jax.random.randint(
            key, jnp.shape(obs)[:-1] + space.shape, 0, space.num_categories,
            space.dtype,
        )

    return policy


def price_threshold_policy(env: Environment, low_frac: float = 0.4):
    """Heuristic: full charge when the current price is in the cheap band,
    half rate otherwise; battery charges when cheap, discharges when expensive.
    Uses only observation features (current price vs 4h-ahead mean)."""
    d = env.config.discretization
    n_ports = env.action_space.shape[-1] - 1  # last head is the battery

    def policy(params, key, obs):
        p_now = obs[..., -3]
        p_mean4 = obs[..., -1]
        cheap = p_now < (1.0 - low_frac * 0.5) * p_mean4
        port_level = jnp.where(cheap, 2 * d, int(1.5 * d))
        batt_level = jnp.where(cheap, 2 * d, 0)
        ports = jnp.broadcast_to(
            port_level[..., None], jnp.shape(obs)[:-1] + (n_ports,)
        )
        batt = batt_level[..., None]
        return jnp.concatenate([ports, batt], axis=-1).astype(jnp.int32)

    return policy


def v2g_arbitrage_policy(
    env: Environment,
    env_params: EnvParams | None = None,
    hi_quantile: float = 0.75,
    lo_quantile: float = 0.40,
    met_frac: float = 0.02,
):
    """V2G price arbitrage: discharge *idle-full* packs above a price quantile.

    Thresholds are quantiles of the scenario's own price table (so the same
    rule transfers across ToU/flat/crisis tariffs).  Above ``hi_quantile``
    the battery and every port whose *original* request is already served
    (``e_remain`` is all V2G debt: the pack earns nothing idle, so cycling
    it has zero opportunity cost) sell at ``grid_sell_discount * p_buy``
    while compensating owners ``p_v2g_comp``; debt is repaid once prices
    drop, never at the peak.  Ports with unmet customer demand always
    charge at max: the retail margin ``p_sell - p_buy`` dominates any grid
    spread.  The battery refills in the cheap band below ``lo_quantile``.
    Needs ``EnvConfig(allow_v2g=True)`` for the port discharge to act.
    """
    params = env_params if env_params is not None else env.default_params
    table = jnp.asarray(params.price_buy_table)
    q_hi = jnp.quantile(table, hi_quantile)
    q_lo = jnp.quantile(table, lo_quantile)
    d = env.config.discretization
    n = env.action_space.shape[-1] - 1  # EVSE heads (battery is last)

    def policy(params, key, obs):
        # observation layout: 8 features per port (see observation_space)
        port = obs[..., : 8 * n].reshape(jnp.shape(obs)[:-1] + (n, 8))
        # original request served when the remaining energy is all V2G debt
        met = port[..., 3] - port[..., 4] < met_frac
        p_now = obs[..., -3]  # current buy price (observation price feats)
        expensive = p_now >= q_hi
        cheap = p_now <= q_lo
        port_level = jnp.where(expensive[..., None] & met, 0, 2 * d)
        batt_level = jnp.where(expensive, 0, jnp.where(cheap, 2 * d, d))
        batt = batt_level[..., None]
        return jnp.concatenate([port_level, batt], axis=-1).astype(jnp.int32)

    return policy


def grid_aware_policy(env: Environment, env_params: EnvParams | None = None):
    """Curtailment baseline for grid-coupled scenarios: never overshoot the cap.

    Derates every port's charge level so the station's *worst-case gross
    grid draw* (all real ports at the derated level, grid-side, i.e. inflated
    by path efficiency) fits under the scenario's tightest feeder cap
    ``min(grid_cap_kw_table)``.  The battery stays idle (it only adds draw).
    All thresholds are factory-time Python floats, so the policy itself is a
    constant broadcast — jit/vmap/scan-transparent like ``max_charge``, and
    ``grid/violation == 0`` by construction: actual draw <= worst-case
    derated draw <= min-cap <= cap(t).  With the default unlimited cap the
    derate factor is 1 and this degrades to the max-charge baseline.
    """
    params = env_params if env_params is not None else env.default_params
    cap_min = float(np.min(np.asarray(params.grid_cap_kw_table)))
    p_max = float(
        np.sum(
            np.asarray(params.evse_voltage)
            * np.asarray(params.evse_max_current)
            * np.asarray(params.evse_mask)
            / np.asarray(params.evse_path_eff)
        )
        / 1000.0
    )
    frac = min(1.0, cap_min / max(p_max, 1e-9))
    d = env.config.discretization
    space = env.action_space
    # floor: the discrete level just UNDER the continuous derate fraction
    port_level = d + int(np.floor(d * frac))
    a = jnp.full(space.shape, port_level, dtype=space.dtype)
    a = a.at[..., -1].set(d)  # battery: 0 amps

    def policy(params, key, obs):
        return jnp.broadcast_to(a, jnp.shape(obs)[:-1] + a.shape)

    return policy


BASELINES = {
    "max_charge": max_charge_policy,
    "random": random_policy,
    "price_threshold": price_threshold_policy,
    "v2g_arbitrage": v2g_arbitrage_policy,
    "grid_aware": grid_aware_policy,
}
