"""PPO on Chargax — PureJaxRL-style, fully jitted (paper §5, App. B).

The whole training run (rollout scan -> GAE -> minibatch epochs) is one jitted
function; environments are vectorised on-device, matching the paper's setup
(Lu et al., 2022).  Hyperparameter defaults replicate paper Table 3.

Environment plumbing goes through the ``repro.envs`` protocol: ``make_train``
wraps the env as ``AutoReset(VmapWrapper(env, num_envs))`` — the wrapper
stack owns batching, the nested scenario×env layout (one exogenous-table
copy per scenario) and episode restarts, so this file contains *no*
env-specific vmap glue and any :class:`repro.envs.Environment` with the
Chargax action layout trains unchanged.

For pod-scale runs, ``shard_envs`` places the environment batch on the mesh's
data axes so rollouts parallelise across chips without host transfers
(DESIGN.md §3) — the same function compiles for 1 CPU device and for the
production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.state import EnvParams
from repro.distributed import env_sharding
from repro.envs import AutoReset, Environment, LogWrapper, VmapWrapper
from repro.obs import annotate
from repro.optim import AdamWConfig, adamw_init, adamw_update, apply_updates, linear_anneal
from repro.rl import networks

# domain KPIs accumulated on device through the rollout scan (LogWrapper's
# MetricsAccumulator) and reported per update as ``metrics["kpi/<name>"]`` —
# batch-mean per-env-step rates, no extra device syncs.  All are per-step
# scalars the env already emits in ``info``.
DEFAULT_KPI_METRICS = (
    "profit",
    "energy_delivered",
    "energy_discharged",
    "v2g_debt",
    "missing_kwh",
    "rejected",
)


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    """Paper Table 3 defaults."""

    total_timesteps: int = 10_000_000
    lr: float = 2.5e-4
    anneal_lr: bool = True
    gamma: float = 0.99
    gae_lambda: float = 0.95
    max_grad_norm: float = 100.0
    clip_eps: float = 0.2
    vf_clip: float = 10.0
    ent_coef: float = 0.01
    vf_coef: float = 0.25
    num_envs: int = 12
    rollout_steps: int = 300
    num_minibatches: int = 4
    update_epochs: int = 4
    hidden: tuple[int, ...] = (128, 128)
    # reward normalisation scale (profits are O(10) per step)
    reward_scale: float = 0.1

    @property
    def batch_size(self) -> int:
        return self.num_envs * self.rollout_steps

    @property
    def minibatch_size(self) -> int:
        return self.batch_size // self.num_minibatches

    @property
    def num_updates(self) -> int:
        return max(self.total_timesteps // self.batch_size, 1)


class Transition(NamedTuple):
    done: jnp.ndarray
    action: jnp.ndarray
    value: jnp.ndarray
    reward: jnp.ndarray
    log_prob: jnp.ndarray
    obs: jnp.ndarray
    info: dict


class RunnerState(NamedTuple):
    params: dict
    opt_state: Any
    env_state: Any
    obs: jnp.ndarray
    key: jax.Array
    update_idx: jnp.ndarray


def make_train(
    config: PPOConfig,
    env: Environment,
    env_params: EnvParams | None = None,
    shard_envs: Callable[[jnp.ndarray], jnp.ndarray] | None = None,
    scenario_params: EnvParams | None = None,
    kpi_metrics: tuple[str, ...] = DEFAULT_KPI_METRICS,
) -> Callable[[jax.Array], dict]:
    """Build the full jitted training function: key -> {runner_state, metrics}.

    ``env`` is any single-instance :class:`repro.envs.Environment`; batching
    and episode restarts come from ``AutoReset(VmapWrapper(env, num_envs))``.

    ``scenario_params`` — a stacked ``(S, ...)`` parameter pytree (e.g. from
    ``scenarios.stack_params``) — trains one agent across a scenario
    *distribution* for robustness (the paper's distribution-shift setting):
    the ``num_envs`` parallel environments are split into S contiguous blocks
    of ``num_envs // S`` and stepped under ``VmapWrapper``'s *nested* vmap
    (scenario axis outer, envs-per-scenario inner), so every rollout mixes
    all S worlds and the minibatches interleave them while device memory
    holds exactly ONE copy of each scenario's exogenous tables (leading axis
    S, never ``num_envs``).  The returned ``train`` function carries the
    resolved parameter pytree as ``train.lowered_env_params`` for
    introspection.
    """
    n_heads = env.action_space.shape[-1]
    n_actions = env.action_space.num_categories
    obs_dim = env.observation_space.shape[-1]
    constrain = shard_envs or env_sharding.constrain_env_batch

    if scenario_params is not None:
        if env_params is not None:
            raise ValueError("pass either env_params or scenario_params, not both")
        n_scen = jax.tree_util.tree_leaves(scenario_params)[0].shape[0]
        if config.num_envs % n_scen != 0:
            raise ValueError(
                f"num_envs={config.num_envs} is not a multiple of {n_scen} "
                "scenarios: the nested vmap assigns num_envs // S envs per "
                "scenario, so an uneven split would drop scenarios or skew "
                "the training mixture; adjust num_envs"
            )
        env_params = jax.tree_util.tree_map(jnp.asarray, scenario_params)
    else:
        env_params = env_params if env_params is not None else env.default_params
        n_scen = None

    lr = (
        linear_anneal(config.lr, config.num_updates * config.update_epochs * config.num_minibatches)
        if config.anneal_lr
        else (lambda step: jnp.float32(config.lr))
    )
    opt_cfg = AdamWConfig(max_grad_norm=config.max_grad_norm)

    # the wrapper stack owns ALL env batching: a flat (num_envs,) vmap, or
    # the nested scenario×env layout when scenario_params is given; AutoReset
    # restarts finished episodes inside step; LogWrapper (outermost, so its
    # running totals survive restarts) carries episode accounting and the
    # in-jit KPI accumulator.  reward/done/obs pass through LogWrapper
    # unchanged, so training math is bit-identical with KPIs on or off.
    venv = VmapWrapper(env, config.num_envs, num_scenarios=n_scen)
    wenv = LogWrapper(AutoReset(venv), metrics=tuple(kpi_metrics))

    def policy(params, obs):
        return networks.apply_actor_critic(params, obs, n_heads, n_actions)

    def train(key: jax.Array) -> dict:
        key, k_net, k_reset = jax.random.split(key, 3)
        params = networks.init_actor_critic(
            k_net, obs_dim, n_heads, n_actions, config.hidden
        )
        opt_state = adamw_init(params)
        obs, env_state = wenv.reset(k_reset, env_params)
        obs = constrain(obs)

        def env_step(runner: RunnerState, _):
            params, opt_state, env_state, obs, key, upd = runner
            key, k_act, k_env = jax.random.split(key, 3)
            out = policy(params, obs)
            action = networks.sample_action(k_act, out.logits)
            logp = networks.log_prob(out.logits, action)

            # step + auto-reset: ts.obs/ts.state restart where done, while
            # ts.reward/ts.done still describe the finishing transition
            ts = wenv.step(k_env, env_state, action, env_params)
            n_obs = constrain(ts.obs)

            t = Transition(
                ts.done, action, out.value, ts.reward * config.reward_scale, logp, obs,
                {k: ts.info[k] for k in ("profit", "missing_kwh", "rejected")},
            )
            return RunnerState(params, opt_state, ts.state, n_obs, key, upd), t

        def compute_gae(traj: Transition, last_val: jnp.ndarray):
            def scan_fn(carry, t):
                gae, next_value = carry
                delta = t.reward + config.gamma * next_value * (1 - t.done) - t.value
                gae = delta + config.gamma * config.gae_lambda * (1 - t.done) * gae
                return (gae, t.value), gae

            _, advantages = jax.lax.scan(
                scan_fn,
                (jnp.zeros_like(last_val), last_val),
                traj,
                reverse=True,
            )
            return advantages, advantages + traj.value

        def loss_fn(params, batch: Transition, gae, targets):
            out = policy(params, batch.obs)
            logp = networks.log_prob(out.logits, batch.action)
            ratio = jnp.exp(logp - batch.log_prob)
            gae_n = (gae - gae.mean()) / (gae.std() + 1e-8)
            pg1 = ratio * gae_n
            pg2 = jnp.clip(ratio, 1 - config.clip_eps, 1 + config.clip_eps) * gae_n
            pg_loss = -jnp.minimum(pg1, pg2).mean()

            v_clip = batch.value + jnp.clip(
                out.value - batch.value, -config.vf_clip, config.vf_clip
            )
            v_losses = jnp.square(out.value - targets)
            v_losses_clip = jnp.square(v_clip - targets)
            v_loss = 0.5 * jnp.maximum(v_losses, v_losses_clip).mean()

            ent = networks.entropy(out.logits).mean()
            total = pg_loss + config.vf_coef * v_loss - config.ent_coef * ent
            return total, {"pg_loss": pg_loss, "v_loss": v_loss, "entropy": ent}

        def update_minibatch(carry, batch):
            params, opt_state = carry
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch[0], batch[1], batch[2]
            )
            updates, opt_state, gnorm = adamw_update(grads, opt_state, params, lr, opt_cfg)
            params = apply_updates(params, updates)
            return (params, opt_state), {"loss": loss, "grad_norm": gnorm, **aux}

        def update_epoch(carry, _):
            params, opt_state, traj, gae, targets, key = carry
            key, k_perm = jax.random.split(key)
            bs = config.batch_size
            perm = jax.random.permutation(k_perm, bs)

            flat = jax.tree_util.tree_map(
                lambda x: x.reshape((bs,) + x.shape[2:]), (traj, gae, targets)
            )
            shuffled = jax.tree_util.tree_map(lambda x: jnp.take(x, perm, axis=0), flat)
            minibatches = jax.tree_util.tree_map(
                lambda x: x.reshape((config.num_minibatches, -1) + x.shape[1:]), shuffled
            )
            (params, opt_state), metrics = jax.lax.scan(
                update_minibatch, (params, opt_state), minibatches
            )
            return (params, opt_state, traj, gae, targets, key), metrics

        def update_step(runner: RunnerState, _):
            acc_before = runner.env_state.metrics
            with annotate("ppo/rollout"):
                runner, traj = jax.lax.scan(
                    env_step, runner, None, config.rollout_steps
                )
            params, opt_state, env_state, obs, key, upd = runner
            with annotate("ppo/gae"):
                last_val = policy(params, obs).value
                gae, targets = compute_gae(traj, last_val)

            with annotate("ppo/update"):
                carry = (params, opt_state, traj, gae, targets, key)
                carry, metrics = jax.lax.scan(
                    update_epoch, carry, None, config.update_epochs
                )
                params, opt_state, _, _, _, key = carry

            mean_ep_reward = traj.reward.sum(axis=0).mean() / config.reward_scale
            mean_profit = traj.info["profit"].mean() * env.config.episode_steps
            out_metrics = {
                "mean_step_reward": traj.reward.mean() / config.reward_scale,
                "rollout_reward": mean_ep_reward,
                "mean_daily_profit": mean_profit,
                "missing_kwh": traj.info["missing_kwh"].mean(),
                "rejected": traj.info["rejected"].mean(),
                "loss": metrics["loss"].mean(),
                "entropy": metrics["entropy"].mean(),
                # LogWrapper episode accounting: last finished episode per env
                "episode_return": env_state.returned_episode_return.mean(),
                "episode_length": env_state.returned_episode_length.astype(
                    jnp.float32
                ).mean(),
            }
            if acc_before is not None:
                # this update's KPI window: batch-mean per-env-step rates from
                # the on-device accumulator (still traced — no host sync)
                delta = env_state.metrics.since(acc_before)
                steps = jnp.maximum(delta.count.mean(), 1.0)
                for n, s in delta.sums.items():
                    out_metrics[f"kpi/{n}"] = s.mean() / steps
            return RunnerState(params, opt_state, env_state, obs, key, upd + 1), out_metrics

        runner = RunnerState(params, opt_state, env_state, obs, key, jnp.int32(0))
        runner, metrics = jax.lax.scan(update_step, runner, None, config.num_updates)
        return {"runner_state": runner, "metrics": metrics}

    # introspection: the parameter pytree exactly as it will be closed over
    # and lowered — tests assert scenario tables keep leading axis S (one
    # copy per scenario), not num_envs (a copy per environment).
    train.lowered_env_params = env_params
    train.scenario_shape = (
        (n_scen, config.num_envs // n_scen) if n_scen is not None else None
    )
    return train


def make_ppo_policy(env: Environment, greedy: bool = True):
    """Wrap trained params into an eval policy: (params, key, obs) -> action."""
    n_heads = env.action_space.shape[-1]
    n_actions = env.action_space.num_categories

    def policy(params, key, obs):
        out = networks.apply_actor_critic(params, obs, n_heads, n_actions)
        if greedy:
            return jnp.argmax(out.logits, axis=-1)
        return networks.sample_action(key, out.logits)

    return policy
