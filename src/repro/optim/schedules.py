"""Learning-rate schedules (step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.float32(lr)


def linear_anneal(lr: float, total_steps: int):
    """PureJaxRL-style linear anneal to 0 (paper Table 3: 'annealed')."""

    def fn(step):
        frac = 1.0 - jnp.minimum(step.astype(jnp.float32) / total_steps, 1.0)
        return jnp.float32(lr) * frac

    return fn


def cosine_warmup_schedule(peak_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    """Linear warmup then cosine decay to final_frac * peak (LM pretraining)."""

    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac * peak_lr + (1 - final_frac) * peak_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, cos)

    return fn
