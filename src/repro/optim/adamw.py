"""AdamW with global-norm clipping and optional fp32 master weights."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass


@pytree_dataclass(meta_fields=("b1", "b2", "eps", "weight_decay", "max_grad_norm"))
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    max_grad_norm: float | None = None


@pytree_dataclass
class AdamWState:
    step: jnp.ndarray  # () int32
    mu: dict  # first moment, same tree as params (fp32)
    nu: dict  # second moment (fp32)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree, max_norm: float) -> tuple:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return AdamWState(step=jnp.int32(0), mu=zeros, nu=jax.tree_util.tree_map(jnp.copy, zeros))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr: jnp.ndarray | float | Callable[[jnp.ndarray], jnp.ndarray],
    config: AdamWConfig = AdamWConfig(),
):
    """Returns (updates, new_state, grad_norm).  new_params = params + updates.

    Moments are fp32 regardless of grad dtype; updates are cast back to the
    parameter dtype (so bf16 params + fp32 moments works out of the box).
    """
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr

    if config.max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, config.max_grad_norm)
    else:
        gnorm = global_norm(grads)

    b1, b2 = config.b1, config.b2

    def moment_update(g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu_n = b1 * mu + (1 - b1) * g32
        nu_n = b2 * nu + (1 - b2) * jnp.square(g32)
        return mu_n, nu_n

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    new_mu, new_nu = [], []
    for g, m, n in zip(flat_g, flat_mu, flat_nu):
        m2, n2 = moment_update(g, m, n)
        new_mu.append(m2)
        new_nu.append(n2)
    mu_t = jax.tree_util.tree_unflatten(treedef, new_mu)
    nu_t = jax.tree_util.tree_unflatten(treedef, new_nu)

    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def make_update(m, n, p):
        mhat = m / bc1
        nhat = n / bc2
        upd = -lr_t * (
            mhat / (jnp.sqrt(nhat) + config.eps)
            + config.weight_decay * p.astype(jnp.float32)
        )
        return upd.astype(p.dtype)

    updates = jax.tree_util.tree_map(make_update, mu_t, nu_t, params)
    return updates, AdamWState(step=step, mu=mu_t, nu=nu_t), gnorm


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
