"""Minimal optax-style optimizer substrate (flax/optax are not available offline).

Provides AdamW with:
  * schedule functions (linear/cosine with warmup),
  * global-norm gradient clipping,
  * optional fp32 master copies for bf16 parameter training (LM trainer),
  * a gradient-transformation interface: ``init(params) -> state``,
    ``update(grads, state, params) -> (updates, state)`` where
    ``new_params = params + updates``.
"""
from repro.optim.adamw import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    apply_updates,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.schedules import constant_schedule, cosine_warmup_schedule, linear_anneal

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "apply_updates",
    "clip_by_global_norm",
    "global_norm",
    "constant_schedule",
    "cosine_warmup_schedule",
    "linear_anneal",
]
